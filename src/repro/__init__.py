"""repro — OSS Vizier reproduced as a production-grade JAX framework ("VizierX").

Layers:
  core/         Vizier primitives (Study/Trial/SearchSpace/StudyConfig/Metadata)
  pythia/       developer API (Policy, PolicySupporter, Designers, algorithms)
  service/      distributed fault-tolerant service (RPC, datastore, operations)
  tuning/       Vizier <-> JAX-trainer integration (workers, shardtune)
  models/       assigned architecture zoo (dense/GQA/MLA/MoE/Mamba2/xLSTM/enc-dec)
  configs/      one config per assigned architecture + input shapes
  distributed/  mesh & logical sharding rules, gradient compression, elastic
  train/        optimizer, data pipeline, checkpointing, train loop
  serve/        KV/SSM cache decode engine
  kernels/      Pallas TPU kernels (+ jnp oracles) for compute hot-spots
  launch/       production mesh, multi-pod dry-run, roofline, train driver
"""

__version__ = "1.0.0"
