"""JAX version-compatibility shims.

Compat policy: the repo targets the newest JAX APIs but must import and run
on the JAX baked into the container. Anything newer than the installed
version gets a guarded import here, with a graceful fallback that preserves
call-site semantics. Call sites never import version-gated symbols from
``jax.*`` directly — they go through this module, so a JAX upgrade means
deleting shims, not hunting imports.

Currently shimmed:

* ``jax.sharding.AxisType`` (added after 0.4.x) — falls back to a sentinel
  enum with the same member names; ``HAS_AXIS_TYPE`` tells callers whether
  the real thing is available.
* ``axis_types=`` kwarg of ``jax.make_mesh`` — ``make_mesh`` below forwards
  it only when the installed signature accepts it.
"""

from __future__ import annotations

import enum
import inspect
from typing import Optional, Sequence

import jax

try:  # jax >= 0.5: explicit/auto/manual mesh axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:  # older jax: every axis behaves like Auto

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False


_MAKE_MESH_TAKES_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Sequence] = None,
    devices=None,
):
    """``jax.make_mesh`` that tolerates JAX versions without ``axis_types``.

    On older JAX every mesh axis is implicitly Auto, which is exactly what
    the fallback provides — callers requesting Auto axes lose nothing.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and HAS_AXIS_TYPE and _MAKE_MESH_TAKES_AXIS_TYPES:
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
