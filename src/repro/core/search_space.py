"""Search space primitives (paper §4.2, Appendix A).

ParameterConfig covers the four primitives — DOUBLE, INTEGER, DISCRETE,
CATEGORICAL — each numerical one with a scaling type, and each potentially
carrying *conditional* child parameters that are only active when the parent
takes specific values.

SearchSpace + SearchSpaceSelector reproduce the PyVizier construction API:

    space = SearchSpace()
    root = space.select_root()
    root.add_float_param('learning_rate', 1e-4, 1e-2, scale_type=ScaleType.LOG)
    model = root.add_categorical_param('model', ['linear', 'dnn'])
    model.select_values(['dnn']).add_int_param('num_layers', 1, 5)
"""

from __future__ import annotations

import dataclasses
import enum
import math
import random as _random
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

ParameterValueTypes = Union[float, int, str, bool]


class ParameterType(enum.Enum):
    DOUBLE = "DOUBLE"
    INTEGER = "INTEGER"
    DISCRETE = "DISCRETE"
    CATEGORICAL = "CATEGORICAL"

    def is_numeric(self) -> bool:
        return self != ParameterType.CATEGORICAL


class ScaleType(enum.Enum):
    """Toggles the transformed space the optimizer works in (paper §4.2)."""

    LINEAR = "UNIT_LINEAR_SCALE"
    LOG = "UNIT_LOG_SCALE"
    REVERSE_LOG = "UNIT_REVERSE_LOG_SCALE"
    UNIFORM_DISCRETE = "UNIT_UNIFORM_DISCRETE"


class ExternalType(enum.Enum):
    """How INTEGER/DISCRETE values surface to user code."""

    INTERNAL = "INTERNAL"
    BOOLEAN = "BOOLEAN"
    INTEGER = "INTEGER"
    FLOAT = "FLOAT"


@dataclasses.dataclass(frozen=True)
class ParameterValue:
    """A single parameter assignment value (PyVizier ParameterValue)."""

    value: ParameterValueTypes

    @property
    def as_float(self) -> float:
        if isinstance(self.value, bool):
            return float(self.value)
        return float(self.value)  # raises for non-numeric strings

    @property
    def as_int(self) -> int:
        return int(self.as_float)

    @property
    def as_str(self) -> str:
        return str(self.value)

    @property
    def as_bool(self) -> bool:
        if isinstance(self.value, bool):
            return self.value
        if isinstance(self.value, str):
            return self.value.lower() == "true"
        return bool(self.value)

    def to_proto(self) -> dict:
        if isinstance(self.value, bool):
            return {"string_value": "true" if self.value else "false"}
        if isinstance(self.value, (int, float)):
            # native type on the wire: int stays int, float stays float, so
            # from_proto can reconstruct exactly what the user set
            return {"number_value": self.value}
        return {"string_value": str(self.value)}

    @classmethod
    def from_proto(cls, proto: dict) -> "ParameterValue":
        if "number_value" in proto:
            # The wire (msgpack/json) distinguishes int from float, so the
            # user-set type survives the roundtrip: 3.0 stays a float, 3 an
            # int. (Demoting integral doubles here used to make
            # ParameterDict.as_dict() return a different type than was set.)
            # Migration note: blobs persisted before this change stored every
            # numeric as float, so their INTEGER values now read back as
            # integral floats — use .as_int when the config says INTEGER.
            v = proto["number_value"]
            return cls(int(v) if isinstance(v, int) and not isinstance(v, bool)
                       else float(v))
        return cls(proto.get("string_value", ""))


class ParameterDict(dict):
    """dict[str, ParameterValue] with convenient raw-value assignment."""

    def __setitem__(self, key: str, value):
        if not isinstance(value, ParameterValue):
            value = ParameterValue(value)
        super().__setitem__(key, value)

    def get_value(self, key: str, default=None):
        if key in self:
            return self[key].value
        return default

    def as_dict(self) -> Dict[str, ParameterValueTypes]:
        return {k: v.value for k, v in self.items()}

    @classmethod
    def from_dict(cls, d: Dict[str, ParameterValueTypes]) -> "ParameterDict":
        pd = cls()
        for k, v in d.items():
            pd[k] = v
        return pd


def _lehmer_encode_bounds(n: int) -> List[int]:
    """Bounds [n, n-1, ..., 1] for the Lehmer-code reparameterization of
    permutations over [n] (paper Appendix A.1.1)."""
    return list(range(n, 0, -1))


def lehmer_decode(code: Sequence[int]) -> List[int]:
    """Decodes a Lehmer code into a permutation of range(len(code))."""
    pool = list(range(len(code)))
    out = []
    for c in code:
        out.append(pool.pop(c))
    return out


def subset_decode(code: Sequence[int], n: int) -> List[int]:
    """Decodes indices-without-replacement into a k-subset of range(n)."""
    pool = list(range(n))
    return [pool.pop(c) for c in code]


@dataclasses.dataclass
class ParameterConfig:
    """Specification for a single parameter (PyVizier ParameterConfig)."""

    name: str
    type: ParameterType
    bounds: Optional[Tuple[float, float]] = None  # DOUBLE / INTEGER
    feasible_values: Optional[List[float]] = None  # DISCRETE
    categories: Optional[List[str]] = None  # CATEGORICAL
    scale_type: Optional[ScaleType] = None
    default_value: Optional[ParameterValueTypes] = None
    external_type: ExternalType = ExternalType.INTERNAL
    # Conditional children: list of (matching parent values, child config).
    children: List[Tuple[List[ParameterValueTypes], "ParameterConfig"]] = dataclasses.field(
        default_factory=list
    )

    def __post_init__(self):
        self.validate()

    # -- validation ----------------------------------------------------------
    def validate(self) -> None:
        if self.type in (ParameterType.DOUBLE, ParameterType.INTEGER):
            if self.bounds is None:
                raise ValueError(f"{self.name}: {self.type} requires bounds")
            lo, hi = self.bounds
            if not lo <= hi:
                raise ValueError(f"{self.name}: bounds must satisfy min <= max, got {self.bounds}")
            if self.type == ParameterType.INTEGER and (
                int(lo) != lo or int(hi) != hi
            ):
                raise ValueError(f"{self.name}: INTEGER bounds must be integral")
        elif self.type == ParameterType.DISCRETE:
            if not self.feasible_values:
                raise ValueError(f"{self.name}: DISCRETE requires feasible_values")
            fv = sorted(float(v) for v in self.feasible_values)
            if len(set(fv)) != len(fv):
                raise ValueError(f"{self.name}: duplicate feasible_values")
            self.feasible_values = fv
        elif self.type == ParameterType.CATEGORICAL:
            if not self.categories:
                raise ValueError(f"{self.name}: CATEGORICAL requires categories")
            if len(set(self.categories)) != len(self.categories):
                raise ValueError(f"{self.name}: duplicate categories")
        # the categorical check must precede the LOG-domain check below: that
        # one dereferences bounds/feasible_values, which a CATEGORICAL config
        # has neither of (it used to crash with TypeError before reaching the
        # intended error)
        if self.scale_type is not None and self.type == ParameterType.CATEGORICAL:
            raise ValueError(f"{self.name}: categorical parameters cannot have a scale_type")
        if self.scale_type in (ScaleType.LOG, ScaleType.REVERSE_LOG):
            lo, _ = self.bounds if self.bounds else (min(self.feasible_values), 0)
            if lo <= 0:
                raise ValueError(
                    f"{self.name}: {self.scale_type} scaling requires strictly positive domain"
                )
        if self.default_value is not None and not self.contains(
            ParameterValue(self.default_value)
        ):
            raise ValueError(f"{self.name}: default {self.default_value!r} is infeasible")

    # -- feasibility ----------------------------------------------------------
    def contains(self, value: ParameterValue) -> bool:
        try:
            if self.type == ParameterType.DOUBLE:
                lo, hi = self.bounds
                return lo <= value.as_float <= hi
            if self.type == ParameterType.INTEGER:
                lo, hi = self.bounds
                f = value.as_float
                return f == int(f) and lo <= f <= hi
            if self.type == ParameterType.DISCRETE:
                return any(
                    math.isclose(value.as_float, fv, rel_tol=1e-12, abs_tol=1e-12)
                    for fv in self.feasible_values
                )
            return value.as_str in self.categories
        except (TypeError, ValueError):
            return False

    @property
    def num_feasible_values(self) -> float:
        if self.type == ParameterType.DOUBLE:
            return math.inf
        if self.type == ParameterType.INTEGER:
            return self.bounds[1] - self.bounds[0] + 1
        if self.type == ParameterType.DISCRETE:
            return len(self.feasible_values)
        return len(self.categories)

    # -- [0,1] featurization (scaling-aware; used by all numeric designers) ---
    def to_unit(self, value: ParameterValue) -> float:
        """Maps a feasible value into [0, 1] honoring the scale_type."""
        if self.type == ParameterType.CATEGORICAL:
            return self.categories.index(value.as_str) / max(1, len(self.categories) - 1)
        if self.type == ParameterType.DISCRETE and self.scale_type in (
            None,
            ScaleType.UNIFORM_DISCRETE,
        ):
            idx = min(
                range(len(self.feasible_values)),
                key=lambda i: abs(self.feasible_values[i] - value.as_float),
            )
            return idx / max(1, len(self.feasible_values) - 1)
        lo, hi = self._continuous_bounds()
        v = min(max(value.as_float, lo), hi)
        if hi == lo:
            return 0.0
        if self.scale_type == ScaleType.LOG:
            return (math.log(v) - math.log(lo)) / (math.log(hi) - math.log(lo))
        if self.scale_type == ScaleType.REVERSE_LOG:
            return 1.0 - (math.log(hi + lo - v) - math.log(lo)) / (
                math.log(hi) - math.log(lo)
            )
        return (v - lo) / (hi - lo)

    def from_unit(self, u: float) -> ParameterValue:
        """Inverse of to_unit: maps [0,1] to a feasible value."""
        u = min(max(float(u), 0.0), 1.0)
        if self.type == ParameterType.CATEGORICAL:
            idx = int(round(u * (len(self.categories) - 1)))
            return ParameterValue(self.categories[idx])
        if self.type == ParameterType.DISCRETE and self.scale_type in (
            None,
            ScaleType.UNIFORM_DISCRETE,
        ):
            idx = int(round(u * (len(self.feasible_values) - 1)))
            return ParameterValue(self.feasible_values[idx])
        lo, hi = self._continuous_bounds()
        if self.scale_type == ScaleType.LOG:
            v = math.exp(math.log(lo) + u * (math.log(hi) - math.log(lo)))
        elif self.scale_type == ScaleType.REVERSE_LOG:
            v = hi + lo - math.exp(math.log(lo) + (1 - u) * (math.log(hi) - math.log(lo)))
        else:
            v = lo + u * (hi - lo)
        if self.type == ParameterType.INTEGER:
            return ParameterValue(int(round(min(max(v, lo), hi))))
        if self.type == ParameterType.DISCRETE:
            nearest = min(self.feasible_values, key=lambda fv: abs(fv - v))
            return ParameterValue(nearest)
        # clamp: exp/log roundtrips can overshoot bounds by an ulp
        return ParameterValue(float(min(max(v, lo), hi)))

    def _continuous_bounds(self) -> Tuple[float, float]:
        if self.bounds is not None:
            return float(self.bounds[0]), float(self.bounds[1])
        return float(self.feasible_values[0]), float(self.feasible_values[-1])

    def sample(self, rng: Optional[_random.Random] = None) -> ParameterValue:
        rng = rng or _random
        if self.type == ParameterType.CATEGORICAL:
            return ParameterValue(rng.choice(self.categories))
        if self.type == ParameterType.DISCRETE and self.scale_type in (
            None,
            ScaleType.UNIFORM_DISCRETE,
        ):
            return ParameterValue(rng.choice(self.feasible_values))
        return self.from_unit(rng.random())

    # -- conditional children --------------------------------------------------
    def add_child(
        self, matching_values: Sequence[ParameterValueTypes], child: "ParameterConfig"
    ) -> None:
        for v in matching_values:
            if not self.contains(ParameterValue(v)):
                raise ValueError(
                    f"{self.name}: conditional match value {v!r} is infeasible"
                )
        self.children.append((list(matching_values), child))

    def active_children(self, value: ParameterValue) -> List["ParameterConfig"]:
        out = []
        for matches, child in self.children:
            if any(ParameterValue(m).value == value.value or
                   (isinstance(m, (int, float)) and not isinstance(m, bool) and
                    isinstance(value.value, (int, float)) and
                    math.isclose(float(m), value.as_float, rel_tol=1e-12, abs_tol=1e-12))
                   for m in matches):
                out.append(child)
        return out

    # -- wire format (Vertex Vizier StudySpec.ParameterSpec field names) ------
    def to_proto(self) -> dict:
        p: Dict[str, Any] = {"parameter_id": self.name}
        if self.type == ParameterType.DOUBLE:
            p["double_value_spec"] = {"min_value": self.bounds[0], "max_value": self.bounds[1]}
        elif self.type == ParameterType.INTEGER:
            p["integer_value_spec"] = {
                "min_value": int(self.bounds[0]),
                "max_value": int(self.bounds[1]),
            }
        elif self.type == ParameterType.DISCRETE:
            p["discrete_value_spec"] = {"values": list(self.feasible_values)}
        else:
            p["categorical_value_spec"] = {"values": list(self.categories)}
        if self.scale_type is not None:
            p["scale_type"] = self.scale_type.value
        if self.default_value is not None:
            p["default_value"] = ParameterValue(self.default_value).to_proto()
        if self.external_type != ExternalType.INTERNAL:
            p["external_type"] = self.external_type.value
        if self.children:
            p["conditional_parameter_specs"] = [
                {
                    "parent_values": [ParameterValue(v).to_proto() for v in matches],
                    "parameter_spec": child.to_proto(),
                }
                for matches, child in self.children
            ]
        return p

    @classmethod
    def from_proto(cls, p: dict) -> "ParameterConfig":
        kwargs: Dict[str, Any] = {"name": p["parameter_id"]}
        if "double_value_spec" in p:
            s = p["double_value_spec"]
            kwargs["type"] = ParameterType.DOUBLE
            kwargs["bounds"] = (float(s["min_value"]), float(s["max_value"]))
        elif "integer_value_spec" in p:
            s = p["integer_value_spec"]
            kwargs["type"] = ParameterType.INTEGER
            kwargs["bounds"] = (int(s["min_value"]), int(s["max_value"]))
        elif "discrete_value_spec" in p:
            kwargs["type"] = ParameterType.DISCRETE
            kwargs["feasible_values"] = list(p["discrete_value_spec"]["values"])
        else:
            kwargs["type"] = ParameterType.CATEGORICAL
            kwargs["categories"] = list(p["categorical_value_spec"]["values"])
        if "scale_type" in p:
            kwargs["scale_type"] = ScaleType(p["scale_type"])
        if "default_value" in p:
            kwargs["default_value"] = ParameterValue.from_proto(p["default_value"]).value
        if "external_type" in p:
            kwargs["external_type"] = ExternalType(p["external_type"])
        cfg = cls(**kwargs)
        for cps in p.get("conditional_parameter_specs", ()):
            child = cls.from_proto(cps["parameter_spec"])
            matches = [ParameterValue.from_proto(v).value for v in cps["parent_values"]]
            cfg.add_child(matches, child)
        return cfg


class SearchSpaceSelector:
    """Fluent builder over a list of ParameterConfigs (paper Code Block 1)."""

    def __init__(self, configs: List[ParameterConfig]):
        self._configs = configs

    # base adders -------------------------------------------------------------
    def _add(self, cfg: ParameterConfig) -> "SearchSpaceSelector":
        if any(c.name == cfg.name for c in self._configs):
            raise ValueError(f"duplicate parameter name {cfg.name!r} in this scope")
        self._configs.append(cfg)
        return _ParamSelector(cfg)

    def add_float_param(
        self,
        name: str,
        min_value: float,
        max_value: float,
        *,
        scale_type: Optional[ScaleType] = ScaleType.LINEAR,
        default_value: Optional[float] = None,
    ):
        return self._add(
            ParameterConfig(
                name,
                ParameterType.DOUBLE,
                bounds=(float(min_value), float(max_value)),
                scale_type=scale_type,
                default_value=default_value,
            )
        )

    # alias matching paper pseudocode
    add_float = add_float_param

    def add_int_param(
        self,
        name: str,
        min_value: int,
        max_value: int,
        *,
        scale_type: Optional[ScaleType] = None,
        default_value: Optional[int] = None,
    ):
        return self._add(
            ParameterConfig(
                name,
                ParameterType.INTEGER,
                bounds=(int(min_value), int(max_value)),
                scale_type=scale_type,
                default_value=default_value,
            )
        )

    add_int = add_int_param

    def add_discrete_param(
        self,
        name: str,
        feasible_values: Sequence[float],
        *,
        scale_type: Optional[ScaleType] = None,
        default_value: Optional[float] = None,
    ):
        return self._add(
            ParameterConfig(
                name,
                ParameterType.DISCRETE,
                feasible_values=[float(v) for v in feasible_values],
                scale_type=scale_type,
                default_value=default_value,
            )
        )

    def add_categorical_param(
        self,
        name: str,
        feasible_values: Sequence[str],
        *,
        default_value: Optional[str] = None,
    ):
        return self._add(
            ParameterConfig(
                name,
                ParameterType.CATEGORICAL,
                categories=list(feasible_values),
                default_value=default_value,
            )
        )

    def add_bool_param(self, name: str, *, default_value: Optional[bool] = None):
        sel = self._add(
            ParameterConfig(
                name,
                ParameterType.CATEGORICAL,
                categories=["false", "true"],
                external_type=ExternalType.BOOLEAN,
                default_value=None
                if default_value is None
                else ("true" if default_value else "false"),
            )
        )
        return sel


class _ParamSelector(SearchSpaceSelector):
    """Selector bound to one parameter; supports conditional children."""

    def __init__(self, config: ParameterConfig):
        super().__init__([config])
        self._param = config

    def select_values(self, values: Sequence[ParameterValueTypes]) -> "_ChildScope":
        return _ChildScope(self._param, list(values))


class _ChildScope(SearchSpaceSelector):
    """Scope that adds conditional children active for given parent values."""

    def __init__(self, parent: ParameterConfig, values: List[ParameterValueTypes]):
        self._parent = parent
        self._values = values
        super().__init__([])

    def _add(self, cfg: ParameterConfig):
        self._parent.add_child(self._values, cfg)
        return _ParamSelector(cfg)


@dataclasses.dataclass
class SearchSpace:
    """The feasible space X: a tree of ParameterConfigs (paper §4.2)."""

    parameters: List[ParameterConfig] = dataclasses.field(default_factory=list)

    def select_root(self) -> SearchSpaceSelector:
        return SearchSpaceSelector(self.parameters)

    # -- traversal -------------------------------------------------------------
    def all_parameters(self) -> List[ParameterConfig]:
        """All configs in the tree (DFS), including inactive-able children."""
        out: List[ParameterConfig] = []

        def visit(cfg: ParameterConfig):
            out.append(cfg)
            for _, child in cfg.children:
                visit(child)

        for cfg in self.parameters:
            visit(cfg)
        return out

    def get(self, name: str) -> ParameterConfig:
        for cfg in self.all_parameters():
            if cfg.name == name:
                return cfg
        raise KeyError(name)

    @property
    def is_conditional(self) -> bool:
        return any(cfg.children for cfg in self.parameters)

    # -- validation / sampling ---------------------------------------------------
    def active_parameters(self, parameters: ParameterDict) -> List[ParameterConfig]:
        """Configs active under the given (possibly partial) assignment."""
        active: List[ParameterConfig] = []

        def visit(cfg: ParameterConfig):
            active.append(cfg)
            if cfg.name in parameters:
                for child in cfg.active_children(parameters[cfg.name]):
                    visit(child)

        for cfg in self.parameters:
            visit(cfg)
        return active

    def validate_parameters(self, parameters: ParameterDict) -> None:
        """Raises if assignment is infeasible or has in/extra-active params."""
        active = self.active_parameters(parameters)
        active_names = {c.name for c in active}
        for cfg in active:
            if cfg.name not in parameters:
                raise ValueError(f"missing active parameter {cfg.name!r}")
            if not cfg.contains(parameters[cfg.name]):
                raise ValueError(
                    f"value {parameters[cfg.name].value!r} infeasible for {cfg.name!r}"
                )
        for name in parameters:
            if name not in active_names:
                raise ValueError(f"parameter {name!r} is not active under this assignment")

    def sample(self, rng: Optional[_random.Random] = None) -> ParameterDict:
        """Uniform (scaling-aware) sample respecting conditionality."""
        rng = rng or _random
        out = ParameterDict()

        def visit(cfg: ParameterConfig):
            value = cfg.sample(rng)
            out[cfg.name] = value
            for child in cfg.active_children(value):
                visit(child)

        for cfg in self.parameters:
            visit(cfg)
        return out

    # -- wire ---------------------------------------------------------------------
    def to_proto(self) -> list:
        return [c.to_proto() for c in self.parameters]

    @classmethod
    def from_proto(cls, protos: list) -> "SearchSpace":
        return cls(parameters=[ParameterConfig.from_proto(p) for p in protos or ()])
