"""Namespaced Metadata (paper §4.1, §6.3).

Metadata is a key-value mapping with namespaces that prevent key collisions.
It is *not interpreted* by the service: algorithm authors use it to persist
policy state (SerializableDesigner.dump/recover), users use it for small
arbitrary payloads, and it doubles as a side-channel between user code and
algorithms.

Values are strings or bytes (anything else must be serialized by the caller,
e.g. json/msgpack) — mirroring google.protobuf.Any semantics without protobuf.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping, Optional, Tuple, Union

MetadataValue = Union[str, bytes]


def _check_value(value: MetadataValue) -> MetadataValue:
    if not isinstance(value, (str, bytes)):
        raise TypeError(
            f"Metadata values must be str or bytes; got {type(value).__name__}. "
            "Serialize structured state (e.g. json.dumps) before storing."
        )
    return value


class Namespace(tuple):
    """Hierarchical namespace, e.g. Namespace(('pythia', 'gp_bandit'))."""

    def __new__(cls, components: Union[str, Tuple[str, ...], "Namespace"] = ()):
        if isinstance(components, Namespace):
            return super().__new__(cls, tuple(components))
        if isinstance(components, str):
            components = tuple(c for c in components.split(":") if c)
        return super().__new__(cls, tuple(components))

    def child(self, component: str) -> "Namespace":
        return Namespace(tuple(self) + (component,))

    def encode(self) -> str:
        return ":".join(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Namespace({self.encode()!r})"


class Metadata:
    """A namespaced key-value store.

    ``md['key']`` reads/writes in the current namespace. ``md.ns('sub')``
    returns a *view* into a child namespace sharing the same storage, so a
    Policy can hand sub-namespaces to sub-components safely.
    """

    def __init__(
        self,
        initial: Optional[Mapping[str, MetadataValue]] = None,
        *,
        _store: Optional[Dict[str, Dict[str, MetadataValue]]] = None,
        _namespace: Namespace = Namespace(),
    ):
        # _store maps encoded-namespace -> {key: value}
        self._store: Dict[str, Dict[str, MetadataValue]] = (
            _store if _store is not None else {}
        )
        self._namespace = Namespace(_namespace)
        if initial:
            for k, v in initial.items():
                self[k] = v

    # -- namespace handling -------------------------------------------------
    @property
    def namespace(self) -> Namespace:
        return self._namespace

    def ns(self, component: str) -> "Metadata":
        """Returns a view of the child namespace (shared storage)."""
        return Metadata(_store=self._store, _namespace=self._namespace.child(component))

    def abs_ns(self, namespace: Union[str, Namespace] = Namespace()) -> "Metadata":
        """Returns a view of an absolute namespace (shared storage)."""
        return Metadata(_store=self._store, _namespace=Namespace(namespace))

    def namespaces(self) -> Tuple[Namespace, ...]:
        return tuple(Namespace(k) for k, v in self._store.items() if v)

    # -- mapping protocol (current namespace) --------------------------------
    def _bucket(self) -> Dict[str, MetadataValue]:
        return self._store.setdefault(self._namespace.encode(), {})

    def __getitem__(self, key: str) -> MetadataValue:
        return self._bucket()[key]

    def __setitem__(self, key: str, value: MetadataValue) -> None:
        self._bucket()[key] = _check_value(value)

    def __delitem__(self, key: str) -> None:
        del self._bucket()[key]

    def __contains__(self, key: str) -> bool:
        return key in self._bucket()

    def __iter__(self) -> Iterator[str]:
        return iter(dict(self._bucket()))

    def __len__(self) -> int:
        return len(self._bucket())

    def get(self, key: str, default=None):
        return self._bucket().get(key, default)

    def keys(self):
        return self._bucket().keys()

    def items(self):
        return self._bucket().items()

    def update(self, other: Mapping[str, MetadataValue]) -> None:
        for k, v in other.items():
            self[k] = v

    def clear_ns(self, namespace: Union[str, Namespace, None] = None) -> None:
        """Drops every key in an absolute namespace (default: the current
        one). Used e.g. to discard a persisted algorithm-state checkpoint."""
        ns = self._namespace if namespace is None else Namespace(namespace)
        self._store.pop(ns.encode(), None)

    # -- merge / serialization ----------------------------------------------
    def attach(self, other: "Metadata") -> None:
        """Merges all namespaces of ``other`` into this metadata (last wins)."""
        for ns_key, bucket in other._store.items():
            dst = self._store.setdefault(ns_key, {})
            dst.update(bucket)

    def to_proto(self) -> list:
        """Wire format: list of {key, ns, value} dicts (value str or bytes)."""
        out = []
        for ns_key in sorted(self._store):
            for key in sorted(self._store[ns_key]):
                out.append({"key": key, "ns": ns_key, "value": self._store[ns_key][key]})
        return out

    @classmethod
    def from_proto(cls, proto: Optional[list]) -> "Metadata":
        md = cls()
        for item in proto or ():
            md._store.setdefault(item.get("ns", ""), {})[item["key"]] = item["value"]
        return md

    def __eq__(self, other) -> bool:
        if not isinstance(other, Metadata):
            return NotImplemented
        clean = lambda s: {k: v for k, v in s.items() if v}
        return clean(self._store) == clean(other._store)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Metadata(ns={self._namespace.encode()!r}, store={self._store!r})"


@dataclasses.dataclass
class MetadataDelta:
    """A batch of metadata updates produced by a Pythia policy (paper §6.3).

    ``on_study`` updates StudySpec-level metadata; ``on_trials`` maps trial id
    to per-Trial metadata. Applied atomically by the service.
    """

    on_study: Metadata = dataclasses.field(default_factory=Metadata)
    on_trials: Dict[int, Metadata] = dataclasses.field(default_factory=dict)

    def assign(
        self,
        namespace: str,
        key: str,
        value: MetadataValue,
        *,
        trial_id: Optional[int] = None,
    ) -> None:
        if trial_id is None:
            self.on_study.abs_ns(Namespace(namespace))[key] = value
        else:
            md = self.on_trials.setdefault(trial_id, Metadata())
            md.abs_ns(Namespace(namespace))[key] = value

    def empty(self) -> bool:
        return not self.on_study._store and not self.on_trials

    def to_proto(self) -> dict:
        return {
            "on_study": self.on_study.to_proto(),
            "on_trials": {str(tid): md.to_proto() for tid, md in self.on_trials.items()},
        }

    @classmethod
    def from_proto(cls, proto: Optional[dict]) -> "MetadataDelta":
        proto = proto or {}
        return cls(
            on_study=Metadata.from_proto(proto.get("on_study")),
            on_trials={
                int(tid): Metadata.from_proto(md)
                for tid, md in (proto.get("on_trials") or {}).items()
            },
        )
