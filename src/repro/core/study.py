"""Study / Trial / Measurement primitives (paper §3, §4.1).

A Study is a single optimization run over a feasible space; a Trial is the
container for a suggestion x (and, once COMPLETED, its objective value(s)).
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

from repro.core.metadata import Metadata
from repro.core.search_space import ParameterDict, ParameterValue


class TrialState(enum.Enum):
    REQUESTED = "REQUESTED"
    ACTIVE = "ACTIVE"          # suggested, not yet evaluated (paper §4.1)
    STOPPING = "STOPPING"      # early-stop signal sent, awaiting final report
    COMPLETED = "SUCCEEDED"    # evaluation finished (proto name: SUCCEEDED)
    INFEASIBLE = "INFEASIBLE"  # persistent failure; do not retry (paper §2)

    @property
    def is_terminal(self) -> bool:
        return self in (TrialState.COMPLETED, TrialState.INFEASIBLE)


class StudyState(enum.Enum):
    ACTIVE = "ACTIVE"
    INACTIVE = "INACTIVE"
    COMPLETED = "COMPLETED"


@dataclasses.dataclass
class Metric:
    """A single metric observation; std captures known observation noise."""

    value: float
    std: Optional[float] = None

    def __post_init__(self):
        self.value = float(self.value)

    def to_proto(self) -> dict:
        p = {"value": self.value}
        if self.std is not None:
            p["std"] = self.std
        return p

    @classmethod
    def from_proto(cls, p) -> "Metric":
        if isinstance(p, dict):
            return cls(value=p["value"], std=p.get("std"))
        return cls(value=float(p))


class MetricDict(dict):
    """dict[str, Metric] accepting raw floats on assignment."""

    def __setitem__(self, key: str, value):
        if not isinstance(value, Metric):
            value = Metric(value)
        super().__setitem__(key, value)

    def get_value(self, key: str, default: Optional[float] = None) -> Optional[float]:
        if key in self:
            return self[key].value
        return default


@dataclasses.dataclass
class Measurement:
    """Metrics observed at one evaluation point (possibly intermediate)."""

    metrics: MetricDict = dataclasses.field(default_factory=MetricDict)
    elapsed_secs: float = 0.0
    steps: int = 0

    def __post_init__(self):
        if not isinstance(self.metrics, MetricDict):
            md = MetricDict()
            for k, v in dict(self.metrics).items():
                md[k] = v
            self.metrics = md

    def to_proto(self) -> dict:
        return {
            "elapsed_duration": self.elapsed_secs,
            "step_count": int(self.steps),
            "metrics": [
                {"metric_id": k, **v.to_proto()} for k, v in sorted(self.metrics.items())
            ],
        }

    @classmethod
    def from_proto(cls, p: Optional[dict]) -> Optional["Measurement"]:
        if p is None:
            return None
        m = cls(elapsed_secs=p.get("elapsed_duration", 0.0), steps=p.get("step_count", 0))
        for item in p.get("metrics", ()):
            m.metrics[item["metric_id"]] = Metric(item["value"], item.get("std"))
        return m


@dataclasses.dataclass
class Trial:
    """Container for x (parameters) and f(x) (measurements). Paper §4.1."""

    id: int = 0
    parameters: ParameterDict = dataclasses.field(default_factory=ParameterDict)
    state: TrialState = TrialState.ACTIVE
    measurements: List[Measurement] = dataclasses.field(default_factory=list)
    final_measurement: Optional[Measurement] = None
    metadata: Metadata = dataclasses.field(default_factory=Metadata)
    client_id: Optional[str] = None  # worker binding (paper §5)
    infeasibility_reason: Optional[str] = None
    creation_time: float = dataclasses.field(default_factory=time.time)
    completion_time: Optional[float] = None
    study_name: Optional[str] = None

    def __post_init__(self):
        if not isinstance(self.parameters, ParameterDict):
            self.parameters = ParameterDict.from_dict(dict(self.parameters))

    # -- state transitions ------------------------------------------------------
    @property
    def is_completed(self) -> bool:
        return self.state.is_terminal

    def complete(
        self,
        measurement: Optional[Measurement] = None,
        *,
        infeasibility_reason: Optional[str] = None,
    ) -> "Trial":
        if infeasibility_reason is not None:
            self.state = TrialState.INFEASIBLE
            self.infeasibility_reason = infeasibility_reason
        else:
            if measurement is None:
                raise ValueError("COMPLETED trials require a final measurement")
            self.final_measurement = measurement
            self.state = TrialState.COMPLETED
        self.completion_time = time.time()
        return self

    def add_measurement(self, measurement: Measurement) -> None:
        self.measurements.append(measurement)

    # -- convenience --------------------------------------------------------------
    def final_objective(self, metric_name: str) -> Optional[float]:
        if self.final_measurement is None:
            return None
        return self.final_measurement.metrics.get_value(metric_name)

    def to_suggestion(self) -> "TrialSuggestion":
        return TrialSuggestion(parameters=self.parameters, metadata=self.metadata)

    # -- wire (Vertex Vizier Trial proto field names) -------------------------------
    def to_proto(self) -> dict:
        p = {
            "id": str(self.id),
            "state": self.state.value,
            "parameters": [
                {"parameter_id": k, "value": v.to_proto()}
                for k, v in sorted(self.parameters.items())
            ],
            "measurements": [m.to_proto() for m in self.measurements],
            "metadata": self.metadata.to_proto(),
            "start_time": self.creation_time,
        }
        if self.final_measurement is not None:
            p["final_measurement"] = self.final_measurement.to_proto()
        if self.client_id is not None:
            p["client_id"] = self.client_id
        if self.infeasibility_reason is not None:
            p["infeasible_reason"] = self.infeasibility_reason
        if self.completion_time is not None:
            p["end_time"] = self.completion_time
        if self.study_name is not None:
            p["name"] = f"{self.study_name}/trials/{self.id}"
        return p

    @classmethod
    def from_proto(cls, p: dict) -> "Trial":
        params = ParameterDict()
        for item in p.get("parameters", ()):
            params[item["parameter_id"]] = ParameterValue.from_proto(item["value"])
        t = cls(
            id=int(p.get("id", 0)),
            parameters=params,
            state=TrialState(p.get("state", "ACTIVE")),
            measurements=[Measurement.from_proto(m) for m in p.get("measurements", ())],
            final_measurement=Measurement.from_proto(p.get("final_measurement")),
            metadata=Metadata.from_proto(p.get("metadata")),
            client_id=p.get("client_id"),
            infeasibility_reason=p.get("infeasible_reason"),
            creation_time=p.get("start_time", 0.0),
            completion_time=p.get("end_time"),
        )
        name = p.get("name")
        if name and "/trials/" in name:
            t.study_name = name.rsplit("/trials/", 1)[0]
        return t


@dataclasses.dataclass
class TrialSuggestion:
    """A suggested x, not yet registered as a Trial (Designer output)."""

    parameters: ParameterDict = dataclasses.field(default_factory=ParameterDict)
    metadata: Metadata = dataclasses.field(default_factory=Metadata)

    def __post_init__(self):
        if not isinstance(self.parameters, ParameterDict):
            self.parameters = ParameterDict.from_dict(dict(self.parameters))

    def to_trial(self, uid: int) -> Trial:
        return Trial(id=uid, parameters=self.parameters, metadata=self.metadata,
                     state=TrialState.ACTIVE)


@dataclasses.dataclass
class CompletedTrials:
    """Batch of newly completed trials handed to Designer.update (paper D.4)."""

    trials: List[Trial] = dataclasses.field(default_factory=list)

    def __len__(self) -> int:
        return len(self.trials)


@dataclasses.dataclass
class Study:
    """All data pertaining to one optimization loop (paper §3)."""

    name: str = ""           # resource name: owners/{owner}/studies/{study_id}
    display_name: str = ""
    state: StudyState = StudyState.ACTIVE
    # StudyConfig is attached by the service layer; typed as Any to avoid an
    # import cycle (study_config imports search_space, not study).
    study_config: Optional[object] = None
    creation_time: float = dataclasses.field(default_factory=time.time)

    def to_proto(self) -> dict:
        p = {
            "name": self.name,
            "display_name": self.display_name,
            "state": self.state.value,
            "create_time": self.creation_time,
        }
        if self.study_config is not None:
            p["study_spec"] = self.study_config.to_proto()
        return p

    @classmethod
    def from_proto(cls, p: dict) -> "Study":
        from repro.core.study_config import StudyConfig  # local: avoid cycle

        cfg = StudyConfig.from_proto(p["study_spec"]) if "study_spec" in p else None
        return cls(
            name=p.get("name", ""),
            display_name=p.get("display_name", ""),
            state=StudyState(p.get("state", "ACTIVE")),
            study_config=cfg,
            creation_time=p.get("create_time", 0.0),
        )
