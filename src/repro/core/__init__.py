"""PyVizier core primitives (the paper's §4)."""

from repro.core.metadata import Metadata, MetadataDelta, Namespace
from repro.core.search_space import (
    ExternalType,
    ParameterConfig,
    ParameterDict,
    ParameterType,
    ParameterValue,
    ScaleType,
    SearchSpace,
    SearchSpaceSelector,
    lehmer_decode,
    subset_decode,
)
from repro.core.study import (
    CompletedTrials,
    Measurement,
    Metric,
    MetricDict,
    Study,
    StudyState,
    Trial,
    TrialState,
    TrialSuggestion,
)
from repro.core.study_config import (
    AutomatedStoppingConfig,
    AutomatedStoppingType,
    MetricInformation,
    MetricsConfig,
    ObjectiveMetricGoal,
    ObservationNoise,
    ProblemStatement,
    StudyConfig,
)
from repro.core import converters, early_stopping, pareto

__all__ = [
    "Metadata", "MetadataDelta", "Namespace",
    "ExternalType", "ParameterConfig", "ParameterDict", "ParameterType",
    "ParameterValue", "ScaleType", "SearchSpace", "SearchSpaceSelector",
    "lehmer_decode", "subset_decode",
    "CompletedTrials", "Measurement", "Metric", "MetricDict", "Study",
    "StudyState", "Trial", "TrialState", "TrialSuggestion",
    "AutomatedStoppingConfig", "AutomatedStoppingType", "MetricInformation",
    "MetricsConfig", "ObjectiveMetricGoal", "ObservationNoise",
    "ProblemStatement", "StudyConfig",
    "converters", "early_stopping", "pareto",
]
