"""StudyConfig / MetricInformation / stopping & noise configs (paper §4.1, B.1, B.2).

PyVizier StudyConfig <-> StudySpec proto (paper Table 2).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Optional, Sequence

from repro.core.metadata import Metadata
from repro.core.search_space import SearchSpace, ParameterDict
from repro.core.study import Measurement, Trial


class ObjectiveMetricGoal(enum.Enum):
    MAXIMIZE = "MAXIMIZE"
    MINIMIZE = "MINIMIZE"


class ObservationNoise(enum.Enum):
    """User hint about evaluation reproducibility (paper Appendix B.2)."""

    UNSPECIFIED = "OBSERVATION_NOISE_UNSPECIFIED"
    LOW = "LOW"    # never repeat the same parameters
    HIGH = "HIGH"  # re-evaluation of (near-)identical parameters is worthwhile


@dataclasses.dataclass
class MetricInformation:
    """Information about one metric f_i to optimize (paper §4.1)."""

    name: str
    goal: ObjectiveMetricGoal
    min_value: Optional[float] = None
    max_value: Optional[float] = None
    safety_threshold: Optional[float] = None  # constraint-style metric hook

    def __post_init__(self):
        if isinstance(self.goal, str):
            self.goal = ObjectiveMetricGoal(self.goal)

    def flip_sign_for_min(self, value: float) -> float:
        """Maps value so that larger-is-better regardless of goal."""
        return value if self.goal == ObjectiveMetricGoal.MAXIMIZE else -value

    def to_proto(self) -> dict:
        p = {"metric_id": self.name, "goal": self.goal.value}
        if self.min_value is not None:
            p["min_value"] = self.min_value
        if self.max_value is not None:
            p["max_value"] = self.max_value
        if self.safety_threshold is not None:
            p["safety_threshold"] = self.safety_threshold
        return p

    @classmethod
    def from_proto(cls, p: dict) -> "MetricInformation":
        return cls(
            name=p["metric_id"],
            goal=ObjectiveMetricGoal(p["goal"]),
            min_value=p.get("min_value"),
            max_value=p.get("max_value"),
            safety_threshold=p.get("safety_threshold"),
        )


class MetricsConfig(list):
    """List of MetricInformation with a convenient .add() (paper Code Block 1)."""

    def add(
        self,
        name: str,
        goal: str | ObjectiveMetricGoal = ObjectiveMetricGoal.MAXIMIZE,
        *,
        min_value: Optional[float] = None,
        max_value: Optional[float] = None,
        safety_threshold: Optional[float] = None,
    ) -> MetricInformation:
        mi = MetricInformation(
            name=name,
            goal=ObjectiveMetricGoal(goal) if isinstance(goal, str) else goal,
            min_value=min_value,
            max_value=max_value,
            safety_threshold=safety_threshold,
        )
        return self.add_metric(mi)

    def add_metric(self, mi: MetricInformation) -> MetricInformation:
        """Appends with the duplicate-id check — the ONLY sanctioned way to
        grow the config (``from_proto`` routes through here too, so a wire
        blob carrying duplicate metric ids fails loudly instead of
        roundtripping a silently ambiguous study)."""
        if any(m.name == mi.name for m in self):
            raise ValueError(f"duplicate metric {mi.name!r}")
        self.append(mi)
        return mi

    def of_interest(self) -> List[MetricInformation]:
        return list(self)

    @property
    def is_multi_objective(self) -> bool:
        return len(self) > 1


class AutomatedStoppingType(enum.Enum):
    NONE = "NONE"
    DECAY_CURVE = "DECAY_CURVE"  # GP regressor over learning curves (B.1)
    MEDIAN = "MEDIAN"            # median rule over running averages (B.1)


@dataclasses.dataclass
class AutomatedStoppingConfig:
    type: AutomatedStoppingType = AutomatedStoppingType.NONE
    # MEDIAN: minimum number of completed trials before the rule activates.
    min_completed_trials: int = 5
    # DECAY_CURVE: stop if P(exceed best) < threshold.
    probability_threshold: float = 0.05
    use_elapsed_duration: bool = False

    @classmethod
    def decay_curve_stopping_config(cls, probability_threshold: float = 0.05):
        return cls(AutomatedStoppingType.DECAY_CURVE,
                   probability_threshold=probability_threshold)

    @classmethod
    def median_automated_stopping_config(cls, min_completed_trials: int = 5):
        return cls(AutomatedStoppingType.MEDIAN,
                   min_completed_trials=min_completed_trials)

    def to_proto(self) -> dict:
        return {
            "type": self.type.value,
            "min_completed_trials": self.min_completed_trials,
            "probability_threshold": self.probability_threshold,
            "use_elapsed_duration": self.use_elapsed_duration,
        }

    @classmethod
    def from_proto(cls, p: Optional[dict]) -> "AutomatedStoppingConfig":
        if not p:
            return cls()
        return cls(
            type=AutomatedStoppingType(p.get("type", "NONE")),
            min_completed_trials=p.get("min_completed_trials", 5),
            probability_threshold=p.get("probability_threshold", 0.05),
            use_elapsed_duration=p.get("use_elapsed_duration", False),
        )


def _validate_prior_study_names(names: Sequence[str]) -> List[str]:
    """Normalizes a prior-study list: non-empty strings, deduplicated with
    the first occurrence's position kept (stacking order is significant)."""
    out: List[str] = []
    for n in names or ():
        if not isinstance(n, str) or not n:
            raise ValueError(
                f"prior_study_names entries must be non-empty study resource "
                f"names, got {n!r}")
        if n not in out:
            out.append(n)
    return out


@dataclasses.dataclass
class StudyConfig:
    """PyVizier StudyConfig == StudySpec proto + SearchSpace (paper Table 2)."""

    search_space: SearchSpace = dataclasses.field(default_factory=SearchSpace)
    metrics: MetricsConfig = dataclasses.field(default_factory=MetricsConfig)
    algorithm: str = "DEFAULT"
    observation_noise: ObservationNoise = ObservationNoise.UNSPECIFIED
    automated_stopping: AutomatedStoppingConfig = dataclasses.field(
        default_factory=AutomatedStoppingConfig
    )
    metadata: Metadata = dataclasses.field(default_factory=Metadata)
    # Resource names of prior studies whose completed trials seed transfer
    # learning (stacked residual GP; earlier names are deeper in the stack).
    prior_study_names: List[str] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prior_study_names = _validate_prior_study_names(self.prior_study_names)

    # -- convenience ----------------------------------------------------------
    @property
    def prior_studies(self) -> List[str]:
        """Alias for ``prior_study_names`` (the user-facing transfer API)."""
        return self.prior_study_names

    @prior_studies.setter
    def prior_studies(self, names: Sequence[str]) -> None:
        self.prior_study_names = _validate_prior_study_names(names)

    @property
    def metric_information(self) -> MetricsConfig:
        return self.metrics

    @property
    def is_multi_objective(self) -> bool:
        return self.metrics.is_multi_objective

    def single_objective_metric(self) -> MetricInformation:
        if len(self.metrics) != 1:
            raise ValueError(
                f"expected a single objective; study has {len(self.metrics)} metrics"
            )
        return self.metrics[0]

    def validate_trial(self, trial: Trial) -> None:
        self.search_space.validate_parameters(trial.parameters)

    def objective_values(self, trial: Trial) -> Optional[List[float]]:
        """Larger-is-better objective vector, or None if not comparable.

        Non-finite metric values (NaN/±inf) make the whole trial
        incomparable — same policy as ``early_stopping._curve``. A NaN that
        leaked through here used to poison GP labels in ``trials_to_xy``
        and, worse, become un-dominatable in ``pareto_frontier_indices``
        (every NaN comparison is False), so ``ListOptimalTrials`` served it
        to users as an "optimal" trial.
        """
        if trial.final_measurement is None:
            return None
        out = []
        for mi in self.metrics:
            v = trial.final_measurement.metrics.get_value(mi.name)
            if v is None or not math.isfinite(v):
                return None
            out.append(mi.flip_sign_for_min(v))
        return out

    # -- wire (StudySpec proto field names) --------------------------------------
    def to_proto(self) -> dict:
        p = {
            "parameters": self.search_space.to_proto(),
            "metrics": [m.to_proto() for m in self.metrics],
            "algorithm": self.algorithm,
            "observation_noise": self.observation_noise.value,
            "metadata": self.metadata.to_proto(),
        }
        if self.automated_stopping.type != AutomatedStoppingType.NONE:
            p["automated_stopping_spec"] = self.automated_stopping.to_proto()
        if self.prior_study_names:
            p["prior_study_names"] = list(self.prior_study_names)
        return p

    @classmethod
    def from_proto(cls, p: dict) -> "StudyConfig":
        cfg = cls(
            search_space=SearchSpace.from_proto(p.get("parameters")),
            algorithm=p.get("algorithm", "DEFAULT"),
            observation_noise=ObservationNoise(
                p.get("observation_noise", "OBSERVATION_NOISE_UNSPECIFIED")
            ),
            automated_stopping=AutomatedStoppingConfig.from_proto(
                p.get("automated_stopping_spec")
            ),
            metadata=Metadata.from_proto(p.get("metadata")),
            prior_study_names=list(p.get("prior_study_names", ())),
        )
        for mp in p.get("metrics", ()):
            # through add_metric, NOT a bare append: duplicate metric ids in
            # a wire blob used to roundtrip silently and leave every
            # objective lookup ambiguous
            cfg.metrics.add_metric(MetricInformation.from_proto(mp))
        return cfg


@dataclasses.dataclass
class ProblemStatement:
    """Algorithm-facing view of a study (search space + metrics only)."""

    search_space: SearchSpace
    metrics: MetricsConfig
    observation_noise: ObservationNoise = ObservationNoise.UNSPECIFIED
    metadata: Metadata = dataclasses.field(default_factory=Metadata)

    @classmethod
    def from_study_config(cls, cfg: StudyConfig) -> "ProblemStatement":
        return cls(
            search_space=cfg.search_space,
            metrics=cfg.metrics,
            observation_noise=cfg.observation_noise,
            metadata=cfg.metadata,
        )

    @property
    def is_multi_objective(self) -> bool:
        return self.metrics.is_multi_objective
