"""Multi-objective (Pareto) utilities in JAX (paper §4.1 multi-objective).

All functions take objective matrices ``Y`` of shape (n, k) in
**larger-is-better** convention (StudyConfig.objective_values already flips
MINIMIZE metrics).
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def pareto_dominated_mask(y: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask of points dominated by some other point.

    A point i is dominated iff there exists j with Y[j] >= Y[i] elementwise and
    Y[j] > Y[i] somewhere. O(n^2 k) vectorized — fine for typical study sizes.
    """
    ge = jnp.all(y[:, None, :] >= y[None, :, :], axis=-1)  # ge[j, i]: j >= i
    gt = jnp.any(y[:, None, :] > y[None, :, :], axis=-1)
    dominates = ge & gt  # dominates[j, i]: j dominates i
    return jnp.any(dominates, axis=0)


def pareto_frontier_indices(y) -> List[int]:
    """Indices of non-dominated points (f64 numpy: denormal-exact).

    Rows carrying ANY non-finite value (NaN/±inf) are treated as
    incomparable and never appear on the frontier: every comparison against
    NaN is False, so a NaN row used to be un-dominatable — it survived every
    domination test and was served to users as an "optimal" trial. Upstream
    (``StudyConfig.objective_values``) already refuses to score such trials;
    this is the defense-in-depth for callers that build Y themselves.
    """
    y = np.asarray(y, dtype=np.float64)
    if y.ndim != 2:
        raise ValueError(f"expected (n, k) objectives, got shape {y.shape}")
    if y.shape[0] == 0:
        return []
    finite = np.all(np.isfinite(y), axis=1)
    ge = np.all(y[:, None, :] >= y[None, :, :], axis=-1)
    gt = np.any(y[:, None, :] > y[None, :, :], axis=-1)
    dominated = np.any((ge & gt) & finite[:, None], axis=0)
    return [i for i in range(y.shape[0]) if finite[i] and not dominated[i]]


def default_reference_point(y, *, margin: float = 0.1) -> np.ndarray:
    """Reference point for hypervolume from observed objectives: the
    per-metric minimum pushed down by ``margin`` of the per-metric span (so
    frontier-extreme points still dominate a box of positive volume). Shared
    by the GP-bandit's hypervolume-scalarized acquisition and the
    multi-metric benchmark/client reporting."""
    y = np.asarray(y, dtype=np.float64)
    if y.ndim != 2 or y.shape[0] == 0:
        raise ValueError(f"expected non-empty (n, k) objectives, got {y.shape}")
    lo = np.min(y, axis=0)
    span = np.maximum(np.max(y, axis=0) - lo, 1e-9)
    return lo - margin * span


@jax.jit
def _hv_mc(y: jnp.ndarray, ref: jnp.ndarray, key: jax.Array, upper: jnp.ndarray) -> jnp.ndarray:
    n_samples = 16384
    k = y.shape[1]
    u = jax.random.uniform(key, (n_samples, k))
    pts = ref + u * (upper - ref)
    dominated = jnp.any(jnp.all(y[None, :, :] >= pts[:, None, :], axis=-1), axis=1)
    vol_box = jnp.prod(upper - ref)
    return jnp.mean(dominated.astype(jnp.float32)) * vol_box


def hypervolume(y, reference_point, *, seed: int = 0) -> float:
    """Hypervolume dominated by Y w.r.t. a reference point.

    Exact for k<=2 (sweep); Monte-Carlo estimate for k>=3 (16384 samples).
    """
    y = np.asarray(y, dtype=np.float32)
    ref = np.asarray(reference_point, dtype=np.float32)
    if y.size == 0:
        return 0.0
    y = y[np.all(y > ref, axis=1)]
    if y.size == 0:
        return 0.0
    k = y.shape[1]
    if k == 1:
        return float(np.max(y[:, 0]) - ref[0])
    if k == 2:
        idx = np.argsort(-y[:, 0])
        ys = y[idx]
        hv, prev_y1 = 0.0, ref[1]
        for x0, x1 in ys:
            if x1 > prev_y1:
                hv += (x0 - ref[0]) * (x1 - prev_y1)
                prev_y1 = x1
        return float(hv)
    upper = np.max(y, axis=0)
    return float(
        _hv_mc(jnp.asarray(y), jnp.asarray(ref), jax.random.PRNGKey(seed), jnp.asarray(upper))
    )


def crowding_distance(y) -> np.ndarray:
    """NSGA-II crowding distance (np; used inside NSGA2Designer)."""
    y = np.asarray(y, dtype=np.float64)
    n, k = y.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for m in range(k):
        order = np.argsort(y[:, m])
        dist[order[0]] = dist[order[-1]] = np.inf
        span = y[order[-1], m] - y[order[0], m]
        if span <= 0:
            continue
        dist[order[1:-1]] += (y[order[2:], m] - y[order[:-2], m]) / span
    return dist


def non_dominated_sort(y) -> List[np.ndarray]:
    """Fast non-dominated sort: list of fronts (index arrays), best first."""
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    ge = np.all(y[:, None, :] >= y[None, :, :], axis=-1)
    gt = np.any(y[:, None, :] > y[None, :, :], axis=-1)
    dominates = ge & gt  # [j, i]: j dominates i
    dom_count = dominates.sum(axis=0).astype(np.int64)  # how many dominate i
    fronts: List[np.ndarray] = []
    remaining = np.ones(n, dtype=bool)
    while remaining.any():
        front = np.where(remaining & (dom_count == 0))[0]
        if front.size == 0:  # numerical degenerate (duplicates): take the rest
            front = np.where(remaining)[0]
        fronts.append(front)
        remaining[front] = False
        # removing the front decrements domination counts of its dominatees
        dom_count = dom_count - dominates[front].sum(axis=0)
    return fronts
