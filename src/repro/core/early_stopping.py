"""Automated/early stopping rules (paper Appendix B.1).

Two modes, selected via StudyConfig.automated_stopping:

* **Median automated stopping** — a pending trial is stopped if its best
  objective so far is strictly below the median *performance* of all completed
  trials up to the pending trial's last reported step, where performance is
  the running average of reported objective values.

* **Decay-curve automated stopping** — a Gaussian-process regressor over
  (step, value) learning curves predicts the trial's final objective; the
  trial is stopped if the probability of exceeding the best completed value is
  below ``probability_threshold``.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.study import Trial, TrialState
from repro.core.study_config import (
    AutomatedStoppingType,
    ObjectiveMetricGoal,
    StudyConfig,
)


def _curve(trial: Trial, metric: str, sign: float) -> List[tuple]:
    """[(step, larger_is_better_value), ...] from intermediate measurements."""
    out = []
    for m in trial.measurements:
        v = m.metrics.get_value(metric)
        if v is not None and math.isfinite(v):
            out.append((m.steps, sign * v))
    return out


def _running_average(values: Sequence[float]) -> List[float]:
    out, acc = [], 0.0
    for i, v in enumerate(values):
        acc += v
        out.append(acc / (i + 1))
    return out


def median_rule_should_stop(
    pending: Trial, completed: List[Trial], config: StudyConfig
) -> bool:
    mi = config.single_objective_metric()
    sign = 1.0 if mi.goal == ObjectiveMetricGoal.MAXIMIZE else -1.0
    pc = _curve(pending, mi.name, sign)
    if not pc:
        return False
    last_step = pc[-1][0]
    best_pending = max(v for _, v in pc)
    references = []
    for t in completed:
        cc = _curve(t, mi.name, sign)
        upto = [v for s, v in cc if s <= last_step]
        if upto:
            references.append(_running_average(upto)[-1])
    if len(references) < config.automated_stopping.min_completed_trials:
        return False
    return best_pending < float(np.median(references))


# ---------------------------------------------------------------------------
# Decay-curve rule: GP over (log-step) -> value, per-study, with a
# monotone-trend prior captured by fitting residuals of a power-law mean.
# ---------------------------------------------------------------------------


def _fit_power_law(steps: np.ndarray, values: np.ndarray):
    """Least-squares fit of v ~ a - b * s^(-c) with c fixed grid-searched."""
    best = None
    s = np.maximum(steps.astype(np.float64), 1.0)
    for c in (0.3, 0.5, 0.7, 1.0):
        X = np.stack([np.ones_like(s), -(s ** (-c))], axis=1)
        coef, *_ = np.linalg.lstsq(X, values, rcond=None)
        resid = values - X @ coef
        sse = float(np.sum(resid**2))
        if best is None or sse < best[0]:
            best = (sse, coef, c)
    return best[1], best[2]


def _gp_posterior(x: np.ndarray, y: np.ndarray, x_star: float, noise: float = 1e-3):
    """Tiny 1-D RBF GP posterior at x_star (mean, std)."""
    if len(x) == 1:
        return float(y[0]), 1.0
    ell = max((x.max() - x.min()) / 2.0, 1e-3)
    amp = max(float(np.var(y)), 1e-6)

    def k(a, b):
        d = (a[:, None] - b[None, :]) / ell
        return amp * np.exp(-0.5 * d * d)

    K = k(x, x) + noise * amp * np.eye(len(x))
    ks = k(np.array([x_star]), x)[0]
    try:
        L = np.linalg.cholesky(K)
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, y))
        v = np.linalg.solve(L, ks)
        mean = float(ks @ alpha)
        var = float(amp - v @ v)
    except np.linalg.LinAlgError:
        return float(np.mean(y)), float(np.std(y) + 1e-6)
    return mean, math.sqrt(max(var, 1e-12))


def decay_curve_should_stop(
    pending: Trial, completed: List[Trial], config: StudyConfig
) -> bool:
    mi = config.single_objective_metric()
    sign = 1.0 if mi.goal == ObjectiveMetricGoal.MAXIMIZE else -1.0
    pc = _curve(pending, mi.name, sign)
    if len(pc) < 3:
        return False  # not enough curve to extrapolate
    finals = [
        sign * t.final_measurement.metrics.get_value(mi.name)
        for t in completed
        if t.state == TrialState.COMPLETED
        and t.final_measurement is not None
        and t.final_measurement.metrics.get_value(mi.name) is not None
    ]
    if not finals:
        return False
    best_final = max(finals)
    steps = np.array([s for s, _ in pc], dtype=np.float64)
    values = np.array([v for _, v in pc], dtype=np.float64)
    horizon = max(float(max(t_steps(t) for t in completed) or steps[-1]), steps[-1])
    # power-law trend + GP on residuals in log-step space
    coef, c = _fit_power_law(steps, values)
    trend = lambda s: coef[0] - coef[1] * np.maximum(s, 1.0) ** (-c)
    resid = values - trend(steps)
    lx = np.log(np.maximum(steps, 1.0))
    mean_r, std_r = _gp_posterior(lx, resid, math.log(max(horizon, 1.0)))
    pred_mean = float(trend(np.array([horizon]))[0]) + mean_r
    pred_std = max(std_r, 1e-6)
    # P(final > best_final)
    z = (pred_mean - best_final) / pred_std
    p_exceed = 0.5 * math.erfc(-z / math.sqrt(2.0))
    return p_exceed < config.automated_stopping.probability_threshold


def t_steps(trial: Trial) -> int:
    return max((m.steps for m in trial.measurements), default=0)


def should_stop(pending: Trial, all_trials: List[Trial], config: StudyConfig) -> bool:
    """Dispatch on StudyConfig.automated_stopping; False if disabled."""
    kind = config.automated_stopping.type
    if kind == AutomatedStoppingType.NONE or config.is_multi_objective:
        return False
    completed = [t for t in all_trials if t.state == TrialState.COMPLETED]
    if kind == AutomatedStoppingType.MEDIAN:
        return median_rule_should_stop(pending, completed, config)
    if kind == AutomatedStoppingType.DECAY_CURVE:
        return decay_curve_should_stop(pending, completed, config)
    return False
