"""Converter objects matching the paper's Table 2 naming.

PyVizier classes in this codebase carry their own ``to_proto``/``from_proto``;
these converter classes exist so user code written against the paper's API
(``TrialConverter.to_proto(trial)`` etc.) works verbatim.
"""

from __future__ import annotations

from typing import List

from repro.core.metadata import Metadata
from repro.core.search_space import ParameterConfig, ParameterValue
from repro.core.study import Measurement, Study, Trial
from repro.core.study_config import MetricInformation, StudyConfig


class _Converter:
    _cls = None

    @classmethod
    def to_proto(cls, obj):
        return obj.to_proto()

    @classmethod
    def from_proto(cls, proto):
        return cls._cls.from_proto(proto)


class TrialConverter(_Converter):
    _cls = Trial

    @classmethod
    def to_protos(cls, trials: List[Trial]) -> list:
        return [t.to_proto() for t in trials]

    @classmethod
    def from_protos(cls, protos: list) -> List[Trial]:
        return [Trial.from_proto(p) for p in protos]


class ParameterConfigConverter(_Converter):
    _cls = ParameterConfig


class ParameterValueConverter(_Converter):
    _cls = ParameterValue


class MeasurementConverter(_Converter):
    _cls = Measurement


class MetadataConverter(_Converter):
    _cls = Metadata


class StudyConfigConverter(_Converter):
    _cls = StudyConfig


class StudyConverter(_Converter):
    _cls = Study


class MetricInformationConverter(_Converter):
    _cls = MetricInformation
