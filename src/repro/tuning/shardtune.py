"""shardtune — Vizier optimizes the framework itself (beyond-paper feature).

The blackbox objective is the dry-run roofline: given an (arch × shape) cell,
a trial assigns {remat policy, MoE chunk count, attention chunk sizes,
microbatches, SP on/off} → lower + compile → optimistic step time
max(compute, memory, collective) from the loop-corrected HLO analysis,
penalized when the per-device footprint exceeds HBM. Because compiles are
expensive and the service is fault-tolerant, trials run under the normal
client loop — exactly the paper's "expensive, minutes-per-eval" regime.

This module is both a real tool (drives §Perf hillclimbing) and the
demonstration that the reproduced service closes the loop on its own
framework.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Optional

from repro.core.search_space import ScaleType
from repro.core.study_config import StudyConfig
from repro.launch.mesh import HBM_BYTES

log = logging.getLogger(__name__)


def shardtune_study_config(*, include_microbatches: bool = True,
                           algorithm: str = "GP_UCB") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_categorical_param("remat", ["none", "block", "full"],
                               default_value="block")
    root.add_discrete_param("moe_chunks", [1, 2, 4, 8, 16, 32])
    root.add_discrete_param("attn_q_chunk", [256, 512, 1024, 2048],
                            scale_type=None)
    root.add_discrete_param("attn_kv_chunk", [256, 512, 1024, 2048])
    if include_microbatches:
        root.add_discrete_param("num_microbatches", [1, 2, 4, 8])
    cfg.metrics.add("step_time_s", "MINIMIZE")
    cfg.algorithm = algorithm
    cfg.observation_noise = cfg.observation_noise.LOW
    return cfg


def overrides_from_parameters(params: Dict) -> Dict:
    """Vizier parameters -> ArchConfig dataclasses.replace overrides."""
    out = {}
    if "remat" in params:
        out["remat"] = str(params["remat"])
    for key in ("moe_chunks",):
        if key in params:
            # moe_chunks lives inside MoEConfig; handled by evaluate_cell
            out[key] = int(params[key])
    for key in ("attn_q_chunk", "attn_kv_chunk", "num_microbatches"):
        if key in params:
            out[key] = int(params[key])
    return out


def evaluate_cell(arch_id: str, shape_name: str, params: Dict,
                  *, multi_pod: bool = False,
                  hbm_penalty_weight: float = 10.0) -> Dict[str, float]:
    """Lower+compile one cell with trial overrides; returns metrics.

    NOTE: must run in a fresh process with 512 virtual devices (the dryrun
    entrypoint handles that); in-process use is for tests with small meshes.
    """
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.launch.dryrun import lower_cell

    ov = overrides_from_parameters(params)
    moe_chunks = ov.pop("moe_chunks", None)
    cfg = get_arch(arch_id)
    if moe_chunks is not None and cfg.moe is not None:
        ov["moe"] = dc.replace(cfg.moe, moe_chunks=moe_chunks)
    record = lower_cell(arch_id, shape_name, multi_pod=multi_pod, overrides=ov)
    step_time = record["roofline"]["step_time_s"]
    mem = record["memory"]["total_per_device"]
    over = max(0.0, mem - HBM_BYTES) / HBM_BYTES
    return {
        "step_time_s": step_time + hbm_penalty_weight * over,
        "raw_step_time_s": step_time,
        "mem_gb": mem / 1e9,
        "compute_s": record["roofline"]["compute_s"],
        "memory_s": record["roofline"]["memory_s"],
        "collective_s": record["roofline"]["collective_s"],
    }
