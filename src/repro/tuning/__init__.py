"""Vizier <-> trainer integration: tuning workers + shardtune."""

from repro.tuning.worker import TuningTask, TuningWorker, apply_parameters
from repro.tuning.shardtune import (
    evaluate_cell,
    overrides_from_parameters,
    shardtune_study_config,
)

__all__ = [
    "TuningTask", "TuningWorker", "apply_parameters", "evaluate_cell",
    "overrides_from_parameters", "shardtune_study_config",
]
