"""Vizier ⇄ JAX-trainer integration (the paper's technique as a first-class
framework feature).

A TuningWorker is one of N parallel clients (paper §5): it pulls a suggestion,
maps parameters onto TrainConfig/ArchConfig fields, runs real training steps,
streams the learning curve back as intermediate measurements (heartbeats!),
polls early stopping, and reports the final objective. Crash-and-rebind works
end-to-end: a worker restarted with the same client_id resumes its ACTIVE
trial and its training checkpoint.
"""

from __future__ import annotations

import dataclasses
import logging
import math
import os
from typing import Callable, Dict, Optional

from repro.configs.base import ArchConfig
from repro.core.study import TrialState
from repro.models import build_model
from repro.service.client import VizierClient
from repro.train.data import DataConfig
from repro.train.step import TrainConfig
from repro.train.train_loop import LoopConfig, LoopResult, train

log = logging.getLogger(__name__)


@dataclasses.dataclass
class TuningTask:
    arch: ArchConfig
    data: DataConfig
    total_steps: int = 60
    report_every: int = 10
    objective: str = "loss"           # minimized
    checkpoint_root: Optional[str] = None


def apply_parameters(train_config: TrainConfig, params: Dict) -> TrainConfig:
    """Maps Vizier parameters onto TrainConfig fields (by name)."""
    fields = {f.name for f in dataclasses.fields(TrainConfig)}
    updates = {k: v for k, v in params.items() if k in fields}
    return dataclasses.replace(train_config, **updates)


class TuningWorker:
    def __init__(self, target, study_name: str, client_id: str,
                 task: TuningTask):
        self.client = VizierClient(target, study_name, client_id)
        self.task = task
        self.client_id = client_id

    def evaluate_trial(self, trial) -> Optional[float]:
        """Trains with the trial's hyperparameters; returns final loss."""
        task = self.task
        params = trial.parameters.as_dict()
        tc = apply_parameters(
            TrainConfig(total_steps=task.total_steps, warmup_steps=max(
                1, task.total_steps // 10)), params)
        model = build_model(task.arch)
        ckpt_dir = None
        if task.checkpoint_root:
            ckpt_dir = os.path.join(task.checkpoint_root,
                                    f"trial_{trial.id}")

        last: Dict[str, float] = {}

        def report(step: int, metrics: Dict[str, float]) -> bool:
            last.update(metrics)
            if step % task.report_every:
                return False
            if not math.isfinite(metrics["loss"]):
                return True
            self.client.report_intermediate_objective_value(
                {task.objective: metrics["loss"]}, trial_id=trial.id, step=step)
            try:
                return self.client.should_trial_stop(trial.id)
            except Exception:  # noqa: BLE001 — stopping is best-effort
                return False

        result: LoopResult = train(
            model, tc, task.data,
            LoopConfig(total_steps=task.total_steps,
                       checkpoint_every=max(1, task.report_every),
                       checkpoint_dir=ckpt_dir, log_every=10**9),
            report_fn=report)
        if not result.losses or not math.isfinite(result.losses[-1]):
            return None
        return float(result.losses[-1])

    def run(self, max_trials: int = 10**9) -> int:
        """Paper Code Block 1 loop. Returns #trials completed."""
        completed = 0
        while completed < max_trials:
            suggestions = self.client.get_suggestions(count=1)
            if not suggestions:
                break
            for trial in suggestions:
                final = self.evaluate_trial(trial)
                if final is None:
                    self.client.complete_trial(
                        trial_id=trial.id,
                        infeasibility_reason="non-finite loss")
                else:
                    self.client.complete_trial(
                        {self.task.objective: final}, trial_id=trial.id)
                completed += 1
        return completed
