"""Optimizers (pure JAX, optax-style trees): AdamW and Adafactor, LR
schedules, global-norm clipping.

AdamW keeps fp32 m/v (sharded like the params — 2D FSDP×TP — so a 236B model
fits); Adafactor keeps factored second moments (the memory-lean option for
the largest archs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# -- schedules -----------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        progress = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


# -- gradient utilities ------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


# -- AdamW ----------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def state_axes(self, param_axes) -> AdamWState:
        """Optimizer state shards exactly like its parameters."""
        return AdamWState(step="", m=param_axes, v=param_axes)

    def apply(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState, dict]:
        if self.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr = self.schedule(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * gf
            v = self.b2 * v + (1 - self.b2) * gf * gf
            mhat = m / (1 - self.b1**t)
            vhat = v / (1 - self.b2**t)
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v), {
            "grad_norm": gnorm, "lr": lr}


# -- Adafactor (factored second moments) ------------------------------------------------


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any   # row factors (or full v for <2D params)
    vc: Any   # col factors (or None placeholder)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    schedule: Callable
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params) -> AdafactorState:
        def rows(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def cols(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(rows, params),
            vc=jax.tree.map(cols, params),
        )

    def state_axes(self, param_axes) -> AdafactorState:
        from repro.distributed.sharding import parse_axes

        def rows(a):
            ax = parse_axes(a)
            return " ".join(x or "-" for x in ax[:-1]) if len(ax) >= 2 else a

        def cols(a):
            ax = parse_axes(a)
            return " ".join(x or "-" for x in (ax[:-2] + ax[-1:])) if len(ax) >= 2 else "-"

        return AdafactorState(
            step="",
            vr=jax.tree.map(rows, param_axes),
            vc=jax.tree.map(cols, param_axes),
        )

    def apply(self, grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)
        lr = self.schedule(step)

        def upd(g, vr, vc, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] /
                          jnp.sqrt(jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                               self.eps))[..., None])
            else:
                vr = beta * vr + (1 - beta) * g2
                u = gf / jnp.sqrt(vr)
                vc = vc
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            new_p = p.astype(jnp.float32) - lr * u
            if self.weight_decay and p.ndim >= 2:
                new_p = new_p - lr * self.weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        is_l = lambda x: isinstance(x, tuple) and len(x) == 3 and not hasattr(x, "_fields")
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_l)
        new_vr = jax.tree.map(lambda o: o[1], out, is_leaf=is_l)
        new_vc = jax.tree.map(lambda o: o[2], out, is_leaf=is_l)
        return new_params, AdafactorState(step=step, vr=new_vr, vc=new_vc), {
            "grad_norm": global_norm(grads), "lr": lr}


def make_optimizer(name: str, schedule: Callable, **kwargs):
    if name == "adamw":
        return AdamW(schedule=schedule, **kwargs)
    if name == "adafactor":
        return Adafactor(schedule=schedule, **kwargs)
    raise KeyError(name)
