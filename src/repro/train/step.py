"""train_step / serve_step builders — the functions the launcher jits.

``build_train_step`` supports:
  * gradient-accumulation microbatching (scan over batch slices),
  * optional int8 gradient compression with error feedback on the DP
    reduction path,
  * logical-axis sharding constraints threaded via ShardingCtx.

``build_serve_steps`` returns (prefill_step, decode_step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamW, Adafactor, make_optimizer, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    num_microbatches: int = 1
    grad_compression: bool = False

    def make_optimizer(self):
        sched = warmup_cosine(self.peak_lr, self.warmup_steps, self.total_steps)
        if self.optimizer == "adamw":
            return AdamW(schedule=sched, weight_decay=self.weight_decay,
                         clip_norm=self.clip_norm)
        return Adafactor(schedule=sched)


def build_train_step(model: Model, train_config: TrainConfig, *, ctx=None
                     ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ["err_fb"]}.
    """
    optimizer = train_config.make_optimizer()
    n_mb = train_config.num_microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, ctx=ctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # microbatch accumulation: split the global batch along dim 0
        def split(x):
            B = x.shape[0]
            assert B % n_mb == 0, (B, n_mb)
            return x.reshape((n_mb, B // n_mb) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def mb_step(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_mb, acc, grads)
            return (acc, loss_acc + loss / n_mb), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), metrics = jax.lax.scan(mb_step, (zero, 0.0), mbs)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state: Dict[str, Any], batch) -> Tuple[Dict[str, Any], dict]:
        params, opt_state = state["params"], state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        if ctx is not None:
            # constrain grads to the param sharding: the DP reduction lowers
            # to reduce-scatter (each shard only needs its own grads for the
            # optimizer update) instead of a full all-reduce
            grads = jax.tree.map(lambda g, a: ctx.shard(g, a),
                                 grads, model.param_axes())
        if train_config.grad_compression:
            from repro.distributed.compression import compress_with_feedback

            grads, err_fb = compress_with_feedback(grads, state["err_fb"])
        new_params, new_opt, opt_info = optimizer.apply(grads, opt_state, params)
        if ctx is not None:
            axes = model.param_axes()
            new_params = jax.tree.map(
                lambda p, a: ctx.shard(p, a), new_params, axes)
        new_state = dict(state, params=new_params, opt=new_opt)
        if train_config.grad_compression:
            new_state["err_fb"] = err_fb
        out_metrics = {"loss": loss, **metrics, **opt_info}
        return new_state, out_metrics

    return train_step


def init_train_state(model: Model, train_config: TrainConfig, rng) -> Dict[str, Any]:
    params = model.init(rng)
    optimizer = train_config.make_optimizer()
    state = {"params": params, "opt": optimizer.init(params)}
    if train_config.grad_compression:
        from repro.distributed.compression import init_error_feedback

        state["err_fb"] = init_error_feedback(params)
    return state


def train_state_axes(model: Model, train_config: TrainConfig):
    axes = model.param_axes()
    optimizer = train_config.make_optimizer()
    state_axes = {"params": axes, "opt": optimizer.state_axes(axes)}
    if train_config.grad_compression:
        state_axes["err_fb"] = axes
    return state_axes


def build_serve_steps(model: Model, *, ctx=None):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, ctx=ctx)
        return logits

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, ctx=ctx)

    return prefill_step, decode_step
