"""Deterministic, shardable synthetic data pipeline.

Produces token batches from a seeded Markov-ish generator — deterministic in
(seed, step, shard), so every host materializes exactly its shard with no
coordination, restarts resume mid-stream (fault tolerance), and elastic
re-sharding just changes (shard_id, num_shards).

A file-backed TokenFileDataset covers the "real data" path: a flat uint16
token file, memory-mapped, strided by (step, shard).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None


class SyntheticLM:
    """Structured synthetic stream: tokens follow x_{t+1} = (a*x_t + noise) %
    V so models can actually reduce loss on it (used by examples/train_lm)."""

    def __init__(self, config: DataConfig, shard_id: int = 0, num_shards: int = 1):
        assert config.global_batch % num_shards == 0
        self.config = config
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = config.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.config
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard_id]))
        B, S = self.local_batch, cfg.seq_len
        x = rng.integers(0, cfg.vocab_size, size=(B, 1), dtype=np.int64)
        rows = [x]
        a = 6364136223846793005
        for _ in range(S):
            noise = (rng.random(size=(B, 1)) < 0.15) * rng.integers(
                0, cfg.vocab_size, size=(B, 1))
            x = (x * a + 12345 + noise) % cfg.vocab_size
            rows.append(x)
        seq = np.concatenate(rows, axis=1)  # (B, S+1)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFileDataset:
    """Flat binary uint16 token file, deterministic strided access."""

    def __init__(self, config: DataConfig, shard_id: int = 0, num_shards: int = 1):
        assert config.token_file and os.path.exists(config.token_file)
        self.config = config
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = config.global_batch // num_shards
        self.tokens = np.memmap(config.token_file, dtype=np.uint16, mode="r")
        self.n_windows = (len(self.tokens) - 1) // config.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.config
        B, S = self.local_batch, cfg.seq_len
        base = (step * cfg.global_batch + self.shard_id * B) % max(
            self.n_windows - B, 1)
        rows = []
        for i in range(B):
            w = (base + i) % self.n_windows
            rows.append(np.asarray(self.tokens[w * S : w * S + S + 1], dtype=np.int64))
        seq = np.stack(rows) % cfg.vocab_size
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }


def make_dataset(config: DataConfig, shard_id: int = 0, num_shards: int = 1):
    if config.token_file:
        return TokenFileDataset(config, shard_id, num_shards)
    return SyntheticLM(config, shard_id, num_shards)
