"""Fault-tolerant training loop.

Production behaviors, all exercised by tests/examples on CPU:
  * periodic atomic checkpoints + auto-resume from the latest committed step
    (node failure / preemption recovery);
  * SIGTERM/SIGINT handler that checkpoints before exiting (preemption);
  * step-time watchdog: steps slower than ``straggler_factor`` × the running
    median are logged as straggler events (on a real pod this feeds the
    controller that triggers elastic re-meshing, distributed.elastic);
  * optional Vizier reporting hook (tuning/worker.py wires this to the
    service: intermediate measurements + early-stop polling).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.models.model import Model
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, make_dataset
from repro.train.step import TrainConfig, build_train_step, init_train_state

log = logging.getLogger(__name__)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: list
    straggler_events: list
    resumed_from: Optional[int]
    interrupted: bool = False


def train(
    model: Model,
    train_config: TrainConfig,
    data_config: DataConfig,
    loop: LoopConfig,
    *,
    ctx=None,
    mesh=None,
    report_fn: Optional[Callable[[int, Dict[str, float]], bool]] = None,
) -> LoopResult:
    """Runs (or resumes) training. ``report_fn(step, metrics) -> should_stop``
    is the Vizier hook."""
    dataset = make_dataset(data_config)
    step_fn = jax.jit(build_train_step(model, train_config, ctx=ctx))

    state = init_train_state(model, train_config, jax.random.PRNGKey(loop.seed))
    start_step = 0
    resumed_from = None
    if loop.checkpoint_dir:
        latest = ckpt_lib.latest_step(loop.checkpoint_dir)
        if latest is not None:
            state = ckpt_lib.restore_checkpoint(loop.checkpoint_dir, latest, state)
            start_step = latest
            resumed_from = latest
            log.info("resumed from checkpoint step %d", latest)

    interrupted = {"flag": False}

    def _handler(signum, frame):
        interrupted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:  # non-main thread (tests)
            pass

    losses, step_times, stragglers = [], [], []
    step = start_step
    try:
        while step < loop.total_steps:
            t0 = time.monotonic()
            batch = {k: jax.numpy.asarray(v) for k, v in dataset.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            step += 1
            losses.append(loss)
            step_times.append(dt)
            if len(step_times) >= 8:
                med = float(np.median(step_times[-32:]))
                if dt > loop.straggler_factor * med:
                    stragglers.append({"step": step, "time": dt, "median": med})
                    log.warning("straggler step %d: %.3fs vs median %.3fs",
                                step, dt, med)
            if step % loop.log_every == 0:
                log.info("step %d loss %.4f (%.3fs/step)", step, loss, dt)
            should_stop = False
            if report_fn is not None:
                should_stop = bool(report_fn(step, {"loss": loss}))
            if loop.checkpoint_dir and (
                step % loop.checkpoint_every == 0
                or step == loop.total_steps
                or interrupted["flag"]
                or should_stop
            ):
                ckpt_lib.save_checkpoint(loop.checkpoint_dir, step, state)
                ckpt_lib.prune_old(loop.checkpoint_dir, loop.keep_checkpoints)
            if interrupted["flag"]:
                log.warning("preemption signal received; checkpointed at %d", step)
                break
            if should_stop:
                log.info("early-stopped by tuner at step %d", step)
                break
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    return LoopResult(
        final_step=step,
        losses=losses,
        straggler_events=stragglers,
        resumed_from=resumed_from,
        interrupted=interrupted["flag"],
    )
