"""Sharded checkpointing with atomic commits and auto-resume.

Layout:
    <dir>/step_000100/
        manifest.msgpack       tree structure, shapes, dtypes, shard map
        shard_00000.npz        this host's array shards
        COMMITTED              written last — partial checkpoints are ignored
Fault tolerance:
  * saves are atomic (tmp dir + rename, COMMITTED marker last);
  * latest_step() skips uncommitted/corrupt checkpoints;
  * restore() accepts a different host count than save() used (elastic
    restart): every host reads the full arrays it needs from all shards.

On a real multi-host pod each host writes only its addressable shards; in
this single-process container there is exactly one shard file, but the
format and code paths are shard-count-generic.
"""

from __future__ import annotations

import os
import re
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    host_id: int = 0, num_hosts: int = 1) -> str:
    """Atomically writes ``tree`` (arrays) for ``step``."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp.{host_id}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "num_hosts": num_hosts,
        "leaves": [
            {"key": k, "shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
            for k, v in flat
        ],
    }
    def to_np(v):
        a = np.asarray(v)
        if a.dtype.name == "bfloat16":  # npz cannot store ml_dtypes
            return a.astype(np.float32)
        return a

    arrays = {k: to_np(v) for k, v in flat}
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"),
             **{k.replace("/", "|"): v for k, v in arrays.items()})
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest, use_bin_type=True))
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write(str(step))
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMITTED")):
            best = max(best or -1, int(m.group(1)))
    return best


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restores into the structure of ``like`` (shapes/dtypes validated)."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    data: Dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(path)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    data[k.replace("|", "/")] = z[k]
    flat = _flatten_with_paths(like)
    restored = []
    for key, leaf in flat:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {want}")
        dtype = np.asarray(leaf).dtype if not hasattr(leaf, "dtype") else leaf.dtype
        restored.append(jnp.asarray(arr, dtype=dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(directory: str, like: Any) -> Tuple[Optional[int], Any]:
    step = latest_step(directory)
    if step is None:
        return None, like
    return step, restore_checkpoint(directory, step, like)


def prune_old(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
