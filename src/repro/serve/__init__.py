"""Serving: KV/SSM-cache decode engine."""

from repro.serve.engine import DecodeEngine, Request

__all__ = ["DecodeEngine", "Request"]
