"""Batched decode engine: continuous-batching-lite serving loop.

Slots hold independent requests; each engine step decodes one token for every
active slot (the batch dimension is fixed — a freed slot is refilled from the
queue, the standard continuous-batching trick at fixed batch shape).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, model: Model, params, *, batch_size: int = 4,
                 max_seq: int = 512, ctx=None, greedy: bool = True):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.ctx = ctx
        self.cache = model.init_cache(batch=batch_size, max_seq=max_seq)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, ctx=ctx))
        self._remaining_prefill: Dict[int, List[int]] = {}

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def _assign_slots(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # prompt tokens are fed through decode steps (prefill-as-decode;
                # the batched prefill path exists separately for throughput)
                self._remaining_prefill[i] = list(req.prompt)

    def step(self) -> List[Request]:
        """One decode step for the whole batch; returns newly finished."""
        self._assign_slots()
        tokens = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pending = self._remaining_prefill.get(i)
            if pending:
                tokens[i, 0] = pending.pop(0)
            elif req.output:
                tokens[i, 0] = req.output[-1]
            elif req.prompt:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens))
        next_tokens = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self._remaining_prefill.get(i):
                continue  # still prefilling this slot
            req.output.append(int(next_tokens[i]))
            if len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slots[i] = None
                self._remaining_prefill.pop(i, None)
        return finished

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        done: List[Request] = []
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) and steps < max_steps:
            done.extend(self.step())
            steps += 1
        return done
