"""Whisper-base — encoder-decoder; conv frontend stubbed (precomputed frame
embeddings) [arXiv:2212.04356]."""

import dataclasses

from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_kind="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=6, n_frames=1500, frame_dim=512),
    source="arXiv:2212.04356; hf:openai/whisper-base",
)

REDUCED = dataclasses.replace(
    CONFIG, name="whisper-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=128,
    encdec=EncDecConfig(n_encoder_layers=2, n_frames=60, frame_dim=64),
)
