"""Granite-20B (code) — llama-arch with MQA (kv=1) [arXiv:2405.04324]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_kind="gelu",  # gpt-bigcode 2-matrix MLP (20B nameplate)
    source="arXiv:2405.04324; hf:ibm-granite/granite-20b-code-base",
)

REDUCED = dataclasses.replace(
    CONFIG, name="granite-reduced", n_layers=3, d_model=96, n_heads=6,
    n_kv_heads=1, d_ff=256, vocab_size=128,
)
