"""InternVL2-76B — InternViT frontend (stub) + 80L LLM backbone
[arXiv:2404.16821]."""

import dataclasses

from repro.configs.base import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vlm=VLMConfig(n_patch_tokens=256, patch_dim=8192),
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-Llama3-76B",
)

REDUCED = dataclasses.replace(
    CONFIG, name="internvl2-reduced", n_layers=3, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=384, vocab_size=256,
    vlm=VLMConfig(n_patch_tokens=16, patch_dim=128),
)
