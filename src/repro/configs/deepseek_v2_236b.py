"""DeepSeek-V2 236B — MLA attention + 160-expert top-6 MoE with 2 shared
experts and first-layer-dense [arXiv:2405.04434]."""

import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,  # per routed expert
    vocab_size=102400,
    d_head=192,  # nope(128) + rope(64)
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_expert=1536,
        n_shared_experts=2,
        first_k_dense=1,
        dense_d_ff=12288,
        capacity_factor=1.25,
        moe_chunks=8,
    ),
    mla=MLAConfig(
        kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
        nope_head_dim=128, v_head_dim=128,
    ),
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="deepseek-v2-reduced",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    d_head=48,
    moe=MoEConfig(
        n_experts=8, top_k=2, d_expert=64, n_shared_experts=1,
        first_k_dense=1, dense_d_ff=256, capacity_factor=1.5, moe_chunks=2,
    ),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32),
)
