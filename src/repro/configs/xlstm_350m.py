"""xLSTM-350M — mLSTM (matrix memory) + sLSTM blocks [arXiv:2405.04517]."""

import dataclasses

from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # xLSTM blocks carry their own up-projection (proj_factor)
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk=256),
    source="arXiv:2405.04517 (xLSTM[7:1] ratio)",
)

REDUCED = dataclasses.replace(
    CONFIG, name="xlstm-reduced", n_layers=4, d_model=64, n_heads=2,
    n_kv_heads=2, vocab_size=128,
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, chunk=16),
)
