"""StableLM-2-12B — dense GQA [hf:stabilityai/stablelm-2-12b]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    source="hf:stabilityai/stablelm-2-12b (per assignment: stablelm-2 family)",
)

REDUCED = dataclasses.replace(
    CONFIG, name="stablelm-reduced", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=192, vocab_size=128,
)
