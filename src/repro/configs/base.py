"""Architecture configs + input shapes (the assigned 10×4 grid).

Each assigned architecture gets a module ``configs/<id>.py`` exporting
``CONFIG: ArchConfig`` (exact published numbers) and ``REDUCED: ArchConfig``
(same family, tiny dims — used by CPU smoke tests). The registry resolves
``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                # per-expert FFN hidden dim
    n_shared_experts: int = 0    # DeepSeek-style always-on experts
    first_k_dense: int = 0       # leading dense layers (DeepSeek: 1)
    dense_d_ff: int = 0          # FFN width of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    moe_chunks: int = 8          # token-chunked dispatch (memory bound)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6          # shared attention block applied every N layers
    shared_attn: bool = True     # one set of attention weights, reused


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8         # 1 sLSTM per this many layers (rest mLSTM)
    proj_factor: float = 2.0
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 6
    n_frames: int = 1500         # whisper 30s @ 50Hz after conv stub
    frame_dim: int = 512


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patch_tokens: int = 256    # InternViT output tokens after pixel shuffle
    patch_dim: int = 8192        # stubbed: already projected to d_model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_kind: str = "swiglu"     # swiglu (3 matrices) | gelu (2 matrices)
    # training-time knobs (hillclimb levers — shardtune searches over these)
    remat: str = "block"         # none | block | full
    scan_layers: bool = True
    num_microbatches: int = 1
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    param_dtype: str = "bfloat16"
    # source annotation
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        for layer in range(self.n_layers):
            if self.family == "hybrid":
                s = self.ssm
                d_in = s.expand * d
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state) + d_in * d
                total += d_in  # dt/A/D params order-of
                continue
            if self.family == "ssm" and self.xlstm is not None:
                d_in = int(self.xlstm.proj_factor * d)
                total += 2 * d * d_in + d_in * d + 3 * d_in * d_in // 4
                continue
            total += attn
            if self.moe is not None and layer >= self.moe.first_k_dense:
                total += self.moe.n_experts * 3 * d * self.moe.d_expert
                total += self.moe.n_shared_experts * 3 * d * self.moe.d_expert
                total += d * self.moe.n_experts  # router
            elif self.moe is not None:
                total += 3 * d * self.moe.dense_d_ff
            else:
                total += (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
        if self.family == "hybrid" and self.hybrid is not None:
            # one shared attention+MLP block
            total += attn + 3 * d * self.d_ff
        if self.encdec is not None:
            enc_attn = 4 * d * d
            nm = 3 if self.mlp_kind == "swiglu" else 2
            total += self.encdec.n_encoder_layers * (enc_attn + nm * d * self.d_ff)
            total += self.n_layers * enc_attn  # cross attention in decoder
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        inactive = (
            (self.n_layers - m.first_k_dense)
            * (m.n_experts - m.top_k)
            * 3 * self.d_model * m.d_expert
        )
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "zamba2_1p2b",
    "olmoe_1b_7b",
    "deepseek_v2_236b",
    "yi_34b",
    "stablelm_12b",
    "granite_20b",
    "phi4_mini_3p8b",
    "xlstm_350m",
    "whisper_base",
    "internvl2_76b",
]

# archs whose attention is full/quadratic -> long_500k is skipped (see DESIGN.md)
SUBQUADRATIC = {"zamba2_1p2b", "xlstm_350m"}


def shape_supported(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def get_arch(arch_id: str, *, reduced: bool = False) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.REDUCED if reduced else mod.CONFIG
