"""Zamba2-1.2B — Mamba2 backbone + one shared attention block [arXiv:2411.15242]."""

import dataclasses

from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, n_groups=1, chunk=256),
    hybrid=HybridConfig(attn_every=6, shared_attn=True),
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="zamba2-reduced",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, n_groups=1, chunk=32),
    hybrid=HybridConfig(attn_every=3, shared_attn=True),
)
