"""Phi-4-mini 3.8B — dense RoPE+SwiGLU+GQA, 200k vocab [arXiv:2412.08905]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    source="arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct",
)

REDUCED = dataclasses.replace(
    CONFIG, name="phi4-reduced", n_layers=3, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=256, vocab_size=256,
)
