"""Assigned-architecture configs (10 archs × 4 input shapes)."""

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    SUBQUADRATIC,
    ArchConfig,
    EncDecConfig,
    HybridConfig,
    InputShape,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    VLMConfig,
    XLSTMConfig,
    get_arch,
    shape_supported,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "SUBQUADRATIC", "ArchConfig", "EncDecConfig",
    "HybridConfig", "InputShape", "MLAConfig", "MoEConfig", "SSMConfig",
    "VLMConfig", "XLSTMConfig", "get_arch", "shape_supported",
]
