"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""

import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert FFN width
    vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024, capacity_factor=1.25),
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="olmoe-reduced",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab_size=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=1.5, moe_chunks=2),
)
