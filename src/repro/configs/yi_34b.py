"""Yi-34B — dense llama-arch GQA [arXiv:2403.04652]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
    source="arXiv:2403.04652; hf:01-ai/Yi-34B",
)

REDUCED = dataclasses.replace(
    CONFIG, name="yi-reduced", n_layers=3, d_model=112, n_heads=7, n_kv_heads=1,
    d_ff=320, vocab_size=128,
)
