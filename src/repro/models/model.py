"""Uniform model facade over all assigned architectures.

``build_model(cfg)`` returns a Model with:
  init / param_axes           — parameters + logical sharding axes
  loss_fn                     — training loss (CE + MoE aux)
  forward                     — logits (prefill / eval)
  decode_step + cache_spec    — single-token serving
  input_specs / batch_axes    — ShapeDtypeStruct stand-ins per InputShape
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import encdec as encdec_lib
from repro.models import transformer as lm_lib


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params ----------------------------------------------------------------
    def init(self, rng) -> Any:
        if self.cfg.family == "encdec":
            return encdec_lib.init_encdec(rng, self.cfg)
        return lm_lib.init_lm(rng, self.cfg)

    def param_axes(self):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_axes(self.cfg)
        return lm_lib.lm_axes(self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- train -------------------------------------------------------------------
    def loss_fn(self, params, batch, *, ctx=None):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_loss(params, batch, self.cfg, ctx=ctx)
        return lm_lib.lm_loss(params, batch, self.cfg, ctx=ctx)

    # -- serve --------------------------------------------------------------------
    def forward(self, params, batch, *, ctx=None):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_forward(
                params, batch["frames"], batch["tokens"], self.cfg, ctx=ctx)
        return lm_lib.lm_forward(
            params, batch["tokens"], self.cfg, ctx=ctx,
            img_embeds=batch.get("img_embeds"))

    def decode_step(self, params, cache, tokens, *, ctx=None):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_decode_step(params, cache, tokens, self.cfg,
                                                 ctx=ctx)
        return lm_lib.lm_decode_step(params, cache, tokens, self.cfg, ctx=ctx)

    def cache_spec(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_cache_spec(self.cfg, batch, max_seq)
        return lm_lib.lm_cache_spec(self.cfg, batch, max_seq)

    def cache_axes(self):
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_cache_axes(self.cfg)
        return lm_lib.lm_cache_axes(self.cfg)

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch, max_seq))

    # -- dry-run inputs -------------------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for one train/prefill/decode batch."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        if cfg.family == "encdec":
            e = cfg.encdec
            specs = {
                "frames": jax.ShapeDtypeStruct((B, e.n_frames, e.frame_dim), cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return specs
        if cfg.family == "vlm":
            P = cfg.vlm.n_patch_tokens
            specs = {
                "img_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S - P), i32)
            return specs
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs

    def batch_axes(self, shape: InputShape):
        axes = {}
        for name in self.input_specs(shape):
            if shape.kind == "decode":
                axes[name] = "kv_batch -"
            elif name == "img_embeds":
                axes[name] = "batch - -"
            elif name == "frames":
                axes[name] = "batch - -"
            else:
                axes[name] = "batch -"
        return axes

    def make_dummy_batch(self, shape: InputShape, rng=None):
        """Concrete batch for smoke tests (reduced configs only)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        specs = self.input_specs(shape)
        out = {}
        for name, sds in specs.items():
            rng, k = jax.random.split(rng)
            if jnp.issubdtype(sds.dtype, jnp.integer):
                out[name] = jax.random.randint(k, sds.shape, 0, self.cfg.vocab_size,
                                               dtype=sds.dtype)
            else:
                out[name] = jax.random.normal(k, sds.shape, jnp.float32).astype(sds.dtype)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg)
