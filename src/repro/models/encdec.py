"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

input_specs provide precomputed frame embeddings (B, n_frames, d) — the
conv1d×2 frontend is a stub per the assignment. Encoder: bidirectional
self-attention; decoder: causal self-attention + cross-attention.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models.layers import (
    apply_mlp,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_mlp,
    mlp_axes,
    rms_norm,
)
from repro.models.transformer import _remat, _stack_init, _prepend_axes


# -- cross attention ----------------------------------------------------------


def init_cross_attn(key, cfg: ArchConfig):
    d, hq, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * hd, cfg.dtype),
        "wk": dense_init(ks[1], d, hq * hd, cfg.dtype),
        "wv": dense_init(ks[2], d, hq * hd, cfg.dtype),
        "wo": dense_init(ks[3], hq * hd, d, cfg.dtype),
    }


def cross_attn_axes():
    return {"wq": "embed heads", "wk": "embed heads", "wv": "embed heads",
            "wo": "heads embed"}


def apply_cross_attn(params, x, enc_kv, cfg: ArchConfig, *, ctx=None):
    """x (B,S,d) queries; enc_kv = (k, v) each (B,F,H,hd) precomputed."""
    B, S, d = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, hq, hd)
    k, v = enc_kv
    out = attn_lib.chunked_attention(
        q, k, v, causal=False, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hq * hd), params["wo"])


def cross_kv(params, enc_out, cfg: ArchConfig):
    B, F, d = enc_out.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    k = jnp.einsum("bfd,dh->bfh", enc_out, params["wk"]).reshape(B, F, hq, hd)
    v = jnp.einsum("bfd,dh->bfh", enc_out, params["wv"]).reshape(B, F, hq, hd)
    return k, v


# -- blocks ----------------------------------------------------------------------


def _init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": attn_lib.init_gqa(k1, cfg),
        "ffn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dtype, cfg.mlp_kind),
    }


def _init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": attn_lib.init_gqa(k1, cfg),
        "cross_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "cross": init_cross_attn(k2, cfg),
        "ffn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.dtype, cfg.mlp_kind),
    }


def init_encdec(key, cfg: ArchConfig):
    e = cfg.encdec
    ks = jax.random.split(key, 6)
    out = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "frame_proj": dense_init(ks[1], e.frame_dim, cfg.d_model, cfg.dtype),
        "enc_pos": embed_init(ks[2], e.n_frames, cfg.d_model, cfg.dtype),
        "enc_blocks": _stack_init(ks[3], e.n_encoder_layers,
                                  lambda k: _init_enc_block(k, cfg)),
        "enc_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "dec_blocks": _stack_init(ks[4], cfg.n_layers,
                                  lambda k: _init_dec_block(k, cfg)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = dense_init(ks[5], cfg.d_model, cfg.vocab_size, cfg.dtype)
    return out


def encdec_axes(cfg: ArchConfig):
    enc_block = {"attn_norm": "-", "attn": attn_lib.gqa_axes(), "ffn_norm": "-",
                 "mlp": mlp_axes(cfg.mlp_kind)}
    dec_block = dict(enc_block, cross_norm="-", cross=cross_attn_axes())
    return {
        "embed": "vocab embed",
        "frame_proj": "- embed",
        "enc_pos": "frames embed",
        "enc_blocks": _prepend_axes(enc_block),
        "enc_norm": "-",
        "dec_blocks": _prepend_axes(dec_block),
        "final_norm": "-",
    } | ({} if cfg.tie_embeddings else {"lm_head": "embed vocab"})


def encode(params, frames: jnp.ndarray, cfg: ArchConfig, *, ctx=None) -> jnp.ndarray:
    """frames (B, F, frame_dim) -> (B, F, d)."""
    x = jnp.einsum("bfd,dh->bfh", frames, params["frame_proj"])
    x = x + params["enc_pos"][None, : x.shape[1]]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, block):
        h = rms_norm(x, block["attn_norm"], cfg.rms_eps)
        h = attn_lib.apply_gqa(block["attn"], h, cfg, positions=positions,
                               causal=False, ctx=ctx)
        x = x + h
        h = rms_norm(x, block["ffn_norm"], cfg.rms_eps)
        return x + apply_mlp(block["mlp"], h, ctx), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.rms_eps)


def encdec_forward(params, frames, tokens, cfg: ArchConfig, *, ctx=None):
    """Teacher-forced decode over full token sequence. Returns (logits, aux)."""
    enc_out = encode(params, frames, cfg, ctx=ctx)
    x = jnp.take(params["embed"], tokens, axis=0)
    if ctx is not None:
        x = ctx.shard(x, "batch - -")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, block):
        if ctx is not None:
            x = ctx.shard(x, "batch seq_sp -")
        h = rms_norm(x, block["attn_norm"], cfg.rms_eps)
        h = attn_lib.apply_gqa(block["attn"], h, cfg, positions=positions,
                               causal=True, ctx=ctx)
        x = x + h
        h = rms_norm(x, block["cross_norm"], cfg.rms_eps)
        x = x + apply_cross_attn(block["cross"], h, cross_kv(block["cross"], enc_out, cfg),
                                 cfg, ctx=ctx)
        h = rms_norm(x, block["ffn_norm"], cfg.rms_eps)
        return x + apply_mlp(block["mlp"], h, ctx), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if ctx is not None:
        logits = ctx.shard(logits, "batch - act_mlp")
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(params, batch, cfg: ArchConfig, *, ctx=None):
    logits, aux = encdec_forward(params, batch["frames"], batch["tokens"], cfg, ctx=ctx)
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# -- decode -----------------------------------------------------------------------


def encdec_cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    e = cfg.encdec
    hq, hd = cfg.n_heads, cfg.head_dim
    self_one = attn_lib.gqa_cache_spec(cfg, batch, max_seq)
    stack = lambda tree, n: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return {
        "self": stack(self_one, cfg.n_layers),
        "cross_k": jax.ShapeDtypeStruct((cfg.n_layers, batch, e.n_frames, hq, hd),
                                        cfg.dtype),
        "cross_v": jax.ShapeDtypeStruct((cfg.n_layers, batch, e.n_frames, hq, hd),
                                        cfg.dtype),
    }


def encdec_cache_axes(cfg: ArchConfig):
    return {
        "self": _prepend_axes(attn_lib.gqa_cache_axes()),
        "cross_k": "layers kv_batch - act_heads -",
        "cross_v": "layers kv_batch - act_heads -",
    }


def encdec_decode_step(params, cache, tokens, cfg: ArchConfig, *, ctx=None):
    """tokens (B,1). Cross K/V precomputed at prefill (part of the cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(x, xs):
        block, c, ck, cv = xs
        h = rms_norm(x, block["attn_norm"], cfg.rms_eps)
        h, c = attn_lib.gqa_decode(block["attn"], h, cfg, c, ctx=ctx)
        x = x + h
        h = rms_norm(x, block["cross_norm"], cfg.rms_eps)
        x = x + apply_cross_attn(block["cross"], h, (ck, cv), cfg, ctx=ctx)
        h = rms_norm(x, block["ffn_norm"], cfg.rms_eps)
        return x + apply_mlp(block["mlp"], h, ctx), c

    x, self_cache = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross_k"],
                  cache["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, dict(cache, self=self_cache)
