"""Decoder-only LM covering the dense / moe / hybrid / ssm / vlm families.

Layer stacks are `lax.scan`'d over stacked parameters (compile-time O(1) in
depth); the scanned block body is optionally `jax.checkpoint`'d (remat).
Hybrid (zamba2) scans *groups* of [attn_every × Mamba2 + 1 shared attention
block]; xLSTM scans groups of [(slstm_every-1) × mLSTM + 1 sLSTM].

Every apply function takes an optional ShardingCtx and threads an `aux`
scalar (MoE load-balance loss) through the scan carry.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_mlp,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_mlp,
    mlp_axes,
    rms_norm,
)


def _stack_init(key, n: int, init_fn):
    """vmap an init over n layers (stacked leading axis)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _prepend_axes(axes_tree, prefix: str = "layers"):
    return jax.tree.map(lambda s: f"{prefix} {s}".strip(), axes_tree)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn)  # "block": save only layer boundaries


def _shard_tree(tree, axes, ctx):
    """Constrain a (sliced) per-layer param subtree to its logical sharding.

    Applied INSIDE scan bodies: the transpose of a sharding constraint
    constrains the cotangent, so the backward scan's gradient-accumulation
    buffers stay 2D-sharded instead of materializing full f32 stacks.
    """
    if ctx is None:
        return tree
    return jax.tree.map(lambda p, a: ctx.shard(p, a), tree, axes)


# ---------------------------------------------------------------------------
# standard transformer block (dense or MoE FFN; GQA or MLA attention)
# ---------------------------------------------------------------------------


def init_std_block(key, cfg: ArchConfig, *, use_moe: bool, dense_ff: Optional[int] = None):
    k1, k2 = jax.random.split(key)
    attn = (attn_lib.init_mla(k1, cfg) if cfg.mla is not None
            else attn_lib.init_gqa(k1, cfg))
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": attn,
        "ffn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, dense_ff or cfg.d_ff, cfg.dtype,
                            cfg.mlp_kind)
    return p


def std_block_axes(cfg: ArchConfig, *, use_moe: bool):
    attn = attn_lib.mla_axes() if cfg.mla is not None else attn_lib.gqa_axes()
    axes = {"attn_norm": "-", "attn": attn, "ffn_norm": "-"}
    if use_moe:
        axes["moe"] = moe_lib.moe_axes(cfg)
    else:
        axes["mlp"] = mlp_axes(cfg.mlp_kind)
    return axes


def apply_std_block(params, x, cfg: ArchConfig, *, positions, ctx=None,
                    use_moe: bool, causal: bool = True):
    h = rms_norm(x, params["attn_norm"], cfg.rms_eps)
    if cfg.mla is not None:
        h = attn_lib.apply_mla(params["attn"], h, cfg, positions=positions, ctx=ctx)
    else:
        h = attn_lib.apply_gqa(params["attn"], h, cfg, positions=positions,
                               causal=causal, ctx=ctx)
    x = x + h
    h = rms_norm(x, params["ffn_norm"], cfg.rms_eps)
    if use_moe:
        h, aux = moe_lib.apply_moe(params["moe"], h, cfg, ctx=ctx)
    else:
        h, aux = apply_mlp(params["mlp"], h, ctx), jnp.zeros((), jnp.float32)
    return x + h, aux


def decode_std_block(params, x, cfg: ArchConfig, cache, *, ctx=None, use_moe: bool):
    h = rms_norm(x, params["attn_norm"], cfg.rms_eps)
    if cfg.mla is not None:
        h, cache = attn_lib.mla_decode(params["attn"], h, cfg, cache, ctx=ctx)
    else:
        h, cache = attn_lib.gqa_decode(params["attn"], h, cfg, cache, ctx=ctx)
    x = x + h
    h = rms_norm(x, params["ffn_norm"], cfg.rms_eps)
    if use_moe:
        h = moe_lib.moe_decode(params["moe"], h, cfg, ctx=ctx)
    else:
        h = apply_mlp(params["mlp"], h, ctx)
    return x + h, cache


# ---------------------------------------------------------------------------
# LM: init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, cfg.dtype)

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: init_std_block(k, cfg, use_moe=False))
    elif cfg.family == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        if m.first_k_dense:
            params["dense_blocks"] = _stack_init(
                ks[3], m.first_k_dense,
                lambda k: init_std_block(k, cfg, use_moe=False,
                                         dense_ff=m.dense_d_ff or cfg.d_ff))
        params["blocks"] = _stack_init(
            ks[2], n_moe, lambda k: init_std_block(k, cfg, use_moe=True))
    elif cfg.family == "hybrid":
        h = cfg.hybrid
        n_groups = cfg.n_layers // h.attn_every
        n_tail = cfg.n_layers - n_groups * h.attn_every
        params["mamba_groups"] = _stack_init(
            ks[2], n_groups,
            lambda k: _stack_init(k, h.attn_every, lambda kk: _init_mamba_block(kk, cfg)))
        if n_tail:
            params["mamba_tail"] = _stack_init(
                ks[4], n_tail, lambda k: _init_mamba_block(k, cfg))
        params["shared_attn"] = init_std_block(ks[5], cfg, use_moe=False)
    elif cfg.family == "ssm":  # xLSTM
        xc = cfg.xlstm
        n_groups = cfg.n_layers // xc.slstm_every
        params["mlstm_groups"] = _stack_init(
            ks[2], n_groups,
            lambda k: _stack_init(k, xc.slstm_every - 1,
                                  lambda kk: _init_mlstm_block(kk, cfg)))
        params["slstm_blocks"] = _stack_init(
            ks[4], n_groups, lambda k: _init_slstm_block(k, cfg))
    else:
        raise ValueError(f"unsupported family {cfg.family}")
    return params


def _init_mamba_block(key, cfg):
    return {"norm": jnp.zeros((cfg.d_model,), cfg.dtype),
            "mamba": mamba_lib.init_mamba2(key, cfg)}


def _init_mlstm_block(key, cfg):
    return {"norm": jnp.zeros((cfg.d_model,), cfg.dtype),
            "mlstm": xlstm_lib.init_mlstm(key, cfg)}


def _init_slstm_block(key, cfg):
    return {"norm": jnp.zeros((cfg.d_model,), cfg.dtype),
            "slstm": xlstm_lib.init_slstm(key, cfg)}


def lm_axes(cfg: ArchConfig):
    axes: Dict[str, Any] = {"embed": "vocab embed", "final_norm": "-"}
    if not cfg.tie_embeddings:
        axes["lm_head"] = "embed vocab"
    if cfg.family in ("dense", "vlm"):
        axes["blocks"] = _prepend_axes(std_block_axes(cfg, use_moe=False))
    elif cfg.family == "moe":
        axes["blocks"] = _prepend_axes(std_block_axes(cfg, use_moe=True))
        if cfg.moe.first_k_dense:
            axes["dense_blocks"] = _prepend_axes(std_block_axes(cfg, use_moe=False))
    elif cfg.family == "hybrid":
        mb = {"norm": "-", "mamba": mamba_lib.mamba2_axes()}
        axes["mamba_groups"] = _prepend_axes(_prepend_axes(mb), "layers")
        if cfg.n_layers % cfg.hybrid.attn_every:
            axes["mamba_tail"] = _prepend_axes(mb)
        axes["shared_attn"] = std_block_axes(cfg, use_moe=False)
    elif cfg.family == "ssm":
        ml = {"norm": "-", "mlstm": xlstm_lib.mlstm_axes()}
        sl = {"norm": "-", "slstm": xlstm_lib.slstm_axes()}
        axes["mlstm_groups"] = _prepend_axes(_prepend_axes(ml), "layers")
        axes["slstm_blocks"] = _prepend_axes(sl)
    return axes


# ---------------------------------------------------------------------------
# LM: forward (train / prefill)
# ---------------------------------------------------------------------------


def lm_forward(
    params,
    tokens: jnp.ndarray,               # (B, S_text)
    cfg: ArchConfig,
    *,
    ctx=None,
    img_embeds: Optional[jnp.ndarray] = None,  # (B, P, D) for vlm
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V) [vocab-sharded], aux_loss ())."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm":
        assert img_embeds is not None
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    if ctx is not None:
        x = ctx.shard(x, "batch - -")
    positions = jnp.arange(S, dtype=jnp.int32)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe"):
        use_moe = cfg.family == "moe"
        if use_moe and cfg.moe.first_k_dense:
            dense_axes = std_block_axes(cfg, use_moe=False)

            def dense_body(carry, block):
                x, aux = carry
                block = _shard_tree(block, dense_axes, ctx)
                x, a = apply_std_block(block, x, cfg, positions=positions, ctx=ctx,
                                       use_moe=False)
                return (x, aux + a), None
            (x, aux0), _ = jax.lax.scan(
                _remat(dense_body, cfg.remat), (x, aux0), params["dense_blocks"])

        block_axes = std_block_axes(cfg, use_moe=use_moe)

        def body(carry, block):
            x, aux = carry
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")  # SP residual saving
            block = _shard_tree(block, block_axes, ctx)
            x, a = apply_std_block(block, x, cfg, positions=positions, ctx=ctx,
                                   use_moe=use_moe)
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")  # saved carry stays SP-sharded
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(_remat(body, cfg.remat), (x, aux0), params["blocks"])

    elif cfg.family == "hybrid":
        mamba_axes = {"norm": "-", "mamba": mamba_lib.mamba2_axes()}

        def mamba_body(x, block):
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")
            block = _shard_tree(block, mamba_axes, ctx)
            h = rms_norm(x, block["norm"], cfg.rms_eps)
            x = x + mamba_lib.apply_mamba2(block["mamba"], h, cfg, ctx=ctx)
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")
            return x, None

        shared = params["shared_attn"]

        def group_body(x, group):
            x, _ = jax.lax.scan(_remat(mamba_body, cfg.remat), x, group)
            x, _ = _remat(
                lambda xx, _unused: (apply_std_block(
                    shared, xx, cfg, positions=positions, ctx=ctx, use_moe=False)[0],
                    None),
                cfg.remat)(x, None)
            return x, None

        x, _ = jax.lax.scan(group_body, x, params["mamba_groups"])
        if "mamba_tail" in params:
            x, _ = jax.lax.scan(_remat(mamba_body, cfg.remat), x, params["mamba_tail"])
        aux = aux0

    elif cfg.family == "ssm":
        ml_axes = {"norm": "-", "mlstm": xlstm_lib.mlstm_axes()}
        sl_axes = {"norm": "-", "slstm": xlstm_lib.slstm_axes()}

        def mlstm_body(x, block):
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")
            block = _shard_tree(block, ml_axes, ctx)
            h = rms_norm(x, block["norm"], cfg.rms_eps)
            x = x + xlstm_lib.apply_mlstm(block["mlstm"], h, cfg, ctx=ctx)
            if ctx is not None:
                x = ctx.shard(x, "batch seq_sp -")
            return x, None

        def xgroup_body(x, group):
            mblocks, sblock = group
            x, _ = jax.lax.scan(_remat(mlstm_body, cfg.remat), x, mblocks)
            sblock = _shard_tree(sblock, sl_axes, ctx)
            h = rms_norm(x, sblock["norm"], cfg.rms_eps)
            x = x + xlstm_lib.apply_slstm(sblock["slstm"], h, cfg, ctx=ctx)
            return x, None

        x, _ = jax.lax.scan(
            xgroup_body, x, (params["mlstm_groups"], params["slstm_blocks"]))
        aux = aux0
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if ctx is not None:
        logits = ctx.shard(logits, "batch - act_mlp")  # vocab-sharded logits
    return logits, aux


def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, *, ctx=None):
    """batch: tokens (B,S), labels (B,S) [, img_embeds (B,P,D)]."""
    logits, aux = lm_forward(
        params, batch["tokens"], cfg, ctx=ctx, img_embeds=batch.get("img_embeds")
    )
    labels = batch["labels"]
    if cfg.family == "vlm":
        # image positions carry no next-token loss
        P = cfg.vlm.n_patch_tokens
        logits = logits[:, P:]
    ce = cross_entropy_loss(logits, labels, batch.get("loss_mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# LM: single-token decode with caches
# ---------------------------------------------------------------------------


def lm_cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    if cfg.family in ("dense", "vlm", "moe"):
        one = (attn_lib.mla_cache_spec(cfg, batch, max_seq) if cfg.mla is not None
               else attn_lib.gqa_cache_spec(cfg, batch, max_seq))
        stack = lambda n: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)
        caches = {"blocks": stack(cfg.n_layers - (cfg.moe.first_k_dense if cfg.moe else 0))}
        if cfg.moe and cfg.moe.first_k_dense:
            caches["dense_blocks"] = stack(cfg.moe.first_k_dense)
        return caches
    if cfg.family == "hybrid":
        h = cfg.hybrid
        n_groups = cfg.n_layers // h.attn_every
        n_tail = cfg.n_layers - n_groups * h.attn_every
        mamba_one = mamba_lib.mamba2_cache_spec(cfg, batch)
        attn_one = attn_lib.gqa_cache_spec(cfg, batch, max_seq)
        stack = lambda tree, *ns: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(tuple(ns) + s.shape, s.dtype), tree)
        caches = {
            "mamba_groups": stack(mamba_one, n_groups, h.attn_every),
            "attn": stack(attn_one, n_groups),
        }
        if n_tail:
            caches["mamba_tail"] = stack(mamba_one, n_tail)
        return caches
    if cfg.family == "ssm":
        xc = cfg.xlstm
        n_groups = cfg.n_layers // xc.slstm_every
        stack = lambda tree, *ns: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(tuple(ns) + s.shape, s.dtype), tree)
        return {
            "mlstm_groups": stack(xlstm_lib.mlstm_cache_spec(cfg, batch),
                                  n_groups, xc.slstm_every - 1),
            "slstm_blocks": stack(xlstm_lib.slstm_cache_spec(cfg, batch), n_groups),
        }
    raise ValueError(cfg.family)


def lm_cache_axes(cfg: ArchConfig):
    pre = lambda tree, n=1: functools.reduce(lambda t, _: _prepend_axes(t), range(n), tree)
    if cfg.family in ("dense", "vlm", "moe"):
        one = (attn_lib.mla_cache_axes() if cfg.mla is not None
               else attn_lib.gqa_cache_axes())
        axes = {"blocks": pre(one)}
        if cfg.moe and cfg.moe.first_k_dense:
            axes["dense_blocks"] = pre(one)
        return axes
    if cfg.family == "hybrid":
        axes = {
            "mamba_groups": pre(mamba_lib.mamba2_cache_axes(), 2),
            "attn": pre(attn_lib.gqa_cache_axes()),
        }
        if cfg.n_layers % cfg.hybrid.attn_every:
            axes["mamba_tail"] = pre(mamba_lib.mamba2_cache_axes())
        return axes
    if cfg.family == "ssm":
        return {
            "mlstm_groups": pre(xlstm_lib.mlstm_cache_axes(), 2),
            "slstm_blocks": pre(xlstm_lib.slstm_cache_axes()),
        }
    raise ValueError(cfg.family)


def lm_decode_step(params, cache, tokens: jnp.ndarray, cfg: ArchConfig, *, ctx=None):
    """tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B,1,D)
    if ctx is not None:
        x = ctx.shard(x, "kv_batch - -")

    if cfg.family in ("dense", "vlm", "moe"):
        use_moe = cfg.family == "moe"
        if use_moe and cfg.moe.first_k_dense:
            def dense_body(x, xs):
                block, c = xs
                x, c = decode_std_block(block, x, cfg, c, ctx=ctx, use_moe=False)
                return x, c
            x, dcache = jax.lax.scan(
                dense_body, x, (params["dense_blocks"], cache["dense_blocks"]))
            cache = dict(cache, dense_blocks=dcache)

        def body(x, xs):
            block, c = xs
            x, c = decode_std_block(block, x, cfg, c, ctx=ctx, use_moe=use_moe)
            return x, c

        x, bcache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        cache = dict(cache, blocks=bcache)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(x, xs):
            block, c = xs
            h = rms_norm(x, block["norm"], cfg.rms_eps)
            h, c = mamba_lib.mamba2_decode(block["mamba"], h, cfg, c, ctx=ctx)
            return x + h, c

        def group_body(x, xs):
            group, mcaches, acache = xs
            x, mcaches = jax.lax.scan(mamba_body, x, (group, mcaches))
            x, acache = decode_std_block(shared, x, cfg, acache, ctx=ctx, use_moe=False)
            return x, (mcaches, acache)

        x, (mcaches, acaches) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["mamba_groups"], cache["attn"]))
        cache = dict(cache, mamba_groups=mcaches, attn=acaches)
        if "mamba_tail" in params:
            x, tcache = jax.lax.scan(
                mamba_body, x, (params["mamba_tail"], cache["mamba_tail"]))
            cache = dict(cache, mamba_tail=tcache)

    elif cfg.family == "ssm":
        def mlstm_body(x, xs):
            block, c = xs
            h = rms_norm(x, block["norm"], cfg.rms_eps)
            h, c = xlstm_lib.mlstm_decode(block["mlstm"], h, cfg, c, ctx=ctx)
            return x + h, c

        def xgroup_body(x, xs):
            (mblocks, sblock), (mc, sc) = xs
            x, mc = jax.lax.scan(mlstm_body, x, (mblocks, mc))
            h = rms_norm(x, sblock["norm"], cfg.rms_eps)
            h, sc = xlstm_lib.slstm_decode(sblock["slstm"], h, cfg, sc, ctx=ctx)
            return x + h, (mc, sc)

        x, (mc, sc) = jax.lax.scan(
            xgroup_body, x,
            ((params["mlstm_groups"], params["slstm_blocks"]),
             (cache["mlstm_groups"], cache["slstm_blocks"])))
        cache = dict(cache, mlstm_groups=mc, slstm_blocks=sc)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, cache
