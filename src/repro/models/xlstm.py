"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) + sLSTM (scalar
memory, recurrent) [arXiv:2405.04517].

mLSTM is expressed on the generalized SSD core (models.mamba2.ssd_core):
the recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T is an SSD scan with
data-dependent log-decay a_t = log sigmoid(f̃_t) and input multiplier
i_t = exp(ĩ_t). The normalizer n_t = f n_{t-1} + i k_t rides along as an
extra channel (x' = [v, 1]).

Numerical-stability note (DESIGN.md §8): instead of the paper's running
max-state m_t we clip the input-gate logit to [-10, 8] — equivalent in the
regimes the smoke tests exercise, and chunk-parallel friendly.

sLSTM keeps its per-timestep recurrence (h_{t-1} feeds the gates through a
block-diagonal recurrent matrix), so it runs as a lax.scan over time — the
architecture is inherently sequential there (one layer per ``slstm_every``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XLSTMConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.mamba2 import ssd_core

I_CLIP = (-10.0, 8.0)


def _dims(cfg: ArchConfig):
    x: XLSTMConfig = cfg.xlstm
    d_inner = int(x.proj_factor * cfg.d_model)
    n_heads = cfg.n_heads
    d_head = d_inner // n_heads
    return x, d_inner, n_heads, d_head


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig):
    x, d_inner, n_heads, d_head = _dims(cfg)
    d = cfg.d_model
    dtype = cfg.dtype
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype),      # [cell in, gate]
        "wq": dense_init(ks[1], d_inner, d_inner, dtype),
        "wk": dense_init(ks[2], d_inner, d_inner, dtype),
        "wv": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_if": dense_init(ks[4], d_inner, 2 * n_heads, jnp.float32, scale=0.01),
        "b_if": jnp.concatenate(
            [jnp.zeros((n_heads,)), jnp.linspace(3.0, 6.0, n_heads)]
        ).astype(jnp.float32),  # forget gates biased open
        "norm_w": jnp.zeros((d_inner,), dtype),
        "w_down": dense_init(ks[5], d_inner, d, dtype),
    }


def mlstm_axes():
    return {"w_up": "embed ssm_inner", "wq": "ssm_inner ssm_inner",
            "wk": "ssm_inner ssm_inner", "wv": "ssm_inner ssm_inner",
            "w_if": "ssm_inner -", "b_if": "-", "norm_w": "ssm_inner",
            "w_down": "ssm_inner embed"}


def _mlstm_gates(params, u, n_heads):
    raw = jnp.einsum("bsi,ig->bsg", u.astype(jnp.float32),
                     params["w_if"].astype(jnp.float32)) + params["b_if"]
    i_raw, f_raw = jnp.split(raw, 2, axis=-1)  # (B,S,H) each
    a = jax.nn.log_sigmoid(f_raw)              # log decay in (-inf, 0)
    mult = jnp.exp(jnp.clip(i_raw, *I_CLIP))   # input gate
    return a, mult


def apply_mlstm(params, x: jnp.ndarray, cfg: ArchConfig, *, ctx=None) -> jnp.ndarray:
    xc, d_inner, n_heads, d_head = _dims(cfg)
    B, S, d = x.shape
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"])
    if ctx is not None:
        up = ctx.shard(up, "batch - act_mlp")
    u, gate = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsi,ij->bsj", u, params["wq"]).reshape(B, S, n_heads, d_head)
    k = jnp.einsum("bsi,ij->bsj", u, params["wk"]).reshape(B, S, n_heads, d_head)
    v = jnp.einsum("bsi,ij->bsj", u, params["wv"]).reshape(B, S, n_heads, d_head)
    k = k / jnp.sqrt(jnp.asarray(d_head, k.dtype))
    a, mult = _mlstm_gates(params, u, n_heads)

    # numerator + normalizer in one SSD pass: x' = [v, 1]
    ones = jnp.ones((B, S, n_heads, 1), v.dtype)
    xprime = jnp.concatenate([v, ones], axis=-1)  # (B,S,H,P+1)
    y, _ = ssd_core(xprime, a, mult, k, q, chunk=xc.chunk)
    num, den = y[..., :d_head], y[..., d_head:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, d_inner)
    h = rms_norm(h, params["norm_w"], cfg.rms_eps)
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("bsi,id->bsd", h, params["w_down"])


def mlstm_decode(params, x: jnp.ndarray, cfg: ArchConfig, cache: dict, *, ctx=None
                 ) -> Tuple[jnp.ndarray, dict]:
    """cache {C: (B,H,P+1,P)} — matrix memory with normalizer row."""
    xc, d_inner, n_heads, d_head = _dims(cfg)
    B = x.shape[0]
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"])
    u, gate = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsi,ij->bsj", u, params["wq"]).reshape(B, 1, n_heads, d_head)
    k = jnp.einsum("bsi,ij->bsj", u, params["wk"]).reshape(B, 1, n_heads, d_head)
    v = jnp.einsum("bsi,ij->bsj", u, params["wv"]).reshape(B, 1, n_heads, d_head)
    k = k / jnp.sqrt(jnp.asarray(d_head, k.dtype))
    a, mult = _mlstm_gates(params, u, n_heads)  # (B,1,H)

    C = cache["C"].astype(jnp.float32)  # (B,H,P+1,P)
    decay = jnp.exp(a[:, 0])            # (B,H)
    xprime = jnp.concatenate([v, jnp.ones((B, 1, n_heads, 1), v.dtype)], -1)[:, 0]
    upd = (mult[:, 0][..., None, None]
           * xprime.astype(jnp.float32)[..., None]          # (B,H,P+1,1)
           * k[:, 0].astype(jnp.float32)[:, :, None, :])    # (B,H,1,N)
    C = C * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", C, q[:, 0].astype(jnp.float32))
    num, den = y[..., :d_head], y[..., d_head:]
    h = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(B, 1, d_inner).astype(x.dtype)
    h = rms_norm(h, params["norm_w"], cfg.rms_eps)
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bsi,id->bsd", h, params["w_down"])
    return out, {"C": C}


def mlstm_cache_spec(cfg: ArchConfig, batch: int):
    _, d_inner, n_heads, d_head = _dims(cfg)
    return {"C": jax.ShapeDtypeStruct((batch, n_heads, d_head + 1, d_head), jnp.float32)}


def mlstm_cache_axes():
    return {"C": "kv_batch ssm_heads - -"}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    n_heads = cfg.n_heads
    d_head = d // n_heads
    dtype = cfg.dtype
    ks = jax.random.split(key, 4)
    return {
        # 4 gates (i, f, z, o) from input and recurrent (block-diagonal) path
        "w_x": dense_init(ks[0], d, 4 * d, dtype),
        "r_h": (jax.random.normal(ks[1], (n_heads, d_head, 4 * d_head), jnp.float32)
                * 0.02).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 4.0), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "norm_w": jnp.zeros((d,), dtype),
        # post-cell gated MLP (proj factor 4/3, paper's sLSTM block)
        "w_up": dense_init(ks[2], d, 2 * (4 * d // 3), dtype),
        "w_down": dense_init(ks[3], 4 * d // 3, d, dtype),
    }


def slstm_axes():
    return {"w_x": "embed mlp", "r_h": "ssm_heads - -", "b": "-",
            "norm_w": "-", "w_up": "embed mlp", "w_down": "mlp embed"}


def _slstm_cell(params, xt, state, n_heads, d_head):
    """xt (B, 4d) pre-projected gates input; state (c, n, h) each (B, d)."""
    c, n, h = state
    B = xt.shape[0]
    d = c.shape[-1]
    hh = h.reshape(B, n_heads, d_head)
    rec = jnp.einsum("bhp,hpg->bhg", hh, params["r_h"].astype(jnp.float32))
    gates = xt + rec.reshape(B, 4 * d) + params["b"]
    i_raw, f_raw, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    i = jnp.exp(jnp.clip(i_raw, *I_CLIP))
    f = jax.nn.sigmoid(f_raw)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h)


def apply_slstm(params, x: jnp.ndarray, cfg: ArchConfig, *, ctx=None) -> jnp.ndarray:
    B, S, d = x.shape
    n_heads = cfg.n_heads
    d_head = d // n_heads
    xg = jnp.einsum("bsd,dg->bsg", x, params["w_x"]).astype(jnp.float32)
    state0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3))

    def step(state, xt):
        state = _slstm_cell(params, xt, state, n_heads, d_head)
        return state, state[2]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,d)
    h = rms_norm(h, params["norm_w"], cfg.rms_eps)
    up = jnp.einsum("bsd,df->bsf", h, params["w_up"])
    a, g = jnp.split(up, 2, axis=-1)
    return jnp.einsum("bsf,fd->bsd",
                      a * jax.nn.silu(g.astype(jnp.float32)).astype(a.dtype),
                      params["w_down"])


def slstm_decode(params, x: jnp.ndarray, cfg: ArchConfig, cache: dict, *, ctx=None
                 ) -> Tuple[jnp.ndarray, dict]:
    B, _, d = x.shape
    n_heads = cfg.n_heads
    d_head = d // n_heads
    xg = jnp.einsum("bsd,dg->bsg", x, params["w_x"])[:, 0].astype(jnp.float32)
    state = (cache["c"], cache["n"], cache["h"])
    c, n, h = _slstm_cell(params, xg, state, n_heads, d_head)
    out = rms_norm(h[:, None].astype(x.dtype), params["norm_w"], cfg.rms_eps)
    up = jnp.einsum("bsd,df->bsf", out, params["w_up"])
    a, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd",
                     a * jax.nn.silu(g.astype(jnp.float32)).astype(a.dtype),
                     params["w_down"])
    return out, {"c": c, "n": n, "h": h}


def slstm_cache_spec(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return {k: jax.ShapeDtypeStruct((batch, d), jnp.float32) for k in ("c", "n", "h")}


def slstm_cache_axes():
    return {"c": "kv_batch -", "n": "kv_batch -", "h": "kv_batch -"}
