"""Attention: GQA (chunked/flash-equivalent), MLA (DeepSeek absorbed form),
and decode paths over sharded KV caches.

The training/prefill path is an online-softmax double-chunked attention —
mathematically identical to flash attention and the jnp oracle for the Pallas
kernel. Chunk sizes bound the score-matrix working set so 32k-sequence
prefill fits per-device memory without materializing (S, S).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.layers import acc_einsum, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked attention (flash-equivalent, pure jnp — oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad seq dims to chunk multiples
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    # grouped layout: q (B, nq, qc, Hkv, G, D) — K/V are NEVER materialized
    # per-q-head (a repeat would multiply KV HBM traffic by the group size),
    # and all inputs stay in their storage dtype (dots accumulate in f32)
    qb = qp.reshape(B, nq, q_chunk, Hkv, group, D)
    kb = kp.reshape(B, nk, kv_chunk, Hkv, D)
    vb = vp.reshape(B, nk, kv_chunk, Hkv, Dv)

    kv_valid = (jnp.arange(nk * kv_chunk) < Sk).reshape(nk, kv_chunk)

    def one_q_block(qi, q_blk):  # q_blk: (B, qc, Hkv, G, D)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry  # (B,Hkv,G,qc), ..., (B,Hkv,G,qc,Dv)
            ki, k_blk, v_blk, valid = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = acc_einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            mask = valid[None, :]
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + acc_einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, group, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, group, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, group, q_chunk, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_valid),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhgqd->bqhgd", out).reshape(B, q_chunk, Hq, Dv)

    with jax.named_scope("xla_flash_attention"):
        outs = jax.lax.map(
            lambda args: one_q_block(*args), (jnp.arange(nq), jnp.moveaxis(qb, 1, 0))
        )  # (nq, B, qc, Hq, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, Hq, Dv)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (B, Hkv, S, D)  — head-major: no per-layer transpose
    v_cache: jnp.ndarray,  # (B, Hkv, S, Dv)
    cache_len,             # () int32 — valid prefix length
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token attention over the cache.

    The cache stays in its storage dtype (bf16) end-to-end — dots accumulate
    in f32 via preferred_element_type; a naive .astype(f32) would stream a
    full converted copy of the cache through HBM every layer. Softmax
    reductions over a sequence-sharded cache lower to tiny all-reduces
    (context parallelism)."""
    B, _, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)
    qd = q[:, 0].reshape(B, Hkv, group, D).astype(k_cache.dtype)
    # grouped einsum: KV cache read once, not repeated per q-head group
    s = acc_einsum("bhgd,bhkd->bhgk", qd, k_cache) * scale
    valid = jnp.arange(S)[None, None, None, :] < cache_len
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = acc_einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, Hq, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block (standard llama-style attention)
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ArchConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dtype = cfg.dtype
    return {
        "wq": dense_init(k1, d, hq * hd, dtype),
        "wk": dense_init(k2, d, hkv * hd, dtype),
        "wv": dense_init(k3, d, hkv * hd, dtype),
        "wo": dense_init(k4, hq * hd, d, dtype),
    }


def gqa_axes():
    return {"wq": "embed heads", "wk": "embed kv_heads", "wv": "embed kv_heads",
            "wo": "heads embed"}


def apply_gqa(
    params, x: jnp.ndarray, cfg: ArchConfig, *, positions: jnp.ndarray,
    causal: bool = True, ctx=None,
) -> jnp.ndarray:
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, hq, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, S, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, S, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        q = ctx.shard(q, "batch - act_heads -")
        k = ctx.shard(k, "batch - act_kv_heads -")
        v = ctx.shard(v, "batch - act_kv_heads -")
    out = chunked_attention(
        q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk
    )
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hq * hd), params["wo"])


def gqa_decode(
    params, x: jnp.ndarray, cfg: ArchConfig, cache: dict, *, ctx=None,
) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d). cache: {k: (B,Hkv,S,hd), v: ..., len: ()}."""
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["len"]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, 1, hq, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, 1, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, 1, hkv, hd)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # head-major cache: update writes a (B,Hkv,1,hd) slice along seq
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype).transpose(0, 2, 1, 3), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype).transpose(0, 2, 1, 3), pos, axis=2)
    if ctx is not None:
        k_cache = ctx.shard(k_cache, "kv_batch act_kv_heads kv_seq -")
        v_cache = ctx.shard(v_cache, "kv_batch act_kv_heads kv_seq -")
    out = decode_attention(q, k_cache, v_cache, pos + 1)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, hq * hd), params["wo"])
    return y, {"k": k_cache, "v": v_cache, "len": pos + 1}


def gqa_cache_spec(cfg: ArchConfig, batch: int, seq: int):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, hkv, seq, hd), cfg.dtype),
        "v": jax.ShapeDtypeStruct((batch, hkv, seq, hd), cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def gqa_cache_axes():
    return {"k": "kv_batch act_kv_heads kv_seq -",
            "v": "kv_batch act_kv_heads kv_seq -", "len": ""}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig):
    m: MLAConfig = cfg.mla
    d, hq = cfg.d_model, cfg.n_heads
    dtype = cfg.dtype
    ks = jax.random.split(key, 7)
    qk_dim = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),          # down
        "wq_b": dense_init(ks[1], m.q_lora_rank, hq * qk_dim, dtype),  # up
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.rope_head_dim, dtype),
        "wk_b": dense_init(ks[3], m.kv_lora_rank, hq * m.nope_head_dim, dtype),
        "wv_b": dense_init(ks[4], m.kv_lora_rank, hq * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], hq * m.v_head_dim, d, dtype),
    }


def mla_axes():
    return {"wq_a": "embed q_lora", "wq_b": "q_lora heads",
            "wkv_a": "embed kv_lora", "wk_b": "kv_lora heads",
            "wv_b": "kv_lora heads", "wo": "heads embed"}


def apply_mla(
    params, x: jnp.ndarray, cfg: ArchConfig, *, positions: jnp.ndarray, ctx=None,
) -> jnp.ndarray:
    """Training/prefill MLA: expand latents to per-head K/V then flash attend."""
    m: MLAConfig = cfg.mla
    B, S, d = x.shape
    hq = cfg.n_heads
    qk_dim = m.nope_head_dim + m.rope_head_dim
    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = jnp.einsum("bsr,rh->bsh", q, params["wq_b"]).reshape(B, S, hq, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # 1 shared head
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, params["wk_b"]).reshape(
        B, S, hq, m.nope_head_dim
    )
    v = jnp.einsum("bsr,rh->bsh", c_kv, params["wv_b"]).reshape(B, S, hq, m.v_head_dim)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, hq, m.rope_head_dim))],
                         axis=-1)
    if ctx is not None:
        qf = ctx.shard(qf, "batch - act_heads -")
        kf = ctx.shard(kf, "batch - act_heads -")
        v = ctx.shard(v, "batch - act_heads -")
    out = chunked_attention(
        qf, kf, v, causal=True, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        scale=1.0 / (qk_dim**0.5),
    )
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hq * m.v_head_dim), params["wo"])


def mla_decode(
    params, x: jnp.ndarray, cfg: ArchConfig, cache: dict, *, ctx=None,
) -> Tuple[jnp.ndarray, dict]:
    """Absorbed-MLA decode: attends over the latent cache (c_kv, k_rope) —
    the memory win that motivates MLA. Cache: {ckv: (B,S,R), krope: (B,S,Dr),
    len: ()}."""
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    hq = cfg.n_heads
    qk_scale = 1.0 / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
    pos = cache["len"]
    positions = jnp.full((B, 1), pos, jnp.int32)

    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = jnp.einsum("bsr,rh->bsh", q, params["wq_b"]).reshape(
        B, 1, hq, m.nope_head_dim + m.rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_new, kr_new = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    kr_new = apply_rope(kr_new[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_new.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], kr_new.astype(cache["krope"].dtype), pos, axis=1)
    if ctx is not None:
        ckv = ctx.shard(ckv, "kv_batch kv_seq -")
        krope = ctx.shard(krope, "kv_batch kv_seq -")

    # absorb W_uk into the query: q' = q_nope @ W_uk^T -> latent space.
    # the latent cache stays bf16 (f32 casts would stream a converted copy
    # of the whole cache through HBM per layer); dots accumulate in f32.
    wk_b = params["wk_b"].reshape(m.kv_lora_rank, hq, m.nope_head_dim)
    q_lat = acc_einsum("bshn,rhn->bshr", q_nope.astype(wk_b.dtype), wk_b)  # (B,1,H,R)
    s_nope = acc_einsum("bshr,btr->bhst", q_lat.astype(ckv.dtype), ckv)
    s_rope = acc_einsum("bshn,btn->bhst", q_rope.astype(krope.dtype), krope)
    s = (s_nope + s_rope) * qk_scale  # (B, H, 1, S)
    S_len = ckv.shape[1]
    valid = jnp.arange(S_len)[None, None, None, :] < (pos + 1)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then expand through W_uv (absorbed output)
    lat = acc_einsum("bhst,btr->bshr", p.astype(ckv.dtype), ckv)  # (B,1,H,R)
    wv_b = params["wv_b"].reshape(m.kv_lora_rank, hq, m.v_head_dim)
    out = acc_einsum("bshr,rhv->bshv", lat.astype(wv_b.dtype), wv_b)
    out = out.reshape(B, 1, hq * m.v_head_dim).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return y, {"ckv": ckv, "krope": krope, "len": pos + 1}


def mla_cache_spec(cfg: ArchConfig, batch: int, seq: int):
    m = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, seq, m.kv_lora_rank), cfg.dtype),
        "krope": jax.ShapeDtypeStruct((batch, seq, m.rope_head_dim), cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def mla_cache_axes():
    return {"ckv": "kv_batch kv_seq -", "krope": "kv_batch kv_seq -", "len": ""}
