"""Shared model layers: linear/init helpers, RMSNorm, RoPE, embeddings, MLP.

Parameters are plain nested dicts. Every init_* function has a matching
*_axes function returning the same tree with string leaves of logical axis
names ('vocab embed', '-' = unsharded) consumed by distributed.sharding.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def acc_einsum(spec: str, a, b):
    """einsum with f32 accumulation. On TPU (and in dry-run lowering) this is
    a native bf16xbf16->f32 dot (no HBM-visible upcast); the CPU *runtime*
    lacks that DotThunk, so eager/test execution upcasts instead."""
    import os

    if jax.default_backend() == "tpu" or os.environ.get("REPRO_DRYRUN"):
        return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# -- RoPE ---------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLP (SwiGLU) ----------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "gelu":  # 2-matrix (gpt/whisper-style)
        return {
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype,
                                 scale=1.0 / jnp.sqrt(d_ff)),
        }
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype, scale=1.0 / jnp.sqrt(d_ff)),
    }


def mlp_axes(kind: str = "swiglu"):
    if kind == "gelu":
        return {"w_up": "embed mlp", "w_down": "mlp embed"}
    return {"w_gate": "embed mlp", "w_up": "embed mlp", "w_down": "mlp embed"}


def apply_mlp(params, x: jnp.ndarray, ctx=None) -> jnp.ndarray:
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if "w_gate" in params:  # SwiGLU
        h = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    else:  # GELU
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    if ctx is not None:
        h = ctx.shard(h, "batch - act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def mlp_flops(tokens: int, d_model: int, d_ff: int) -> int:
    return 2 * tokens * d_model * d_ff * 3


# -- losses ------------------------------------------------------------------------


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over (possibly masked) positions; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
