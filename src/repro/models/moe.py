"""Mixture-of-Experts layer: top-k routing with capacity-bounded scatter
dispatch (+ DeepSeek-style shared experts and first-k-dense layers).

Dispatch strategy (GSPMD-friendly):
  * tokens are processed in ``moe_chunks`` sequence chunks (bounds the
    dispatch buffer memory);
  * within a chunk, per-batch-row scatter builds an (B, E, C, D) buffer —
    batch stays data-sharded, so the scatter is shard-local; the expert
    einsum then runs with E sharded over `model` (expert parallelism);
  * over-capacity tokens are dropped (their combine weight is zero) —
    standard capacity-factor semantics.

The router runs in float32; an auxiliary load-balancing loss (Switch-style)
is returned for the train loss.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ArchConfig):
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    dtype = cfg.dtype
    ks = jax.random.split(key, 5)
    params = {
        "w_router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": _expert_init(ks[1], m.n_experts, d, m.d_expert, dtype),
        "w_up": _expert_init(ks[2], m.n_experts, d, m.d_expert, dtype),
        "w_down": _expert_init(ks[3], m.n_experts, m.d_expert, d, dtype),
    }
    if m.n_shared_experts:
        from repro.models.layers import init_mlp

        params["shared"] = init_mlp(ks[4], d, m.n_shared_experts * m.d_expert, dtype)
    return params


def _expert_init(key, e, din, dout, dtype):
    scale = 1.0 / jnp.sqrt(din)
    return (jax.random.normal(key, (e, din, dout), jnp.float32) * scale).astype(dtype)


def moe_axes(cfg: ArchConfig):
    axes = {
        "w_router": "embed -",
        "w_gate": "experts embed expert_mlp",
        "w_up": "experts embed expert_mlp",
        "w_down": "experts expert_mlp embed",
    }
    if cfg.moe.n_shared_experts:
        from repro.models.layers import mlp_axes

        axes["shared"] = mlp_axes()
    return axes


def _dispatch_one_row(x_row, idx_row, pos_row, keep_row, n_experts, capacity):
    """x_row (T, D); idx/pos/keep (T, k) -> buffer (E*C, D). vmapped over B."""
    T, D = x_row.shape
    k = idx_row.shape[1]
    # over-capacity assignments are routed to an out-of-bounds sentinel slot
    # and dropped by the scatter (capacity-factor token dropping)
    slot = jnp.where(keep_row, idx_row * capacity + pos_row, n_experts * capacity)
    updates = jnp.repeat(x_row, k, axis=0) * keep_row.reshape(T * k, 1).astype(x_row.dtype)
    buf = jnp.zeros((n_experts * capacity, D), x_row.dtype)
    return buf.at[slot.reshape(T * k)].add(updates, mode="drop")


def _moe_chunk(params, x, cfg: ArchConfig, ctx=None):
    """x: (B, T, D) one sequence chunk -> (out, aux_loss_terms)."""
    m: MoEConfig = cfg.moe
    B, T, D = x.shape
    E, k = m.n_experts, m.top_k
    # ceil, floor 1: at T=1 (decode) a row sends <= 1 token per expert, so
    # C=1 suffices — a floor of k would multiply decode expert compute by k
    capacity = max(-(-int(T * k * m.capacity_factor) // E), 1)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (B, T, E)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each assignment within its expert, via a per-row stable
    # sort over expert ids — O(T*k) memory (a dense (T*k, E) one-hot cumsum
    # would be ~GBs per device at production batch sizes)
    flat_e = idx.reshape(B, T * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)            # (B, T*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left"))(sorted_e)
    ends = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="right"))(sorted_e)
    pos_sorted = (jnp.arange(T * k)[None, :]
                  - jnp.take_along_axis(starts, sorted_e, axis=1))
    inv_order = jnp.argsort(order, axis=1)
    pos = jnp.take_along_axis(pos_sorted, inv_order, axis=1)
    pos = pos.reshape(B, T, k).astype(jnp.int32)
    keep = pos < capacity

    # Switch-style aux loss terms (combined across chunks by the caller)
    token_frac = jnp.mean((ends - starts).astype(jnp.float32), axis=0) / (T * k)
    prob_frac = jnp.mean(probs, axis=(0, 1))                    # (E,)
    aux = E * jnp.sum(token_frac * prob_frac)

    buf = jax.vmap(
        functools.partial(_dispatch_one_row, n_experts=E, capacity=capacity)
    )(x, idx, pos, keep)  # (B, E*C, D)
    buf = buf.reshape(B, E, capacity, D)
    if ctx is not None:
        buf = ctx.shard(buf, "batch act_experts - -")

    h = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])  # (B,E,C,D)
    if ctx is not None:
        out_buf = ctx.shard(out_buf, "batch act_experts - -")

    # combine: gather each assignment's output, weight, sum over k
    out_flat = out_buf.reshape(B, E * capacity, D)
    flat_slot = jnp.minimum(idx * capacity + pos, E * capacity - 1).reshape(B, T * k)
    gathered = jnp.take_along_axis(out_flat, flat_slot[..., None], axis=1)  # (B,T*k,D)
    w = (gate_vals * keep.astype(jnp.float32)).reshape(B, T * k, 1).astype(x.dtype)
    out = jnp.sum((gathered * w).reshape(B, T, k, D), axis=2)
    return out, aux


def apply_moe(params, x: jnp.ndarray, cfg: ArchConfig, *, ctx=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss ())."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    n_chunks = min(m.moe_chunks, S)
    assert S % n_chunks == 0, (S, n_chunks)
    xc = jnp.moveaxis(x.reshape(B, n_chunks, S // n_chunks, D), 1, 0)

    chunk_fn = jax.checkpoint(
        lambda xt: _moe_chunk(params, xt, cfg, ctx=ctx))

    def step(carry, xt):
        out, aux = chunk_fn(xt)
        return carry + aux, out

    aux_total, outs = jax.lax.scan(step, jnp.zeros((), jnp.float32), xc)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, D)

    if m.n_shared_experts:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(params["shared"], x, ctx)
    return out, aux_total / n_chunks * m.router_aux_weight


def moe_decode(params, x: jnp.ndarray, cfg: ArchConfig, *, ctx=None) -> jnp.ndarray:
    """Decode path (T small): dense-gather per token, no capacity games."""
    out, _ = _moe_chunk(params, x, cfg, ctx=ctx)
    if cfg.moe.n_shared_experts:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(params["shared"], x, ctx)
    return out
