"""Mamba2 block (state-space dual / SSD) — zamba2's backbone.

Training/prefill uses the chunked SSD form: within-chunk computation is a
masked attention-like quadratic in the chunk length (MXU-friendly), chunks
are linked by a tiny recurrence over per-chunk states. Decode is the O(1)
recurrent update. ``ssd_chunked`` is the jnp oracle for the Pallas kernel in
kernels/mamba2_ssd.py.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# chunked SSD scan (jnp oracle; matches kernels/ref.ssd_scan sequentially)
# ---------------------------------------------------------------------------


def ssd_core(
    x: jnp.ndarray,     # (B, S, H, P)
    a: jnp.ndarray,     # (B, S, H)  log-decay per step (<= 0)
    mult: jnp.ndarray,  # (B, S, H)  input multiplier (mamba2: dt; mLSTM: i-gate)
    Bm: jnp.ndarray,    # (B, S, G, N)
    Cm: jnp.ndarray,    # (B, S, G, N)
    *,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generalized SSD: h_t = exp(a_t) h_{t-1} + mult_t x_t B_t^T; y_t = h_t C_t."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    with jax.named_scope("xla_ssd_scan"):  # input prep counts as kernel-fused
        xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
        dtf = mult.astype(jnp.float32).reshape(B, nc, chunk, H)
        Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(
            B, nc, chunk, H, N)
        Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(
            B, nc, chunk, H, N)
        a = a.astype(jnp.float32).reshape(B, nc, chunk, H)  # log-decay
    return _ssd_core_body(xf, a, dtf, Bf, Cf, init_state, B, nc, chunk, H, P, N,
                          x.dtype)


def _ssd_core_body(xf, a, dtf, Bf, Cf, init_state, B, nc, chunk, H, P, N, out_dtype):
    return _ssd_scoped(xf, a, dtf, Bf, Cf, init_state, B, nc, chunk, H, P, N,
                       out_dtype)


@jax.named_scope("xla_ssd_scan")
def _ssd_scoped(xf, a, dtf, Bf, Cf, init_state, B, nc, chunk, H, P, N, out_dtype):
    seg = jnp.cumsum(a, axis=2)                      # within-chunk cumulative
    total = seg[:, :, -1, :]                         # (B,nc,H)

    # -- intra-chunk (attention-like, causal) --------------------------------
    # M[i,j] = exp(seg_i - seg_j) * dt_j  for j <= i
    li = seg[:, :, :, None, :]                       # (B,nc,L,1,H)
    lj = seg[:, :, None, :, :]                       # (B,nc,1,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # clamp BEFORE exp: the masked (j > i) entries have positive exponents
    # whose exp overflows — where() would keep the NaN in the gradient
    diff = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)      # (B,nc,L,L,H)
    scores = jnp.einsum("bclhn,bcmhn->bclmh", Cf, Bf)  # (B,nc,L,L,H)
    M = scores * decay * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xf)

    # -- chunk states ----------------------------------------------------------
    # S_c = sum_j exp(total - seg_j) dt_j B_j x_j^T   (B,nc,H,P,N)
    # NOTE: reassociated two-step — a 3-operand einsum can materialize the
    # (B,nc,L,H,P,N) outer product (~275 GB/layer at xLSTM head widths)
    w = jnp.exp(total[:, :, None, :] - seg) * dtf    # (B,nc,L,H)
    wx = xf * w[..., None]                           # (B,nc,L,H,P)
    states = jnp.einsum("bclhp,bclhn->bchpn", wx, Bf)

    # -- inter-chunk recurrence -------------------------------------------------
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def step(h, inputs):
        st, tot = inputs  # (B,H,P,N), (B,H)
        h_prev = h
        h = h * jnp.exp(tot)[:, :, None, None] + st
        return h, h_prev

    (hT, h_prevs) = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # (B,nc,H,P,N) state before chunk

    # -- inter-chunk contribution to outputs (reassociated, see above) -----------
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Cf, h_prevs) \
        * jnp.exp(seg)[..., None]
    y = (y_intra + y_inter).reshape(B, nc * chunk, H, P)
    return y.astype(out_dtype), hT


def ssd_chunked(
    x: jnp.ndarray,    # (B, S, H, P)
    dt: jnp.ndarray,   # (B, S, H) positive
    A: jnp.ndarray,    # (H,) negative
    Bm: jnp.ndarray,   # (B, S, G, N)
    Cm: jnp.ndarray,   # (B, S, G, N)
    *,
    init_state: Optional[jnp.ndarray] = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD: log-decay a = A*dt, input multiplier = dt."""
    a = A.astype(jnp.float32)[None, None, :] * dt.astype(jnp.float32)
    return ssd_core(x, a, dt, Bm, Cm, init_state=init_state, chunk=chunk)


def ssd_decode_step(
    h: jnp.ndarray,    # (B, H, P, N)
    x: jnp.ndarray,    # (B, H, P)
    dt: jnp.ndarray,   # (B, H)
    A: jnp.ndarray,    # (H,)
    Bm: jnp.ndarray,   # (B, G, N)
    Cm: jnp.ndarray,   # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    H, G = x.shape[1], Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(A.astype(jnp.float32)[None, :] * dt.astype(jnp.float32))
    h = h * decay[..., None, None] + (
        (dt.astype(jnp.float32)[..., None] * x.astype(jnp.float32))[..., None]
        * Bh[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# full Mamba2 block (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------


def _dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: ArchConfig):
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    dtype = cfg.dtype
    ks = jax.random.split(key, 6)
    # in_proj emits [z (gate), x, B, C, dt] fused
    proj_out = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype),
    }


def mamba2_axes():
    return {
        "in_proj": "embed ssm_inner",
        "conv_w": "conv -", "conv_b": "-",
        "A_log": "ssm_heads", "D": "ssm_heads", "dt_bias": "ssm_heads",
        "norm_w": "ssm_inner",
        "out_proj": "ssm_inner embed",
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    s, d_inner, n_heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gn], axis=-1)
    return z, xbc, dt  # gate, conv input, dt logits


def _causal_conv(xbc: jnp.ndarray, conv_w, conv_b, *, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq. xbc (B,S,C); state (B, d_conv-1, C)."""
    K = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu((out + conv_b[None, None, :]).astype(jnp.float32)).astype(
        xbc.dtype
    ), new_state


def apply_mamba2(params, x: jnp.ndarray, cfg: ArchConfig, *, ctx=None) -> jnp.ndarray:
    s, d_inner, n_heads, _ = _dims(cfg)
    B, S, d = x.shape
    gn = s.n_groups * s.d_state
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"])
    if ctx is not None:
        proj = ctx.shard(proj, "batch - act_mlp")
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, _ = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(B, S, n_heads, s.head_dim)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    from repro.kernels import ops as kops

    y, _ = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=s.chunk)
    y = y + xs * params["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (mamba2 style)
    from repro.models.layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_w"], cfg.rms_eps)
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"])


def mamba2_decode(params, x: jnp.ndarray, cfg: ArchConfig, cache: dict, *, ctx=None
                  ) -> Tuple[jnp.ndarray, dict]:
    """x (B,1,d); cache {conv: (B,K-1,convdim), ssm: (B,H,P,N)}."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    B = x.shape[0]
    gn = s.n_groups * s.d_state
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], state=cache["conv"]
    )
    xs, Bm, Cm = jnp.split(xbc[:, 0], [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(B, n_heads, s.head_dim)
    Bm = Bm.reshape(B, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])
    y, ssm_state = ssd_decode_step(cache["ssm"].astype(jnp.float32), xs, dt, A, Bm, Cm)
    y = y + xs * params["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, 1, d_inner)
    from repro.models.layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_w"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": ssm_state}


def mamba2_cache_spec(cfg: ArchConfig, batch: int):
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), cfg.dtype),
        "ssm": jax.ShapeDtypeStruct((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def mamba2_cache_axes():
    return {"conv": "kv_batch - act_mlp", "ssm": "kv_batch ssm_heads - -"}
