"""Versioned algorithm-state records persisted in study metadata (paper §6.3).

The paper's metadata mechanism exists so "algorithms may store state in the
database" and resume cheaply across stateless Pythia invocations. This module
defines the GP-bandit's state record and the namespace conventions around it:

* Namespaces starting with ``repro.`` are RESERVED for built-in policy state;
  user code must not write them (see ROADMAP "Algorithm-state persistence").
  The GP bandit owns ``repro.gp_bandit`` and stores one JSON blob under the
  key ``state``.
* Records are versioned (``STATE_SCHEMA_VERSION``). Any change to the field
  set or semantics bumps the version; loaders treat an unknown version as a
  cold start, never as an error.
* Loading is defensive end to end: a corrupt, truncated, version-skewed,
  dimension-mismatched or otherwise hostile blob yields ``None`` (cold fit),
  never an exception that could fail a suggestion operation.

The record carries the raw kernel hyperparameters, the Adam moments and step
count (so the fit resumes mid-trajectory, not just from a good point), a
trial-count fingerprint guarding against a rewound datastore, and — since v3
— the fitted hyperparameters of every PRIOR stack level, each keyed by its
(study name, aligned-trial count) fingerprint, so transfer operations skip
the per-prior Adam refit for the longest still-matching prefix
(``load_prior_levels``).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.core.metadata import Metadata, MetadataDelta, MetadataValue, Namespace

# Reserved namespace prefix for built-in policy state. Stateless policies
# (random/grid search, CMA-ES, ...) never write under it.
RESERVED_NAMESPACE_PREFIX = "repro."

GP_BANDIT_NAMESPACE = "repro.gp_bandit"
STATE_KEY = "state"
# v2 (transfer learning): adds ``prior_fingerprints`` — aligned-trial counts
# per prior study at fit time. The persisted trajectory is the TOP (residual)
# level of the stack, so any change in the prior data it was fit against
# (priors grew, shrank, or the prior list changed) invalidates it.
# v3 (prior-level checkpoints): adds ``prior_levels`` — the ordered fitted
# hyperparameters of each PRIOR stack level, keyed by (study name,
# aligned-trial count). Unlike the top-level trajectory (exact-fingerprint
# reuse), prior levels reuse PREFIX-wise: level i's residual targets depend
# only on levels 0..i-1, so the longest matching prefix skips its Adam
# refits (~60ms/op per prior) even when a later prior changed. Per the
# version-bump policy (ROADMAP), v1/v2 blobs are treated as a cold start.
# v4 (multi-metric): adds ``metric_states`` — one ordered
# {"name", "raw", "adam_m", "adam_v"} entry per objective metric for
# multi-metric studies (one GP per metric shares the blob's adam_t clock;
# metric 0's trajectory is ALSO the top-level raw/adam_m/adam_v, keeping
# the required-field validation identical for both study kinds).
# Single-objective studies write ``metric_states == []``. v3 blobs are a
# cold start, and a multi-metric blob is incompatible with the
# single-objective path (and vice versa) — see check_compatible /
# load_metric_states.
STATE_SCHEMA_VERSION = 4
GP_BANDIT_ALGORITHM = "gp_bandit"

# The hyperparameter tree layout shared by raw params and Adam moments:
# key -> None for scalars, "dim" for (d,)-shaped vectors.
_TREE_SHAPE = {"log_amp": None, "log_ell": "dim", "log_noise": None}


class StateDecodeError(Exception):
    """The stored blob is absent, corrupt, or incompatible (fall back cold)."""


def _as_finite_float(name: str, value: Any) -> float:
    try:
        f = float(value)
    except (TypeError, ValueError) as e:
        raise StateDecodeError(f"{name}: not a number ({value!r})") from e
    if not math.isfinite(f):
        raise StateDecodeError(f"{name}: non-finite value {f!r}")
    return f


def _validate_tree(name: str, tree: Any, dim: int) -> Dict[str, Union[float, List[float]]]:
    if not isinstance(tree, dict):
        raise StateDecodeError(f"{name}: expected an object, got {type(tree).__name__}")
    out: Dict[str, Union[float, List[float]]] = {}
    for key, shape in _TREE_SHAPE.items():
        if key not in tree:
            raise StateDecodeError(f"{name}: missing key {key!r}")
        value = tree[key]
        if shape == "dim":
            if not isinstance(value, (list, tuple)) or len(value) != dim:
                raise StateDecodeError(
                    f"{name}.{key}: expected a length-{dim} vector, got {value!r}")
            out[key] = [_as_finite_float(f"{name}.{key}[{i}]", v)
                        for i, v in enumerate(value)]
        else:
            out[key] = _as_finite_float(f"{name}.{key}", value)
    return out


def _tree_to_py(tree: Dict[str, Any]) -> Dict[str, Union[float, List[float]]]:
    """jax/numpy hyperparameter tree -> JSON-able floats/lists."""
    out: Dict[str, Union[float, List[float]]] = {}
    for key, shape in _TREE_SHAPE.items():
        arr = np.asarray(tree[key], dtype=np.float64)
        out[key] = arr.tolist() if shape == "dim" else float(arr)
    return out


@dataclasses.dataclass
class PolicyState:
    """One fitted-GP checkpoint: hyperparameters + optimizer trajectory.

    ``num_trials`` is the completed-trial fingerprint at fit time; a stored
    fingerprint LARGER than the current count means the datastore was rewound
    (trials deleted) and the state is stale. ``steps_run``/``warm_started``/
    ``converged`` are observability fields used by tests and benchmarks.
    """

    dim: int
    num_trials: int
    raw: Dict[str, Union[float, List[float]]]
    adam_m: Dict[str, Union[float, List[float]]]
    adam_v: Dict[str, Union[float, List[float]]]
    adam_t: int
    steps_run: int = 0
    warm_started: bool = False
    converged: bool = False
    # study name -> number of aligned prior trials the stack was fit on (v2)
    prior_fingerprints: Dict[str, int] = dataclasses.field(default_factory=dict)
    # ordered PRIOR stack levels (v3): [{"name", "num_trials", "raw"}, ...];
    # the raw hyperparameters of level i are valid iff priors 0..i all still
    # fingerprint-match (prefix reuse, see load_prior_levels)
    prior_levels: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    # per-metric GP trajectories (v4): ordered
    # [{"name", "raw", "adam_m", "adam_v"}, ...] for multi-metric studies
    # (adam_t is shared — the metrics step in lockstep through one vmapped
    # fit); [] for single-objective studies
    metric_states: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    version: int = STATE_SCHEMA_VERSION
    algorithm: str = GP_BANDIT_ALGORITHM

    # -- serialization -------------------------------------------------------
    def to_value(self) -> str:
        return json.dumps({
            "version": self.version,
            "algorithm": self.algorithm,
            "dim": self.dim,
            "num_trials": self.num_trials,
            "raw": self.raw,
            "adam_m": self.adam_m,
            "adam_v": self.adam_v,
            "adam_t": self.adam_t,
            "steps_run": self.steps_run,
            "warm_started": self.warm_started,
            "converged": self.converged,
            "prior_fingerprints": dict(self.prior_fingerprints),
            "prior_levels": [dict(lvl) for lvl in self.prior_levels],
            "metric_states": [dict(ms) for ms in self.metric_states],
        })

    @classmethod
    def from_value(cls, value: Optional[MetadataValue]) -> "PolicyState":
        """Strict decode; raises StateDecodeError on anything suspect."""
        if value is None:
            raise StateDecodeError("no stored state")
        if isinstance(value, bytes):
            try:
                value = value.decode("utf-8")
            except UnicodeDecodeError as e:
                raise StateDecodeError(f"undecodable bytes: {e}") from e
        try:
            obj = json.loads(value)
        except (json.JSONDecodeError, TypeError) as e:
            raise StateDecodeError(f"not valid JSON: {e}") from e
        if not isinstance(obj, dict):
            raise StateDecodeError(f"expected an object, got {type(obj).__name__}")
        version = obj.get("version")
        if version != STATE_SCHEMA_VERSION:
            raise StateDecodeError(
                f"schema version skew: stored {version!r}, "
                f"supported {STATE_SCHEMA_VERSION}")
        algorithm = obj.get("algorithm")
        dim = obj.get("dim")
        if not isinstance(dim, int) or dim <= 0:
            raise StateDecodeError(f"bad dim {dim!r}")
        num_trials = obj.get("num_trials")
        if not isinstance(num_trials, int) or num_trials < 0:
            raise StateDecodeError(f"bad num_trials {num_trials!r}")
        adam_t = obj.get("adam_t")
        if not isinstance(adam_t, int) or adam_t < 0:
            raise StateDecodeError(f"bad adam_t {adam_t!r}")
        try:
            steps_run = int(obj.get("steps_run", 0))
        except (TypeError, ValueError) as e:
            raise StateDecodeError(f"bad steps_run {obj.get('steps_run')!r}") from e
        pf = obj.get("prior_fingerprints", {})
        if not isinstance(pf, dict):
            raise StateDecodeError(f"bad prior_fingerprints {pf!r}")
        prior_fingerprints: Dict[str, int] = {}
        for k, v in pf.items():
            if not isinstance(k, str) or not isinstance(v, int) or \
                    isinstance(v, bool) or v < 0:
                raise StateDecodeError(f"bad prior_fingerprints entry {k!r}: {v!r}")
            prior_fingerprints[k] = v
        pl = obj.get("prior_levels", [])
        if not isinstance(pl, list):
            raise StateDecodeError(f"bad prior_levels {pl!r}")
        prior_levels: List[Dict[str, Any]] = []
        for i, lvl in enumerate(pl):
            if not isinstance(lvl, dict):
                raise StateDecodeError(f"prior_levels[{i}]: not an object")
            name = lvl.get("name")
            nt = lvl.get("num_trials")
            if not isinstance(name, str):
                raise StateDecodeError(f"prior_levels[{i}].name: {name!r}")
            if not isinstance(nt, int) or isinstance(nt, bool) or nt < 0:
                raise StateDecodeError(f"prior_levels[{i}].num_trials: {nt!r}")
            prior_levels.append({
                "name": name,
                "num_trials": nt,
                "raw": _validate_tree(f"prior_levels[{i}].raw",
                                      lvl.get("raw"), dim),
            })
        ms = obj.get("metric_states", [])
        if not isinstance(ms, list):
            raise StateDecodeError(f"bad metric_states {ms!r}")
        if len(ms) == 1:
            raise StateDecodeError(
                "metric_states with exactly one entry: multi-metric records "
                "need k >= 2 metrics, single-objective records need []")
        metric_states: List[Dict[str, Any]] = []
        for i, entry in enumerate(ms):
            if not isinstance(entry, dict):
                raise StateDecodeError(f"metric_states[{i}]: not an object")
            name = entry.get("name")
            if not isinstance(name, str) or not name:
                raise StateDecodeError(f"metric_states[{i}].name: {name!r}")
            metric_states.append({
                "name": name,
                "raw": _validate_tree(f"metric_states[{i}].raw",
                                      entry.get("raw"), dim),
                "adam_m": _validate_tree(f"metric_states[{i}].adam_m",
                                         entry.get("adam_m"), dim),
                "adam_v": _validate_tree(f"metric_states[{i}].adam_v",
                                         entry.get("adam_v"), dim),
            })
        return cls(
            dim=dim,
            num_trials=num_trials,
            raw=_validate_tree("raw", obj.get("raw"), dim),
            adam_m=_validate_tree("adam_m", obj.get("adam_m"), dim),
            adam_v=_validate_tree("adam_v", obj.get("adam_v"), dim),
            adam_t=adam_t,
            steps_run=steps_run,
            warm_started=bool(obj.get("warm_started", False)),
            converged=bool(obj.get("converged", False)),
            prior_fingerprints=prior_fingerprints,
            prior_levels=prior_levels,
            metric_states=metric_states,
            version=version,
            algorithm=str(algorithm),
        )

    # -- use -----------------------------------------------------------------
    def check_compatible(self, *, dim: int, num_trials: int,
                         algorithm: str = GP_BANDIT_ALGORITHM,
                         prior_fingerprints: Optional[Dict[str, int]] = None,
                         metric_names: Optional[List[str]] = None,
                         ) -> None:
        """``metric_names=None`` is the single-objective path: a blob carrying
        per-metric trajectories belongs to a different (multi-metric) study
        shape and is rejected. The multi-metric path passes the ordered
        objective names and requires an exact match — a renamed, reordered,
        added or dropped metric changes every fit target."""
        stored_names = [ms["name"] for ms in self.metric_states]
        if metric_names is None:
            if stored_names:
                raise StateDecodeError(
                    f"multi-metric state ({stored_names!r}) on the "
                    "single-objective path")
        elif stored_names != list(metric_names):
            raise StateDecodeError(
                f"metric skew: stored {stored_names!r}, "
                f"study has {list(metric_names)!r}")
        if self.algorithm != algorithm:
            raise StateDecodeError(
                f"algorithm mismatch: stored {self.algorithm!r}, want {algorithm!r}")
        if self.dim != dim:
            raise StateDecodeError(
                f"dimension mismatch: stored {self.dim}, search space has {dim}")
        if self.num_trials > num_trials:
            raise StateDecodeError(
                f"stale fingerprint: stored num_trials={self.num_trials} > "
                f"current {num_trials} (datastore rewound?)")
        # the persisted trajectory is the TOP of the residual stack: any
        # change in the prior data underneath it (a prior grew, vanished, or
        # the list changed) makes the residual targets different, so the
        # checkpoint must be discarded — exact equality required
        if dict(self.prior_fingerprints) != dict(prior_fingerprints or {}):
            raise StateDecodeError(
                f"prior-study fingerprint skew: stored "
                f"{self.prior_fingerprints!r} != current {prior_fingerprints!r}")

    def fit_init(self) -> Dict[str, Any]:
        """The warm-start init accepted by GaussianProcessBandit.fit."""
        return {"raw": self.raw, "adam_m": self.adam_m, "adam_v": self.adam_v,
                "adam_t": self.adam_t}

    def metric_fit_init(self) -> Dict[str, Any]:
        """The warm-start init accepted by MultiMetricGP.fit: per-metric
        trees in metric order plus the shared Adam clock."""
        return {"raws": [ms["raw"] for ms in self.metric_states],
                "adam_m": [ms["adam_m"] for ms in self.metric_states],
                "adam_v": [ms["adam_v"] for ms in self.metric_states],
                "adam_t": self.adam_t}

    @classmethod
    def from_fit(cls, info, *, dim: int, num_trials: int,
                 prior_fingerprints: Optional[Dict[str, int]] = None,
                 prior_levels: Optional[List] = None,
                 metric_states: Optional[List] = None,
                 ) -> "PolicyState":
        """Builds the record from a GaussianProcessBandit FitInfo.

        ``prior_levels``: ordered [(study name, aligned-trial count, raw
        hyperparameter tree), ...] for the fitted PRIOR stack levels.
        ``metric_states``: ordered [(metric name, raw, adam_m, adam_v), ...]
        per-metric trajectories for multi-metric studies (``info`` must then
        be metric 0's view, so the top-level fields mirror the first entry).
        """
        return cls(
            dim=dim,
            num_trials=num_trials,
            raw=_tree_to_py(info.raw),
            adam_m=_tree_to_py(info.m),
            adam_v=_tree_to_py(info.v),
            adam_t=info.t,
            steps_run=info.steps_run,
            warm_started=info.warm,
            converged=info.converged,
            prior_fingerprints=dict(prior_fingerprints or {}),
            prior_levels=[
                {"name": name, "num_trials": int(nt), "raw": _tree_to_py(raw)}
                for name, nt, raw in (prior_levels or [])
            ],
            metric_states=[
                {"name": name, "raw": _tree_to_py(raw),
                 "adam_m": _tree_to_py(m), "adam_v": _tree_to_py(v)}
                for name, raw, m, v in (metric_states or [])
            ],
        )


def load_state(metadata: Metadata, *, dim: int, num_trials: int,
               prior_fingerprints: Optional[Dict[str, int]] = None,
               namespace: str = GP_BANDIT_NAMESPACE) -> Optional[PolicyState]:
    """Reads + validates the stored state; ``None`` on ANY problem.

    This is the only entry point policies use at suggest time, so it must
    never raise: a hostile or stale blob degrades to a cold fit.
    """
    try:
        value = metadata.abs_ns(Namespace(namespace)).get(STATE_KEY)
        state = PolicyState.from_value(value)
        state.check_compatible(dim=dim, num_trials=num_trials,
                               prior_fingerprints=prior_fingerprints)
        return state
    except StateDecodeError:
        return None
    except Exception:  # noqa: BLE001 — a bad blob must never fail a suggest
        return None


def load_metric_states(metadata: Metadata, *, dim: int, num_trials: int,
                       metric_names: List[str],
                       namespace: str = GP_BANDIT_NAMESPACE,
                       ) -> Optional[PolicyState]:
    """Multi-metric counterpart of ``load_state``: the stored record must
    carry one trajectory per objective metric, names matching in order
    (plus all the usual dim / fingerprint / algorithm checks). Returns the
    whole PolicyState — the warm fit consumes ``metric_states`` for the
    per-metric trees and the top-level ``adam_t`` as the shared clock.
    ``None`` on ANY problem (cold fit), never an exception.
    """
    try:
        value = metadata.abs_ns(Namespace(namespace)).get(STATE_KEY)
        state = PolicyState.from_value(value)
        state.check_compatible(dim=dim, num_trials=num_trials,
                               metric_names=list(metric_names))
        return state
    except StateDecodeError:
        return None
    except Exception:  # noqa: BLE001 — a bad blob must never fail a suggest
        return None


def load_prior_levels(metadata: Metadata, *, dim: int,
                      priors: "List[tuple]",
                      namespace: str = GP_BANDIT_NAMESPACE) -> List[Dict]:
    """Reusable prior-level hyperparameters for the longest matching prefix.

    ``priors`` is the ordered [(study name, aligned-trial count), ...] the
    policy is about to fit. Level i's stored hyperparameters are reusable
    iff every stored level 0..i matches the current (name, count) — a
    mismatch invalidates that level AND everything above it (residual
    targets downstream change), but never the prefix below. Unlike
    ``load_state`` this deliberately ignores the top-level fingerprint:
    prior levels stay reusable even when the current study gained trials.

    Defensive like load_state: any problem yields ``[]`` (refit all
    levels), never an exception.
    """
    try:
        value = metadata.abs_ns(Namespace(namespace)).get(STATE_KEY)
        state = PolicyState.from_value(value)
        if state.algorithm != GP_BANDIT_ALGORITHM or state.dim != dim:
            return []
        out: List[Dict] = []
        for i, (name, count) in enumerate(priors):
            if i >= len(state.prior_levels):
                break
            stored = state.prior_levels[i]
            if stored["name"] != name or stored["num_trials"] != int(count):
                break
            out.append(stored["raw"])
        return out
    except StateDecodeError:
        return []
    except Exception:  # noqa: BLE001 — a bad blob must never fail a suggest
        return []


def store_state(delta: MetadataDelta, state: PolicyState,
                namespace: str = GP_BANDIT_NAMESPACE) -> None:
    """Writes the record into a policy's outgoing MetadataDelta."""
    delta.assign(namespace, STATE_KEY, state.to_value())
