"""Factorized-posterior acquisition engine: one Cholesky per suggest op.

The GP-bandit acquisition used to re-run an O(n^3) Cholesky of the SAME
K(X, X) once per candidate-pool scoring, once per batch member, once per
fantasy vector and once per stack level — and every distinct
(n_trials, pool_size) shape retraced the jitted kernels. This module is the
replacement hot path:

* ``CholeskyPosterior`` factorizes K(X, X) + noise·I exactly once (reusing
  the fit's final hyperparameters, so the factorization built right after
  the fit serves every later query of the operation) and answers all
  mean/std/UCB queries from the cached (L, w = L^-1 y).
* Batch members and fantasized pending points extend the factorization with
  O(n^2) rank-1 ``append`` updates (a new Cholesky row via one triangular
  solve) instead of refactorizing from scratch; when a candidate pool is
  attached, each append also folds its new cross-row into the cached pool
  mean/variance in O(n·m), so a count-k batch costs one factorization + one
  pool solve + k rank-1 updates rather than k full refactorizations.
* All device buffers are padded to power-of-two buckets with noise-masked
  padding rows (padding contributes an identity block to K and zeros to
  every cross term, so results are exact, not approximate), which keeps the
  jitted kernel shapes constant across operations: steady-state suggest ops
  stop retracing. ``TRACE_COUNTS`` counts actual retraces for the
  regression test.

Bucket rules (documented in ROADMAP): training/design buffers round up to
the next power of two with a floor of ``MIN_TRAIN_BUCKET`` (64); candidate
pools round up to multiples of ``POOL_BUCKET_STEP`` (256). The capacity
bucket is chosen once per operation with headroom for every planned append
(pending fantasies + batch count), so a suggest op never re-buckets
mid-flight; ``append`` past capacity refuses loudly instead of silently
refactorizing.

This dense engine is the DEFAULT and the exactness oracle: it serves every
study at or below ``sparse_posterior.SPARSE_THRESHOLD`` design rows.
Strictly above the threshold ``StackedResidualGP.fit_level`` builds the
drop-in ``sparse_posterior.SparsePosterior`` instead — an SGPR
inducing-point factorization whose per-op cost is O(n·m^2) against an m×m
inducing factor rather than O(n^3). Both classes expose the same
set_pool/append/append_pool_member/query interface, keep the same bucket
and retrace invariants, and share ``TRACE_COUNTS``.

The duplicate-append pivot: a rank-1 ``append`` of a point (near-)identical
to an existing design row has a true Schur complement of ~2·noise, never 0;
the pivot is floored at the fitted noise variance so the whitened
observation cannot explode (see ``_append_row``).
"""

from __future__ import annotations

import collections
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

# Incremented inside the traced bodies below — a counter ticks only when XLA
# actually (re)traces the kernel, so tests can pin "no retraces across ops".
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()

MIN_TRAIN_BUCKET = 64
POOL_BUCKET_STEP = 256

_JITTER = 1e-4  # matches the fit's noise floor (gp_bandit._neg_mll)


def reset_trace_counts() -> None:
    TRACE_COUNTS.clear()


def train_bucket(n: int) -> int:
    """Next power-of-two >= n, floored at MIN_TRAIN_BUCKET."""
    b = MIN_TRAIN_BUCKET
    while b < n:
        b *= 2
    return b


def pool_bucket(m: int) -> int:
    """Next multiple of POOL_BUCKET_STEP >= m (pow-2 buckets would waste up
    to 2x solve work on pools that are ~fixed-size per policy config)."""
    return max(POOL_BUCKET_STEP,
               ((m + POOL_BUCKET_STEP - 1) // POOL_BUCKET_STEP)
               * POOL_BUCKET_STEP)


def _scaled(raw: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.exp(raw["log_ell"])


def _gram(raw: Dict, x1: jnp.ndarray, x2: jnp.ndarray) -> jnp.ndarray:
    return kops.matern52_gram(_scaled(raw, x1), _scaled(raw, x2),
                              jnp.exp(raw["log_amp"]), impl="auto")


@jax.jit
def _factor(raw: Dict, xp: jnp.ndarray, yp: jnp.ndarray, mask: jnp.ndarray):
    """(L, w) of the masked-padded kernel matrix; the op's ONE Cholesky.

    Padding rows (mask 0) contribute an identity block: their K rows/cols
    are zeroed and the diagonal set to 1, so L embeds the real factor
    exactly and w is zero on padding (yp is zero there).
    """
    TRACE_COUNTS["factor"] += 1
    noise = jnp.exp(raw["log_noise"]) + _JITTER
    K = _gram(raw, xp, xp) * (mask[:, None] * mask[None, :])
    K = K + jnp.diag(noise * mask + (1.0 - mask))
    L = jnp.linalg.cholesky(K)
    w = jax.scipy.linalg.solve_triangular(L, yp, lower=True)
    return L, w


@jax.jit
def _alpha(L: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """alpha = K^-1 y from the cached factor: one O(n^2) back-solve."""
    TRACE_COUNTS["alpha"] += 1
    return jax.scipy.linalg.solve_triangular(L.T, w, lower=False)


def _cross_solve(raw: Dict, xp: jnp.ndarray, mask: jnp.ndarray,
                 L: jnp.ndarray, w: jnp.ndarray, xqp: jnp.ndarray):
    """Shared cross-solve body (traced inside the jitted wrappers):
    V = L^-1 Kq, mean = V^T w, var = amp - colsum(V^2)."""
    Kq = _gram(raw, xp, xqp) * mask[:, None]          # (B, M)
    V = jax.scipy.linalg.solve_triangular(L, Kq, lower=True)
    mean = V.T @ w
    var = jnp.exp(raw["log_amp"]) - jnp.sum(V * V, axis=0)
    return V, mean, var


def _append_row(raw: Dict, L: jnp.ndarray, xp: jnp.ndarray,
                mask: jnp.ndarray, w: jnp.ndarray, xn: jnp.ndarray,
                yn: jnp.ndarray):
    """Shared rank-1 append body: the new Cholesky row l = L^-1 k with pivot
    sqrt(k_ss - l·l), and the new w entry. Padding rows keep their identity
    block (their k entries are masked to 0), so later appends remain exact.
    """
    amp = jnp.exp(raw["log_amp"])
    noise = jnp.exp(raw["log_noise"]) + _JITTER
    k = _gram(raw, xp, xn[None, :])[:, 0] * mask          # (B,)
    l = jax.scipy.linalg.solve_triangular(L, k, lower=True)
    # Pivot floored at the NOISE scale, not machine epsilon: appending a
    # near-duplicate of an existing row drives the Schur complement toward
    # its analytic limit of ~2*noise (independent observation noise keeps
    # the augmented matrix well-conditioned), but f32 roundoff can push the
    # computed value far below it — with a 1e-10 floor the pivot collapses
    # to 1e-5 and wn = (yn - l.w)/lss explodes, poisoning the cached pool
    # mean/var for the rest of the operation.
    lss = jnp.sqrt(jnp.maximum(amp + noise - jnp.dot(l, l), noise))
    wn = (yn - jnp.dot(l, w)) / lss
    return l, lss, wn


def _rescore_row(raw: Dict, V: jnp.ndarray, xqp: jnp.ndarray,
                 xn: jnp.ndarray, l: jnp.ndarray, lss: jnp.ndarray):
    """Shared pool-refresh body: the appended row's cross-solve extension
    r = (k_q - l^T V) / lss, folding into mean/var in O(m)."""
    kq = _gram(raw, xn[None, :], xqp)[0]                  # (M,)
    return (kq - l @ V) / lss


@jax.jit
def _attach_pool(raw: Dict, xp: jnp.ndarray, mask: jnp.ndarray,
                 L: jnp.ndarray, w: jnp.ndarray, xqp: jnp.ndarray):
    """Cross-solve for a candidate pool, cached so rank-1 appends can
    update mean/var in O(m) without another solve."""
    TRACE_COUNTS["attach_pool"] += 1
    return _cross_solve(raw, xp, mask, L, w, xqp)


@jax.jit
def _query(raw: Dict, xp: jnp.ndarray, mask: jnp.ndarray, L: jnp.ndarray,
           w: jnp.ndarray, xqp: jnp.ndarray):
    """One-shot posterior (mean, std) at arbitrary padded query points."""
    TRACE_COUNTS["query"] += 1
    _V, mean, var = _cross_solve(raw, xp, mask, L, w, xqp)
    return mean, jnp.sqrt(jnp.maximum(var, 1e-10))


@jax.jit
def _append(raw: Dict, L: jnp.ndarray, xp: jnp.ndarray, yp: jnp.ndarray,
            mask: jnp.ndarray, w: jnp.ndarray, idx: jnp.ndarray,
            xn: jnp.ndarray, yn: jnp.ndarray):
    """Rank-1 Cholesky append at (traced) row ``idx``: O(n^2), no retrace."""
    TRACE_COUNTS["append"] += 1
    l, lss, wn = _append_row(raw, L, xp, mask, w, xn, yn)
    return (L.at[idx, :].set(l).at[idx, idx].set(lss),
            xp.at[idx].set(xn), yp.at[idx].set(yn),
            mask.at[idx].set(1.0), w.at[idx].set(wn), l, lss, wn)


@jax.jit
def _rescore(raw: Dict, V: jnp.ndarray, mean: jnp.ndarray, var: jnp.ndarray,
             xqp: jnp.ndarray, idx: jnp.ndarray, xn: jnp.ndarray,
             l: jnp.ndarray, lss: jnp.ndarray, wn: jnp.ndarray):
    """Fold one appended row into the cached pool posterior: O(n·m)."""
    TRACE_COUNTS["rescore"] += 1
    r = _rescore_row(raw, V, xqp, xn, l, lss)
    return (V.at[idx, :].set(r), mean + r * wn, var - r * r)


@jax.jit
def _append_member(raw: Dict, L: jnp.ndarray, xp: jnp.ndarray,
                   yp: jnp.ndarray, mask: jnp.ndarray, w: jnp.ndarray,
                   V: jnp.ndarray, mean: jnp.ndarray, var: jnp.ndarray,
                   xqp: jnp.ndarray, idx: jnp.ndarray, pool_i: jnp.ndarray):
    """Fused batch-member append: pool point ``pool_i`` conditioned at its
    CURRENT cached posterior mean, factor + pool stats updated in ONE
    dispatch with zero host round-trips (the suggest count-loop hot path).
    Same math as ``_append`` + ``_rescore`` via the shared bodies.
    """
    TRACE_COUNTS["append_member"] += 1
    xn = xqp[pool_i]
    yn = mean[pool_i]
    l, lss, wn = _append_row(raw, L, xp, mask, w, xn, yn)
    r = _rescore_row(raw, V, xqp, xn, l, lss)
    return (L.at[idx, :].set(l).at[idx, idx].set(lss),
            xp.at[idx].set(xn), yp.at[idx].set(yn), mask.at[idx].set(1.0),
            w.at[idx].set(wn), V.at[idx, :].set(r), mean + r * wn,
            var - r * r)


@jax.jit
def _pool_scores(mean: jnp.ndarray, var: jnp.ndarray,
                 beta: jnp.ndarray) -> jnp.ndarray:
    TRACE_COUNTS["pool_scores"] += 1
    return mean + beta * jnp.sqrt(jnp.maximum(var, 1e-10))


@jax.jit
def _pool_mean_std(mean: jnp.ndarray, var: jnp.ndarray) -> jnp.ndarray:
    """Stacked (2, M) [mean; std] so both pool statistics cross the host
    boundary in ONE sync — the multi-metric scalarized acquisition needs
    mean AND std per metric, and separate pool_mean()/pool_std() calls
    would double the per-metric transfer count. Shape depends only on the
    pool bucket, so every metric's posterior reuses the same compilation."""
    TRACE_COUNTS["pool_mean_std"] += 1
    return jnp.stack([mean, jnp.sqrt(jnp.maximum(var, 1e-10))])


class CholeskyPosterior:
    """Cached-factorization GP posterior for one suggest operation.

    Factorizes once at construction; every later query (pool scores, point
    posteriors, UCB, batch/fantasy extensions) reuses (L, w). ``capacity``
    reserves append headroom so the whole operation lives in one bucket.
    """

    def __init__(self, raw: Dict, x, y, *, capacity: Optional[int] = None):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        n, d = x.shape
        self.raw = {k: jnp.asarray(v, jnp.float32) for k, v in raw.items()}
        self.capacity = train_bucket(max(capacity or n, n))
        self.n = n
        xp = np.zeros((self.capacity, d), np.float32)
        yp = np.zeros((self.capacity,), np.float32)
        mask = np.zeros((self.capacity,), np.float32)
        xp[:n], yp[:n], mask[:n] = x, y, 1.0
        self._xp = jnp.asarray(xp)
        self._yp = jnp.asarray(yp)
        self._mask = jnp.asarray(mask)
        self._L, self._w = _factor(self.raw, self._xp, self._yp, self._mask)
        self._alpha_cache: Optional[jnp.ndarray] = None
        # attached candidate pool (set_pool): padded xq + cached solve
        self._xqp: Optional[jnp.ndarray] = None
        self._m = 0
        self._V = self._pool_mean = self._pool_var = None

    # -- whole-pool scoring --------------------------------------------------
    def set_pool(self, xq) -> None:
        """Attach a candidate pool: ONE cross-solve, cached for the op."""
        xq = np.asarray(xq, np.float32)
        m = xq.shape[0]
        mb = pool_bucket(m)
        xqp = np.zeros((mb, xq.shape[1]), np.float32)
        xqp[:m] = xq
        self._xqp = jnp.asarray(xqp)
        self._m = m
        self._V, self._pool_mean, self._pool_var = _attach_pool(
            self.raw, self._xp, self._mask, self._L, self._w, self._xqp)

    def pool_mean(self) -> np.ndarray:
        return np.asarray(self._pool_mean)[: self._m]

    def pool_std(self) -> np.ndarray:
        var = np.asarray(self._pool_var)[: self._m]
        return np.sqrt(np.maximum(var, 1e-10))

    def pool_ucb(self, beta: float) -> np.ndarray:
        """mean + beta*std for the attached pool: one fused device op and
        ONE host sync (the count-loop's only per-member transfer)."""
        return np.asarray(_pool_scores(
            self._pool_mean, self._pool_var, jnp.float32(beta)))[: self._m]

    def pool_mean_std(self) -> "tuple[np.ndarray, np.ndarray]":
        """(mean, std) of the attached pool, fused into one dispatch and one
        host sync — the per-metric transfer of the multi-metric scalarized
        acquisition (k metrics cost k syncs per rescoring, not 2k)."""
        ms = np.asarray(_pool_mean_std(self._pool_mean, self._pool_var))
        return ms[0, : self._m], ms[1, : self._m]

    # -- extension -----------------------------------------------------------
    def append(self, x_new, y_new) -> None:
        """Condition on one more (x, y) via a rank-1 Cholesky append.

        O(n^2) against the cached factor (plus O(n·m) to refresh an
        attached pool) — the replacement for the per-batch-member and
        per-fantasy full refactorizations.
        """
        if self.n >= self.capacity:
            raise ValueError(
                f"CholeskyPosterior capacity {self.capacity} exhausted; "
                "construct with headroom for every planned append")
        idx = jnp.asarray(self.n, jnp.int32)
        xn = jnp.asarray(np.asarray(x_new, np.float32).reshape(-1))
        yn = jnp.asarray(np.float32(y_new))
        (self._L, self._xp, self._yp, self._mask, self._w,
         l, lss, wn) = _append(self.raw, self._L, self._xp, self._yp,
                               self._mask, self._w, idx, xn, yn)
        if self._xqp is not None:
            self._V, self._pool_mean, self._pool_var = _rescore(
                self.raw, self._V, self._pool_mean, self._pool_var,
                self._xqp, idx, xn, l, lss, wn)
        self.n += 1
        self._alpha_cache = None

    def append_pool_member(self, pool_index: int) -> None:
        """Condition on pool member ``pool_index`` fantasized at its current
        cached posterior mean — the batch count-loop's rank-1 step, fused
        into a single device dispatch (no value ever crosses to the host)."""
        if self.n >= self.capacity:
            raise ValueError(
                f"CholeskyPosterior capacity {self.capacity} exhausted; "
                "construct with headroom for every planned append")
        if self._xqp is None:
            raise ValueError("append_pool_member() requires set_pool() first")
        idx = jnp.asarray(self.n, jnp.int32)
        (self._L, self._xp, self._yp, self._mask, self._w, self._V,
         self._pool_mean, self._pool_var) = _append_member(
            self.raw, self._L, self._xp, self._yp, self._mask, self._w,
            self._V, self._pool_mean, self._pool_var, self._xqp, idx,
            jnp.asarray(pool_index, jnp.int32))
        self.n += 1
        self._alpha_cache = None

    # -- point queries ---------------------------------------------------------
    def query(self, xq) -> "tuple[np.ndarray, np.ndarray]":
        """(mean, std) at arbitrary points from the cached factor (padded to
        the pool bucket so repeated shapes never retrace)."""
        xq = np.asarray(xq, np.float32)
        m = xq.shape[0]
        xqp = np.zeros((pool_bucket(m), xq.shape[1]), np.float32)
        xqp[:m] = xq
        mean, std = _query(self.raw, self._xp, self._mask, self._L, self._w,
                           jnp.asarray(xqp))
        return np.asarray(mean)[:m], np.asarray(std)[:m]

    @property
    def alpha(self) -> jnp.ndarray:
        """K^-1 y (real rows only), zero on padding — feeds the fused
        gram-matvec stack means without refactorizing."""
        if self._alpha_cache is None:
            self._alpha_cache = _alpha(self._L, self._w)
        return self._alpha_cache

    @property
    def x_padded(self) -> jnp.ndarray:
        return self._xp

    @property
    def design_x(self) -> np.ndarray:
        return np.asarray(self._xp)[: self.n]

    @property
    def design_y(self) -> np.ndarray:
        return np.asarray(self._yp)[: self.n]
