"""SGPR inducing-point posterior: the engine's large-n drop-in.

``SparsePosterior`` mirrors the ``CholeskyPosterior`` interface (set_pool /
pool_mean / pool_std / pool_ucb / append / append_pool_member / query /
alpha / x_padded / design_x / design_y / capacity / n) but factorizes the
m×m inducing matrix instead of the n×n design Gram — O(n·m²) once per
suggest operation instead of O(n³), with m fixed (``n_inducing``) so the
factor cost stops growing with the study. The dense path survives untouched
as the small-n default and the exactness oracle (Z = X makes SGPR exact).

Formulation (Titsias 2009, the GPflow SGPR algebra rearranged around an
explicit B^-1):

    Kuu = K(Z, Z) + jitter·I           Luu = chol(Kuu)
    Kuf = K(Z, X)                      sigma2 = noise
    B   = I + Luu^-1 Kuf Kuf^T Luu^-T / sigma2        LB = chol(B)
    g   = Luu^-1 (Kuf y)
    mean(q) = q_u^T B^-1 g / sigma2,       q_u = Luu^-1 K(Z, q)
    var(q)  = k(q,q) - q_u^T q_u + q_u^T B^-1 q_u

The Gram-product form B = I + Luu^-1 (Kuf Kuf^T) Luu^-T / sigma2 costs one
(m, n)·(n, m) GEMM plus two m×m triangular solves — the O(m²·n) wide solve
A = Luu^-1 Kuf is never materialized.

Rank-1 appends (pending fantasies, batch members) keep the engine's append
semantics against the m×m factor: a new observation (x*, y*) only touches
B and g —

    u = Luu^-1 K(Z, x*) / sigma
    LB   <- cholupdate(LB, u)                       (Pallas kernel)
    B^-1 <- B^-1 - (B^-1 u)(B^-1 u)^T / (1 + u^T B^-1 u)   (Sherman-Morrison)
    g    <- g + Luu^-1 K(Z, x*) · y*

so one append is O(m²) + an O(m·M) cached-pool refresh — same complexity
class the dense engine's rank-1 appends have, but independent of n. Both
factor forms are maintained: ``LB`` (via the cholupdate kernel) serves fresh
cross-solves, ``B^-1`` serves the incremental pool mean/var updates.

Engine invariants carried over: training buffers bucket-pad to
``train_bucket`` with masked columns (padding contributes zero to Kuf·y and
to the Gram product — results are exact), pools pad to ``pool_bucket``, Z
has the STATIC shape (n_inducing, d), and every jitted body counts its
(re)traces in ``posterior.TRACE_COUNTS`` under ``sparse_*`` keys so the
steady-state no-retrace property is pinned by tests. ``append`` past the
reserved capacity refuses loudly, exactly like the dense engine.

Inducing sites are scrambled-Halton points (``pythia/halton.py``) in the
unit cube — low-discrepancy coverage of the feature space, deterministic
per seed, and independent of the trial order so identical study snapshots
place identical sites in every topology.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.pythia import halton
from repro.pythia.posterior import (
    _JITTER,
    TRACE_COUNTS,
    _gram,
    _pool_mean_std,
    _pool_scores,
    pool_bucket,
    train_bucket,
)

# Design sizes strictly above this threshold switch GPBanditPolicy /
# StackedResidualGP levels from the dense CholeskyPosterior to the sparse
# path (documented in ROADMAP's engine rules). At the threshold itself the
# dense path still runs: small-n behavior — and every existing benchmark
# number at n <= 1000 — is bit-for-bit unchanged.
SPARSE_THRESHOLD = 1024

# Inducing-set size: fixed per policy config, so the m×m kernel shapes are
# static across operations and trial counts (no retrace as the study grows).
N_INDUCING = 256


def inducing_sites(n_inducing: int, dim: int, seed: int) -> np.ndarray:
    """Scrambled-Halton inducing sites in [0, 1)^d.

    Seeded by the POLICY seed, not the per-operation nonce: sites must be a
    deterministic function of (config, dim) alone so warm and cold servers,
    replays, and both Figure-2 topologies place the same Z for the same
    study snapshot.
    """
    rng = np.random.RandomState(seed)
    return halton.scrambled_halton(n_inducing, dim, rng).astype(np.float32)


def _noise(raw: Dict) -> jnp.ndarray:
    return jnp.exp(raw["log_noise"]) + _JITTER


@jax.jit
def _sfactor(raw: Dict, z: jnp.ndarray, xp: jnp.ndarray, yp: jnp.ndarray,
             mask: jnp.ndarray):
    """(Luu, LB, Binv, g): the op's ONE sparse factorization.

    Padding columns of the design (mask 0) zero out of Kuf, so they add
    nothing to the Gram product or to Kuf·y — padded results are exact.
    """
    TRACE_COUNTS["sparse_factor"] += 1
    m = z.shape[0]
    sigma2 = _noise(raw)
    Kuu = _gram(raw, z, z) + _JITTER * jnp.eye(m)
    Luu = jnp.linalg.cholesky(Kuu)
    Kuf = _gram(raw, z, xp) * mask[None, :]               # (m, N)
    G = Kuf @ Kuf.T                                       # (m, m) GEMM
    S = kops.tri_solve(Luu, G, impl="auto")               # Luu^-1 G
    S = kops.tri_solve(Luu, S.T, impl="auto")             # Luu^-1 G Luu^-T
    B = jnp.eye(m) + S / sigma2
    LB = jnp.linalg.cholesky(B)
    Y = kops.tri_solve(LB, jnp.eye(m), impl="auto")       # LB^-1
    Binv = Y.T @ Y
    g = kops.tri_solve(Luu, Kuf @ yp, impl="auto")
    return Luu, LB, Binv, g


@jax.jit
def _salpha(raw: Dict, Luu: jnp.ndarray, Binv: jnp.ndarray,
            g: jnp.ndarray) -> jnp.ndarray:
    """alpha_u with mean(q) = K(q, Z) · alpha_u — the inducing-basis mean
    weights feeding the fused gram-matvec stack means."""
    TRACE_COUNTS["sparse_alpha"] += 1
    return kops.tri_solve(Luu, Binv @ g, trans=True, impl="auto") / _noise(raw)


def _pool_stats(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray,
                Binv: jnp.ndarray, g: jnp.ndarray, xqp: jnp.ndarray):
    """Shared cross-solve body: Q = Luu^-1 K(Z, q), mean/var per column."""
    sigma2 = _noise(raw)
    Q = kops.tri_solve(Luu, _gram(raw, z, xqp), impl="auto")  # (m, M)
    mean = Q.T @ (Binv @ g) / sigma2
    var = (jnp.exp(raw["log_amp"]) - jnp.sum(Q * Q, axis=0)
           + jnp.sum(Q * (Binv @ Q), axis=0))
    return Q, mean, var


@jax.jit
def _sattach_pool(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray,
                  Binv: jnp.ndarray, g: jnp.ndarray, xqp: jnp.ndarray):
    """Candidate-pool cross-solve, cached so appends refresh in O(m·M)."""
    TRACE_COUNTS["sparse_attach_pool"] += 1
    return _pool_stats(raw, z, Luu, Binv, g, xqp)


@jax.jit
def _squery(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray, Binv: jnp.ndarray,
            g: jnp.ndarray, xqp: jnp.ndarray):
    """One-shot posterior (mean, std) at arbitrary padded query points."""
    TRACE_COUNTS["sparse_query"] += 1
    _Q, mean, var = _pool_stats(raw, z, Luu, Binv, g, xqp)
    return mean, jnp.sqrt(jnp.maximum(var, 1e-10))


def _append_core(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray,
                 LB: jnp.ndarray, Binv: jnp.ndarray, g: jnp.ndarray,
                 xn: jnp.ndarray, yn: jnp.ndarray):
    """Shared rank-1 append body: cholupdate of LB, Sherman-Morrison of
    B^-1, and the g refresh — O(m²), independent of n."""
    sigma2 = _noise(raw)
    kv = _gram(raw, z, xn[None, :])[:, 0]                 # (m,)
    qv = kops.tri_solve(Luu, kv, impl="auto")             # Luu^-1 k
    u = qv / jnp.sqrt(sigma2)
    LB = kops.cholupdate(LB, u, impl="auto")
    P = Binv @ u
    denom = 1.0 + jnp.dot(u, P)
    Binv = Binv - jnp.outer(P, P) / denom
    g = g + qv * yn
    return LB, Binv, g, P, denom


def _pool_refresh(raw: Dict, Q: jnp.ndarray, var: jnp.ndarray,
                  Binv: jnp.ndarray, g: jnp.ndarray, P: jnp.ndarray,
                  denom: jnp.ndarray):
    """Fold one append into the cached pool posterior: O(m·M).

    The variance contracts by the Sherman-Morrison correction projected
    onto the pool cross-solve; the mean is rebuilt from the updated
    (B^-1, g) — one m-vector solve plus one (M, m) matvec.
    """
    t = Q.T @ P                                           # (M,)
    var = var - t * t / denom
    mean = Q.T @ (Binv @ g) / _noise(raw)
    return mean, var


@jax.jit
def _sappend(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray, LB: jnp.ndarray,
             Binv: jnp.ndarray, g: jnp.ndarray, xn: jnp.ndarray,
             yn: jnp.ndarray):
    """Rank-1 append with no attached pool."""
    TRACE_COUNTS["sparse_append"] += 1
    LB, Binv, g, _P, _denom = _append_core(raw, z, Luu, LB, Binv, g, xn, yn)
    return LB, Binv, g


@jax.jit
def _sappend_rescore(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray,
                     LB: jnp.ndarray, Binv: jnp.ndarray, g: jnp.ndarray,
                     Q: jnp.ndarray, var: jnp.ndarray, xn: jnp.ndarray,
                     yn: jnp.ndarray):
    """Append + cached-pool refresh fused into one dispatch."""
    TRACE_COUNTS["sparse_append_rescore"] += 1
    LB, Binv, g, P, denom = _append_core(raw, z, Luu, LB, Binv, g, xn, yn)
    mean, var = _pool_refresh(raw, Q, var, Binv, g, P, denom)
    return LB, Binv, g, mean, var


@jax.jit
def _sappend_member(raw: Dict, z: jnp.ndarray, Luu: jnp.ndarray,
                    LB: jnp.ndarray, Binv: jnp.ndarray, g: jnp.ndarray,
                    Q: jnp.ndarray, mean: jnp.ndarray, var: jnp.ndarray,
                    xqp: jnp.ndarray, pool_i: jnp.ndarray):
    """Fused batch-member append: pool point ``pool_i`` conditioned at its
    CURRENT cached posterior mean, factors + pool stats updated in ONE
    dispatch with zero host round-trips (the suggest count-loop hot path).
    """
    TRACE_COUNTS["sparse_append_member"] += 1
    xn = xqp[pool_i]
    yn = mean[pool_i]
    LB, Binv, g, P, denom = _append_core(raw, z, Luu, LB, Binv, g, xn, yn)
    mean, var = _pool_refresh(raw, Q, var, Binv, g, P, denom)
    return LB, Binv, g, mean, var, xn, yn


class SparsePosterior:
    """Cached inducing-point (SGPR) posterior for one suggest operation.

    Drop-in alternative to ``CholeskyPosterior`` above ``SPARSE_THRESHOLD``
    design rows: factorizes the m×m inducing system once at construction;
    every later query is served from the cached (Luu, LB, B^-1, g), and
    batch/fantasy extensions are O(m²) rank-1 appends against those factors.
    ``capacity`` reserves the same append headroom contract as the dense
    engine — appends past it refuse loudly.
    """

    def __init__(self, raw: Dict, x, y, *, n_inducing: int = N_INDUCING,
                 seed: int = 0, capacity: Optional[int] = None,
                 z: Optional[np.ndarray] = None):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        n, d = x.shape
        self.raw = {k: jnp.asarray(v, jnp.float32) for k, v in raw.items()}
        self.capacity = max(capacity or n, n)
        self.n = n
        self.n_inducing = n_inducing
        if z is None:
            z = inducing_sites(n_inducing, d, seed)
        self._z = jnp.asarray(np.asarray(z, np.float32))
        bucket = train_bucket(n)
        xp = np.zeros((bucket, d), np.float32)
        yp = np.zeros((bucket,), np.float32)
        mask = np.zeros((bucket,), np.float32)
        xp[:n], yp[:n], mask[:n] = x, y, 1.0
        self._Luu, self._LB, self._Binv, self._g = _sfactor(
            self.raw, self._z, jnp.asarray(xp), jnp.asarray(yp),
            jnp.asarray(mask))
        # the growing design stays on the host: appends only touch the m×m
        # factors, so no bucket-padded device design buffer is needed
        self._x = x
        self._y = y
        self._alpha_cache: Optional[jnp.ndarray] = None
        self._xqp: Optional[jnp.ndarray] = None
        self._m = 0
        self._Q = self._pool_mean = self._pool_var = None

    # -- whole-pool scoring --------------------------------------------------
    def set_pool(self, xq) -> None:
        """Attach a candidate pool: ONE cross-solve, cached for the op."""
        xq = np.asarray(xq, np.float32)
        m = xq.shape[0]
        xqp = np.zeros((pool_bucket(m), xq.shape[1]), np.float32)
        xqp[:m] = xq
        self._xqp = jnp.asarray(xqp)
        self._m = m
        self._Q, self._pool_mean, self._pool_var = _sattach_pool(
            self.raw, self._z, self._Luu, self._Binv, self._g, self._xqp)

    def pool_mean(self) -> np.ndarray:
        return np.asarray(self._pool_mean)[: self._m]

    def pool_std(self) -> np.ndarray:
        var = np.asarray(self._pool_var)[: self._m]
        return np.sqrt(np.maximum(var, 1e-10))

    def pool_ucb(self, beta: float) -> np.ndarray:
        """mean + beta*std for the attached pool: one fused device op and
        ONE host sync (the count-loop's only per-member transfer)."""
        return np.asarray(_pool_scores(
            self._pool_mean, self._pool_var, jnp.float32(beta)))[: self._m]

    def pool_mean_std(self) -> "tuple[np.ndarray, np.ndarray]":
        """(mean, std) of the attached pool, fused into one dispatch and one
        host sync — shares the dense engine's compiled kernel (shape depends
        only on the pool bucket)."""
        ms = np.asarray(_pool_mean_std(self._pool_mean, self._pool_var))
        return ms[0, : self._m], ms[1, : self._m]

    # -- extension -----------------------------------------------------------
    def _check_capacity(self) -> None:
        if self.n >= self.capacity:
            raise ValueError(
                f"SparsePosterior capacity {self.capacity} exhausted; "
                "construct with headroom for every planned append")

    def append(self, x_new, y_new) -> None:
        """Condition on one more (x, y) via a rank-1 append against the m×m
        inducing factors: cholupdate of LB + Sherman-Morrison of B^-1, O(m²)
        regardless of the design size (plus O(m·M) to refresh an attached
        pool)."""
        self._check_capacity()
        xn = np.asarray(x_new, np.float32).reshape(-1)
        yn = np.float32(y_new)
        if self._xqp is None:
            self._LB, self._Binv, self._g = _sappend(
                self.raw, self._z, self._Luu, self._LB, self._Binv, self._g,
                jnp.asarray(xn), jnp.asarray(yn))
        else:
            (self._LB, self._Binv, self._g, self._pool_mean,
             self._pool_var) = _sappend_rescore(
                self.raw, self._z, self._Luu, self._LB, self._Binv, self._g,
                self._Q, self._pool_var, jnp.asarray(xn), jnp.asarray(yn))
        self._x = np.vstack([self._x, xn[None, :]])
        self._y = np.append(self._y, yn)
        self.n += 1
        self._alpha_cache = None

    def append_pool_member(self, pool_index: int) -> None:
        """Condition on pool member ``pool_index`` fantasized at its current
        cached posterior mean — the batch count-loop's rank-1 step, fused
        into a single device dispatch (no value ever crosses to the host)."""
        self._check_capacity()
        if self._xqp is None:
            raise ValueError("append_pool_member() requires set_pool() first")
        (self._LB, self._Binv, self._g, self._pool_mean, self._pool_var,
         xn, yn) = _sappend_member(
            self.raw, self._z, self._Luu, self._LB, self._Binv, self._g,
            self._Q, self._pool_mean, self._pool_var, self._xqp,
            jnp.asarray(pool_index, jnp.int32))
        self._x = np.vstack([self._x, np.asarray(xn)[None, :]])
        self._y = np.append(self._y, np.float32(yn))
        self.n += 1
        self._alpha_cache = None

    # -- point queries -------------------------------------------------------
    def query(self, xq) -> "tuple[np.ndarray, np.ndarray]":
        """(mean, std) at arbitrary points from the cached factors (padded
        to the pool bucket so repeated shapes never retrace)."""
        xq = np.asarray(xq, np.float32)
        m = xq.shape[0]
        xqp = np.zeros((pool_bucket(m), xq.shape[1]), np.float32)
        xqp[:m] = xq
        mean, std = _squery(self.raw, self._z, self._Luu, self._Binv,
                            self._g, jnp.asarray(xqp))
        return np.asarray(mean)[:m], np.asarray(std)[:m]

    @property
    def alpha(self) -> jnp.ndarray:
        """Inducing-basis mean weights: mean(q) = K(q, Z) · alpha. Pairs
        with ``x_padded`` (= Z) to feed the fused gram-matvec stack means —
        an (m,) contraction instead of (n,), no refactorization."""
        if self._alpha_cache is None:
            self._alpha_cache = _salpha(self.raw, self._Luu, self._Binv,
                                        self._g)
        return self._alpha_cache

    @property
    def x_padded(self) -> jnp.ndarray:
        """The mean-basis points pairing with ``alpha`` — the inducing set
        Z, whose (n_inducing, d) shape is static across operations."""
        return self._z

    @property
    def inducing_z(self) -> np.ndarray:
        return np.asarray(self._z)

    @property
    def design_x(self) -> np.ndarray:
        return self._x

    @property
    def design_y(self) -> np.ndarray:
        return self._y
