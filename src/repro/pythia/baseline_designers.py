"""Baseline designers: random, grid, quasi-random (Halton).

RANDOM_SEARCH is the paper's running example (Code Block 1). Grid and Halton
are SerializableDesigners — their whole state is a cursor, which makes them
the simplest demonstrations of O(1) metadata state recovery (§6.3).
"""

from __future__ import annotations

import random
from typing import List, Optional, Sequence

import numpy as np

from repro.core.metadata import Metadata
from repro.core.search_space import ParameterConfig, ParameterDict, ParameterType, ParameterValue
from repro.core.study import CompletedTrials, TrialSuggestion
from repro.core.study_config import StudyConfig
from repro.pythia.designers import PartiallySerializableDesignerMixin, SerializableDesigner


class RandomSearchDesigner(SerializableDesigner, PartiallySerializableDesignerMixin):
    """Uniform (scaling-aware, conditionality-respecting) random search."""

    def __init__(self, study_config: StudyConfig, seed: int = 0):
        self._config = study_config
        self._seed = seed
        self._count = 0
        self._rng = random.Random(seed)

    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        for _ in range(count or 1):
            out.append(TrialSuggestion(parameters=self._config.search_space.sample(self._rng)))
            self._count += 1
        return out

    def update(self, delta: CompletedTrials) -> None:
        pass  # memoryless

    def dump(self) -> Metadata:
        return self._dump_json({"count": self._count, "seed": self._seed})

    def load(self, metadata: Metadata) -> None:
        state = self._load_json(metadata)
        self._seed = int(state["seed"])
        self._count = int(state["count"])
        # continue the stream deterministically without replaying draws
        self._rng = random.Random(f"{self._seed}:{self._count}")


class GridSearchDesigner(SerializableDesigner, PartiallySerializableDesignerMixin):
    """Exhaustive grid over a non-conditional space; DOUBLEs discretized."""

    def __init__(self, study_config: StudyConfig, double_grid_resolution: int = 10):
        if study_config.search_space.is_conditional:
            raise ValueError("GridSearchDesigner does not support conditional spaces")
        self._config = study_config
        self._resolution = int(double_grid_resolution)
        self._index = 0
        self._axes: List[List[ParameterValue]] = [
            self._axis_values(cfg) for cfg in study_config.search_space.parameters
        ]

    def _axis_values(self, cfg: ParameterConfig) -> List[ParameterValue]:
        if cfg.type == ParameterType.CATEGORICAL:
            return [ParameterValue(c) for c in cfg.categories]
        if cfg.type == ParameterType.DISCRETE:
            return [ParameterValue(v) for v in cfg.feasible_values]
        if cfg.type == ParameterType.INTEGER:
            lo, hi = int(cfg.bounds[0]), int(cfg.bounds[1])
            step = max(1, (hi - lo) // max(1, self._resolution - 1))
            vals = list(range(lo, hi + 1, step))
            if vals[-1] != hi:
                vals.append(hi)
            return [ParameterValue(v) for v in vals]
        n = self._resolution
        return [cfg.from_unit(i / max(1, n - 1)) for i in range(n)]

    @property
    def grid_size(self) -> int:
        size = 1
        for axis in self._axes:
            size *= len(axis)
        return size

    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        names = [c.name for c in self._config.search_space.parameters]
        for _ in range(count or 1):
            if self._index >= self.grid_size:
                break  # grid exhausted
            rem = self._index
            params = ParameterDict()
            for name, axis in zip(names, self._axes):
                params[name] = axis[rem % len(axis)]
                rem //= len(axis)
            out.append(TrialSuggestion(parameters=params))
            self._index += 1
        return out

    def update(self, delta: CompletedTrials) -> None:
        pass

    def dump(self) -> Metadata:
        return self._dump_json({"index": self._index})

    def load(self, metadata: Metadata) -> None:
        self._index = int(self._load_json(metadata)["index"])


def _halton(index: int, base: int) -> float:
    f, r = 1.0, 0.0
    i = index
    while i > 0:
        f /= base
        r += f * (i % base)
        i //= base
    return r


_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61,
           67, 71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113, 127, 131]


class HaltonDesigner(SerializableDesigner, PartiallySerializableDesignerMixin):
    """Halton low-discrepancy sequence (QUASI_RANDOM_SEARCH)."""

    def __init__(self, study_config: StudyConfig, skip: int = 20):
        from repro.pythia.converters import TrialToArrayConverter

        self._config = study_config
        self._conv = TrialToArrayConverter(study_config.search_space, onehot_categorical=False)
        if self._conv.dim > len(_PRIMES):
            raise ValueError(f"HaltonDesigner supports <= {len(_PRIMES)} dims")
        self._index = skip

    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        for _ in range(count or 1):
            row = np.array([_halton(self._index, _PRIMES[d]) for d in range(self._conv.dim)])
            params = self._conv.to_parameters(row[None, :])[0]
            out.append(TrialSuggestion(parameters=params))
            self._index += 1
        return out

    def update(self, delta: CompletedTrials) -> None:
        pass

    def dump(self) -> Metadata:
        return self._dump_json({"index": self._index})

    def load(self, metadata: Metadata) -> None:
        self._index = int(self._load_json(metadata)["index"])
