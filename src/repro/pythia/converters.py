"""Trial <-> array featurization for numeric designers (GP, CMA-ES).

Maps parameter assignments into the unit hypercube [0,1]^d honoring scale
types; CATEGORICAL parameters are one-hot encoded. Inactive conditional
parameters are imputed at 0.5 with an extra "active" indicator feature so
regressors can distinguish inactive from mid-range (paper §4.2 notes the
independence invariance conditionality conveys).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.search_space import (
    ParameterConfig,
    ParameterDict,
    ParameterType,
    ParameterValue,
    SearchSpace,
)
from repro.core.study import Trial
from repro.core.study_config import StudyConfig


@dataclasses.dataclass
class _Feature:
    config: ParameterConfig
    one_hot: bool
    width: int
    conditional: bool


class TrialToArrayConverter:
    def __init__(self, search_space: SearchSpace, *, onehot_categorical: bool = True):
        self._space = search_space
        self._features: List[_Feature] = []
        root_names = {c.name for c in search_space.parameters}
        for cfg in search_space.all_parameters():
            onehot = onehot_categorical and cfg.type == ParameterType.CATEGORICAL
            width = len(cfg.categories) if onehot else 1
            conditional = cfg.name not in root_names
            if conditional:
                width += 1  # active indicator
            self._features.append(_Feature(cfg, onehot, width, conditional))

    @property
    def dim(self) -> int:
        return sum(f.width for f in self._features)

    @property
    def n_params(self) -> int:
        return len(self._features)

    def to_features(self, parameters_list: Sequence[ParameterDict]) -> np.ndarray:
        out = np.zeros((len(parameters_list), self.dim), dtype=np.float64)
        for i, params in enumerate(parameters_list):
            col = 0
            for f in self._features:
                cfg = f.config
                active = cfg.name in params
                base_w = f.width - (1 if f.conditional else 0)
                if f.one_hot:
                    if active:
                        idx = cfg.categories.index(params[cfg.name].as_str)
                        out[i, col + idx] = 1.0
                    else:
                        out[i, col : col + base_w] = 1.0 / base_w
                else:
                    out[i, col] = cfg.to_unit(params[cfg.name]) if active else 0.5
                if f.conditional:
                    out[i, col + base_w] = 1.0 if active else 0.0
                col += f.width
        return out

    def to_parameters(self, features: np.ndarray) -> List[ParameterDict]:
        """Array -> parameters. Conditionality is re-derived from parent values
        (indicator columns are ignored on the way back)."""
        features = np.atleast_2d(features)
        out: List[ParameterDict] = []
        for row in features:
            flat = {}
            col = 0
            for f in self._features:
                cfg = f.config
                base_w = f.width - (1 if f.conditional else 0)
                if f.one_hot:
                    idx = int(np.argmax(row[col : col + base_w]))
                    flat[cfg.name] = ParameterValue(cfg.categories[idx])
                else:
                    flat[cfg.name] = cfg.from_unit(float(row[col]))
                col += f.width
            params = ParameterDict()

            def visit(cfg: ParameterConfig):
                params[cfg.name] = flat[cfg.name]
                for child in cfg.active_children(flat[cfg.name]):
                    visit(child)

            for cfg in self._space.parameters:
                visit(cfg)
            out.append(params)
        return out


def trials_to_xy(
    trials: Sequence[Trial],
    config: StudyConfig,
    converter: Optional[TrialToArrayConverter] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(features, larger-is-better objectives) for completed feasible trials."""
    converter = converter or TrialToArrayConverter(config.search_space)
    rows, ys = [], []
    for t in trials:
        obj = config.objective_values(t)
        if obj is None:
            continue
        rows.append(t.parameters)
        ys.append(obj)
    if not rows:
        return np.zeros((0, converter.dim)), np.zeros((0, len(config.metrics)))
    return converter.to_features(rows), np.asarray(ys, dtype=np.float64)
