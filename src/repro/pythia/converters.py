"""Trial <-> array featurization for numeric designers (GP, CMA-ES).

Maps parameter assignments into the unit hypercube [0,1]^d honoring scale
types; CATEGORICAL parameters are one-hot encoded. Inactive conditional
parameters are imputed at 0.5 with an extra "active" indicator feature so
regressors can distinguish inactive from mid-range (paper §4.2 notes the
independence invariance conditionality conveys).

Imputation policy (featurizer hardening): a parameter value that is missing,
out of the current domain (e.g. an unknown categorical from a stale or
cross-study trial), or unparsable featurizes exactly like an *inactive*
conditional parameter — uniform mass over the one-hot block or the 0.5
midpoint, with the active indicator at 0 when present. One bad stored value
must never crash a whole suggest operation; this is also what lets prior
studies' trials flow through the *current* study's featurizer for transfer
learning (see ``align_prior_trials``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.search_space import (
    ParameterConfig,
    ParameterDict,
    ParameterType,
    ParameterValue,
    ScaleType,
    SearchSpace,
)
from repro.core.study import Trial
from repro.core.study_config import StudyConfig

# Row-count threshold past which eligible feature columns are encoded with a
# single vectorized numpy pass instead of a per-trial ``to_unit`` call. The
# per-(trial, parameter) Python loop dominates featurization once studies
# reach thousands of trials; small batches keep the loop (less overhead).
_VECTORIZE_MIN_ROWS = 64


@dataclasses.dataclass
class _Feature:
    config: ParameterConfig
    one_hot: bool
    width: int
    conditional: bool
    # True when the column is a plain continuous unit-map (no one-hot block,
    # no active indicator, no nearest-feasible-value snap) — exactly the
    # ``_continuous_bounds`` branch of ParameterConfig.to_unit, which
    # vectorizes over trials
    fast: bool = False


class TrialToArrayConverter:
    def __init__(self, search_space: SearchSpace, *, onehot_categorical: bool = True):
        self._space = search_space
        self._features: List[_Feature] = []
        root_names = {c.name for c in search_space.parameters}
        for cfg in search_space.all_parameters():
            onehot = onehot_categorical and cfg.type == ParameterType.CATEGORICAL
            width = len(cfg.categories) if onehot else 1
            conditional = cfg.name not in root_names
            if conditional:
                width += 1  # active indicator
            fast = (
                not onehot
                and not conditional
                and cfg.type != ParameterType.CATEGORICAL
                and not (
                    cfg.type == ParameterType.DISCRETE
                    and cfg.scale_type in (None, ScaleType.UNIFORM_DISCRETE)
                )
            )
            self._features.append(
                _Feature(cfg, onehot, width, conditional, fast))

    @property
    def dim(self) -> int:
        return sum(f.width for f in self._features)

    @property
    def n_params(self) -> int:
        return len(self._features)

    @property
    def parameter_names(self) -> List[str]:
        return [f.config.name for f in self._features]

    def to_features(self, parameters_list: Sequence[ParameterDict]) -> np.ndarray:
        """(n, dim) unit-cube features. Columns are encoded feature-by-feature;
        plain continuous columns (``_Feature.fast``) of large batches go
        through one vectorized numpy pass, everything else through the exact
        per-trial ``to_unit`` loop — both produce identical values."""
        n = len(parameters_list)
        out = np.zeros((n, self.dim), dtype=np.float64)
        col = 0
        for f in self._features:
            if f.fast and n >= _VECTORIZE_MIN_ROWS:
                out[:, col] = self._unit_column(f.config, parameters_list)
            else:
                self._encode_feature(f, parameters_list, out, col)
            col += f.width
        return out

    def _encode_feature(self, f: _Feature, parameters_list, out: np.ndarray,
                        col: int) -> None:
        """Per-trial loop for one feature's columns (the general path)."""
        cfg = f.config
        base_w = f.width - (1 if f.conditional else 0)
        for i, params in enumerate(parameters_list):
            if f.one_hot:
                idx = None
                if cfg.name in params:
                    try:
                        idx = cfg.categories.index(params[cfg.name].as_str)
                    except ValueError:
                        idx = None  # out-of-domain category: impute
                active = idx is not None
                if active:
                    out[i, col + idx] = 1.0
                else:
                    out[i, col : col + base_w] = 1.0 / base_w
            else:
                u = None
                if cfg.name in params:
                    try:
                        u = cfg.to_unit(params[cfg.name])
                    except (TypeError, ValueError):
                        u = None  # infeasible/unparsable value: impute
                active = u is not None
                out[i, col] = u if active else 0.5
            if f.conditional:
                out[i, col + base_w] = 1.0 if active else 0.0

    @staticmethod
    def _unit_column(cfg: ParameterConfig, parameters_list) -> np.ndarray:
        """Vectorized ``to_unit`` over trials for one continuous parameter:
        gather raw floats (NaN marks missing/unparsable -> imputed at 0.5),
        then apply the scale transform to the whole column at once."""
        name = cfg.name
        nan = float("nan")
        raw = []
        for params in parameters_list:
            pv = params.get(name)
            if pv is None:
                raw.append(nan)
                continue
            try:
                # inlined ParameterValue.as_float (float(bool) == bool path)
                raw.append(float(pv.value))
            except (TypeError, ValueError):
                raw.append(nan)  # unparsable value: impute
        vals = np.asarray(raw, dtype=np.float64)
        active = ~np.isnan(vals)
        column = np.full(len(parameters_list), 0.5)
        if not active.any():
            return column
        lo, hi = cfg._continuous_bounds()
        v = np.clip(vals[active], lo, hi)
        if hi == lo:
            u = np.zeros_like(v)
        elif cfg.scale_type == ScaleType.LOG:
            u = (np.log(v) - np.log(lo)) / (np.log(hi) - np.log(lo))
        elif cfg.scale_type == ScaleType.REVERSE_LOG:
            u = 1.0 - (np.log(hi + lo - v) - np.log(lo)) / (
                np.log(hi) - np.log(lo))
        else:
            u = (v - lo) / (hi - lo)
        column[active] = u
        return column

    def to_parameters(self, features: np.ndarray) -> List[ParameterDict]:
        """Array -> parameters. Conditionality is re-derived from parent values
        (indicator columns are ignored on the way back)."""
        features = np.atleast_2d(features)
        out: List[ParameterDict] = []
        for row in features:
            flat = {}
            col = 0
            for f in self._features:
                cfg = f.config
                base_w = f.width - (1 if f.conditional else 0)
                if f.one_hot:
                    idx = int(np.argmax(row[col : col + base_w]))
                    flat[cfg.name] = ParameterValue(cfg.categories[idx])
                else:
                    flat[cfg.name] = cfg.from_unit(float(row[col]))
                col += f.width
            params = ParameterDict()

            def visit(cfg: ParameterConfig):
                params[cfg.name] = flat[cfg.name]
                for child in cfg.active_children(flat[cfg.name]):
                    visit(child)

            for cfg in self._space.parameters:
                visit(cfg)
            out.append(params)
        return out


def align_prior_trials(
    prior_trials: Sequence[Trial],
    prior_config: StudyConfig,
    converter: TrialToArrayConverter,
) -> Tuple[np.ndarray, np.ndarray]:
    """Featurizes another study's completed trials through the CURRENT
    study's converter (transfer learning, stacked residual GP).

    Alignment rules:
      * only parameters that exist in the current search space contribute;
        extra parameters carried by a prior trial are ignored;
      * parameters missing from a prior trial, or whose value is infeasible
        in the current space (out-of-domain categorical, unparsable number),
        are imputed by the converter's inactive encoding — never an error;
      * trials sharing NO parameter name with the current space are dropped
        (they carry no signal in the current geometry);
      * the objective is the PRIOR study's own first metric, sign-flipped to
        larger-is-better by *its* goal; trials it cannot score are dropped.

    Returns (features, objectives) — objectives shaped (n,), un-normalized
    (each stack level z-scores its own study's labels before fitting).
    """
    known = set(converter.parameter_names)
    rows, ys = [], []
    for t in prior_trials:
        obj = prior_config.objective_values(t)
        if obj is None:
            continue
        if not any(name in known for name in t.parameters):
            continue
        rows.append(t.parameters)
        ys.append(obj[0])
    if not rows:
        return np.zeros((0, converter.dim)), np.zeros((0,))
    return converter.to_features(rows), np.asarray(ys, dtype=np.float64)


def trials_to_xy(
    trials: Sequence[Trial],
    config: StudyConfig,
    converter: Optional[TrialToArrayConverter] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(features, larger-is-better objectives) for completed feasible trials.

    ``objective_values`` already refuses trials with missing or non-finite
    metric values; the explicit finite filter below is defense-in-depth for
    any caller-constructed config whose scoring path regresses — a NaN label
    row must NEVER reach a GP fit (it turns the whole Cholesky into NaN and
    poisons every suggestion of the operation).
    """
    converter = converter or TrialToArrayConverter(config.search_space)
    rows, ys = [], []
    for t in trials:
        obj = config.objective_values(t)
        if obj is None or not all(math.isfinite(v) for v in obj):
            continue
        rows.append(t.parameters)
        ys.append(obj)
    if not rows:
        return np.zeros((0, converter.dim)), np.zeros((0, len(config.metrics)))
    return converter.to_features(rows), np.asarray(ys, dtype=np.float64)
