"""Pythia developer API (paper §6): policies, supporters, designers."""

from repro.pythia.policy import (
    EarlyStopDecision,
    EarlyStopDecisions,
    EarlyStopRequest,
    Policy,
    PolicySupporter,
    StudyDescriptor,
    SuggestDecision,
    SuggestRequest,
)
from repro.pythia.designers import (
    Designer,
    DesignerPolicy,
    HarmlessDecodeError,
    SerializableDesigner,
    SerializableDesignerPolicy,
)
from repro.pythia.registry import make_policy, register, registered_algorithms

__all__ = [
    "EarlyStopDecision", "EarlyStopDecisions", "EarlyStopRequest", "Policy",
    "PolicySupporter", "StudyDescriptor", "SuggestDecision", "SuggestRequest",
    "Designer", "DesignerPolicy", "HarmlessDecodeError", "SerializableDesigner",
    "SerializableDesignerPolicy", "make_policy", "register",
    "registered_algorithms",
]
