"""Evolutionary designers (paper §6.3, Appendix D.4).

* RegularizedEvolutionDesigner — (Real et al., 2019), the paper's own example
  of an algorithm whose population pool must be checkpointed via Metadata.
* NSGA2Designer — (Deb et al., 2002), the paper's multi-objective reference.

Both are SerializableDesigners: state restores in O(population), not
O(#trials) — the paper's motivating scalability property.
"""

from __future__ import annotations

import random
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.metadata import Metadata
from repro.core.pareto import crowding_distance, non_dominated_sort
from repro.core.search_space import (
    ParameterConfig,
    ParameterDict,
    ParameterType,
    ParameterValue,
)
from repro.core.study import CompletedTrials, TrialSuggestion
from repro.core.study_config import StudyConfig
from repro.pythia.designers import PartiallySerializableDesignerMixin, SerializableDesigner


def _mutate_one(cfg: ParameterConfig, value: ParameterValue, rng: random.Random,
                sigma: float = 0.15) -> ParameterValue:
    """Local mutation in the scaled unit space (numeric) / resample (categorical)."""
    if cfg.type == ParameterType.CATEGORICAL:
        return ParameterValue(rng.choice(cfg.categories))
    u = cfg.to_unit(value)
    u = min(1.0, max(0.0, u + rng.gauss(0.0, sigma)))
    return cfg.from_unit(u)


class _EvolutionBase(SerializableDesigner, PartiallySerializableDesignerMixin):
    """Shared encode/decode + mutation machinery."""

    def __init__(self, study_config: StudyConfig, seed: int = 0):
        self._config = study_config
        self._space = study_config.search_space
        self._rng = random.Random(seed)

    # population entries: (params_dict, objective_vector)
    def _encode_params(self, params: ParameterDict) -> dict:
        return {k: v.value for k, v in params.items()}

    def _decode_params(self, d: dict) -> ParameterDict:
        return ParameterDict.from_dict(d)

    def _mutate(self, params: ParameterDict) -> ParameterDict:
        """Mutate one active parameter; re-derive conditional children."""
        out = ParameterDict()
        active = self._space.active_parameters(params)
        target = self._rng.choice([c.name for c in active])

        def visit(cfg: ParameterConfig):
            if cfg.name == target or cfg.name not in params:
                value = (
                    _mutate_one(cfg, params[cfg.name], self._rng)
                    if cfg.name in params
                    else cfg.sample(self._rng)
                )
            else:
                value = params[cfg.name]
            out[cfg.name] = value
            for child in cfg.active_children(value):
                visit(child)

        for cfg in self._space.parameters:
            visit(cfg)
        return out

    def _crossover(self, a: ParameterDict, b: ParameterDict) -> ParameterDict:
        out = ParameterDict()

        def visit(cfg: ParameterConfig):
            src = a if self._rng.random() < 0.5 else b
            value = src[cfg.name] if cfg.name in src else cfg.sample(self._rng)
            out[cfg.name] = value
            for child in cfg.active_children(value):
                visit(child)

        for cfg in self._space.parameters:
            visit(cfg)
        return out


class RegularizedEvolutionDesigner(_EvolutionBase):
    """Single-objective aging evolution: tournament-select, mutate, age out."""

    def __init__(self, study_config: StudyConfig, *, population_size: int = 25,
                 tournament_size: int = 5, seed: int = 0):
        super().__init__(study_config, seed)
        self._metric = study_config.single_objective_metric()
        self.population_size = population_size
        self.tournament_size = tournament_size
        # FIFO of (encoded_params, objective)
        self._population: List[Tuple[dict, float]] = []

    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        for _ in range(count or 1):
            if len(self._population) < self.population_size:
                out.append(TrialSuggestion(parameters=self._space.sample(self._rng)))
                continue
            k = min(self.tournament_size, len(self._population))
            contenders = self._rng.sample(range(len(self._population)), k)
            best = max(contenders, key=lambda i: self._population[i][1])
            parent = self._decode_params(self._population[best][0])
            out.append(TrialSuggestion(parameters=self._mutate(parent)))
        return out

    def update(self, delta: CompletedTrials) -> None:
        for t in delta.trials:
            obj = self._config.objective_values(t)
            if obj is None:
                continue
            self._population.append((self._encode_params(t.parameters), obj[0]))
            if len(self._population) > self.population_size:
                self._population.pop(0)  # age out the oldest (regularized)

    def dump(self) -> Metadata:
        return self._dump_json({"population": self._population})

    def load(self, metadata: Metadata) -> None:
        state = self._load_json(metadata)
        self._population = [(dict(p), float(o)) for p, o in state["population"]]


class NSGA2Designer(_EvolutionBase):
    """NSGA-II: non-dominated sort + crowding distance selection."""

    def __init__(self, study_config: StudyConfig, *, population_size: int = 50,
                 mutation_rate: float = 0.7, seed: int = 0):
        super().__init__(study_config, seed)
        self.population_size = population_size
        self.mutation_rate = mutation_rate
        self._population: List[Tuple[dict, List[float]]] = []

    def _select_parents(self) -> Tuple[ParameterDict, ParameterDict]:
        y = np.array([obj for _, obj in self._population])
        fronts = non_dominated_sort(y)
        rank = np.zeros(len(self._population), dtype=int)
        for r, front in enumerate(fronts):
            rank[front] = r
        crowd = np.zeros(len(self._population))
        for front in fronts:
            crowd[front] = crowding_distance(y[front])

        def tournament() -> int:
            i, j = self._rng.randrange(len(self._population)), self._rng.randrange(
                len(self._population)
            )
            if rank[i] != rank[j]:
                return i if rank[i] < rank[j] else j
            return i if crowd[i] >= crowd[j] else j

        a, b = tournament(), tournament()
        return (
            self._decode_params(self._population[a][0]),
            self._decode_params(self._population[b][0]),
        )

    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        for _ in range(count or 1):
            if len(self._population) < max(4, self.population_size // 4):
                out.append(TrialSuggestion(parameters=self._space.sample(self._rng)))
                continue
            pa, pb = self._select_parents()
            child = self._crossover(pa, pb)
            if self._rng.random() < self.mutation_rate:
                child = self._mutate(child)
            out.append(TrialSuggestion(parameters=child))
        return out

    def update(self, delta: CompletedTrials) -> None:
        for t in delta.trials:
            obj = self._config.objective_values(t)
            if obj is None:
                continue
            self._population.append((self._encode_params(t.parameters), list(obj)))
        # environmental selection back to population_size
        if len(self._population) > self.population_size:
            y = np.array([o for _, o in self._population])
            fronts = non_dominated_sort(y)
            keep: List[int] = []
            for front in fronts:
                if len(keep) + len(front) <= self.population_size:
                    keep.extend(front.tolist())
                else:
                    crowd = crowding_distance(y[front])
                    order = np.argsort(-crowd)
                    need = self.population_size - len(keep)
                    keep.extend(front[order[:need]].tolist())
                    break
            self._population = [self._population[i] for i in sorted(keep)]

    def pareto_front(self) -> List[Tuple[dict, List[float]]]:
        if not self._population:
            return []
        y = np.array([o for _, o in self._population])
        front = non_dominated_sort(y)[0]
        return [self._population[i] for i in front]

    def dump(self) -> Metadata:
        return self._dump_json({"population": self._population})

    def load(self, metadata: Metadata) -> None:
        state = self._load_json(metadata)
        self._population = [(dict(p), [float(v) for v in o]) for p, o in state["population"]]
