"""Designer abstractions + Policy wrappers (paper §6.3, Appendix D.4).

* Designer — stateful algorithm: ``suggest(count)`` / ``update(completed)``.
* DesignerPolicy — wraps a Designer into a Policy by *replaying all completed
  trials* on every operation: O(#trials) per call, always correct.
* SerializableDesigner — adds ``dump() -> Metadata`` / ``recover(Metadata)``.
* SerializableDesignerPolicy — restores the designer from study metadata and
  feeds it only trials newer than the last incorporated id: O(new trials)
  per call. This is the paper's key scalability mechanism for cheap-objective
  studies with very many trials.
"""

from __future__ import annotations

import abc
import json
from typing import Callable, List, Optional, Sequence, Type, TypeVar

from repro.core.metadata import Metadata, MetadataDelta, Namespace
from repro.core.study import CompletedTrials, Trial, TrialSuggestion
from repro.core.study_config import ProblemStatement, StudyConfig
from repro.pythia.policy import (
    EarlyStopDecision,
    EarlyStopDecisions,
    EarlyStopRequest,
    Policy,
    PolicySupporter,
    SuggestDecision,
    SuggestRequest,
)

_S = TypeVar("_S", bound="SerializableDesigner")

STATE_NAMESPACE = "pythia.designer_state"


class HarmlessDecodeError(Exception):
    """recover() failed benignly; the wrapper falls back to full replay."""


class Designer(abc.ABC):
    @abc.abstractmethod
    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        ...

    @abc.abstractmethod
    def update(self, delta: CompletedTrials) -> None:
        ...


class SerializableDesigner(Designer):
    @abc.abstractmethod
    def dump(self) -> Metadata:
        """Dumps internal state (e.g. population pool) to metadata."""

    @abc.abstractmethod
    def load(self, metadata: Metadata) -> None:
        """Restores state in-place on a factory-fresh instance; raises
        HarmlessDecodeError if the metadata is absent or corrupt."""

    @classmethod
    def recover(cls: Type[_S], factory, config, metadata: Metadata) -> _S:
        """Factory-construct then load (paper Code Block 7 equivalent)."""
        designer = factory(config)
        designer.load(metadata)
        return designer


def _rule_based_early_stop(supporter: PolicySupporter, request: EarlyStopRequest
                           ) -> EarlyStopDecisions:
    """Automated-stopping rules (core.early_stopping) over supporter reads."""
    from repro.core import early_stopping

    all_trials = supporter.GetTrials(request.study_guid)
    by_id = {t.id: t for t in all_trials}
    decisions = []
    for tid in request.trial_ids:
        t = by_id.get(tid)
        if t is None:
            decisions.append(EarlyStopDecision(tid, False, "unknown trial"))
            continue
        stop = early_stopping.should_stop(t, all_trials, request.study_config)
        decisions.append(EarlyStopDecision(
            tid, stop, "automated stopping rule" if stop else ""))
    return EarlyStopDecisions(decisions=decisions)


class DesignerPolicy(Policy):
    """O(n)-replay wrapper (correct default for expensive objectives)."""

    def __init__(self, supporter: PolicySupporter, designer_factory: Callable[[StudyConfig], Designer]):
        self._supporter = supporter
        self._designer_factory = designer_factory

    def early_stop(self, request: EarlyStopRequest) -> EarlyStopDecisions:
        return _rule_based_early_stop(self._supporter, request)

    def suggest(self, request: SuggestRequest) -> SuggestDecision:
        designer = self._designer_factory(request.study_config)
        completed = self._supporter.CompletedTrials(request.study_guid)
        designer.update(CompletedTrials(completed))
        suggestions = list(designer.suggest(request.count))
        return SuggestDecision(suggestions=suggestions)


class SerializableDesignerPolicy(Policy):
    """O(new trials) wrapper via metadata state saving (paper §6.3)."""

    def __init__(
        self,
        supporter: PolicySupporter,
        designer_factory: Callable[[StudyConfig], "SerializableDesigner"],
        designer_cls: Type["SerializableDesigner"],
        *,
        namespace: str = STATE_NAMESPACE,
    ):
        self._supporter = supporter
        self._designer_factory = designer_factory
        self._designer_cls = designer_cls
        self._ns = namespace
        # observability for tests/benchmarks
        self.last_restore_was_incremental: bool = False
        self.last_trials_loaded: int = 0

    def _load_designer(self, request: SuggestRequest):
        config = request.study_config
        state_md = config.metadata.abs_ns(Namespace(self._ns))
        designer = self._designer_factory(config)
        incorporated = 0
        self.last_restore_was_incremental = False
        if "incorporated_max_trial_id" in state_md:
            try:
                designer.load(state_md)
                incorporated = int(str(state_md["incorporated_max_trial_id"]))
                self.last_restore_was_incremental = True
            except HarmlessDecodeError:
                designer = self._designer_factory(config)  # corrupt state: replay
                incorporated = 0
        return designer, incorporated

    def suggest(self, request: SuggestRequest) -> SuggestDecision:
        designer, incorporated = self._load_designer(request)
        new_trials = self._supporter.CompletedTrials(
            request.study_guid, min_trial_id=incorporated + 1
        )
        self.last_trials_loaded = len(new_trials)
        if new_trials:
            designer.update(CompletedTrials(new_trials))
            incorporated = max(t.id for t in new_trials)
        suggestions = list(designer.suggest(request.count))
        # persist the updated state
        delta = MetadataDelta()
        dumped = designer.dump()
        dumped_abs = Metadata()
        dumped_abs.abs_ns(Namespace(self._ns)).update(dict(dumped.items()))
        dumped_abs.abs_ns(Namespace(self._ns))["incorporated_max_trial_id"] = str(incorporated)
        delta.on_study.attach(dumped_abs)
        self._supporter.SendMetadata(delta)
        return SuggestDecision(suggestions=suggestions, metadata=delta)

    def early_stop(self, request: EarlyStopRequest) -> EarlyStopDecisions:
        return _rule_based_early_stop(self._supporter, request)


class PartiallySerializableDesignerMixin:
    """Helper for designers whose state is a plain JSON-able dict."""

    def _dump_json(self, obj) -> Metadata:
        md = Metadata()
        md["state"] = json.dumps(obj)
        return md

    @staticmethod
    def _load_json(metadata: Metadata):
        if "state" not in metadata:
            raise HarmlessDecodeError('cannot find key "state"')
        try:
            raw = metadata["state"]
            if isinstance(raw, bytes):
                raw = raw.decode("utf-8")
            return json.loads(raw)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise HarmlessDecodeError(str(e)) from e
