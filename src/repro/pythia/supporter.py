"""PolicySupporter implementations (paper §6.2).

* DatastorePolicySupporter — used when Pythia runs inside the API server
  process: reads straight from the datastore.
* RemotePolicySupporter — used when Pythia runs as a *separate service*
  (paper Fig. 2): reads via RPCs back to the API server, so the algorithm
  binary needs no database access.
* PrefetchedPolicySupporter — wraps another supporter with a trial snapshot
  prefetched for a whole coalesced BatchSuggestTrials dispatch, so N
  policies run against one multi-study datastore read instead of issuing
  N x (completed + active) queries.

All support cross-study reads (transfer learning / meta-learning).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.metadata import MetadataDelta
from repro.core.study import Trial, TrialState
from repro.core.study_config import StudyConfig
from repro.pythia.policy import PolicySupporter

_STATE_BY_NAME = {s.value: s for s in TrialState}
_STATE_BY_NAME["COMPLETED"] = TrialState.COMPLETED  # alias


def _states_arg(status_matches: Optional[str]):
    if status_matches is None:
        return None
    if status_matches not in _STATE_BY_NAME:
        raise ValueError(f"unknown trial state filter {status_matches!r}")
    return [_STATE_BY_NAME[status_matches]]


class DatastorePolicySupporter(PolicySupporter):
    def __init__(self, datastore, study_guid: str):
        self._ds = datastore
        self._study_guid = study_guid

    def GetStudyConfig(self, study_guid: str) -> StudyConfig:
        return self._ds.get_study(study_guid).study_config

    def GetTrials(
        self,
        study_guid: str,
        *,
        status_matches: Optional[str] = None,
        min_trial_id: Optional[int] = None,
        max_trial_id: Optional[int] = None,
    ) -> List[Trial]:
        trials = self._ds.list_trials(
            study_guid, states=_states_arg(status_matches), min_trial_id=min_trial_id
        )
        if max_trial_id is not None:
            trials = [t for t in trials if t.id <= max_trial_id]
        return trials

    def SendMetadata(self, delta: MetadataDelta) -> None:
        if delta.empty():
            return
        # one atomic datastore application (policy state saving, paper §6.3):
        # the backend holds its lock across the read-modify-write
        self._ds.apply_metadata_delta(self._study_guid, delta)

    def GetTrialsMulti(
        self, study_guids: List[str], *, status_matches: Optional[str] = None
    ) -> Dict[str, List[Trial]]:
        return self._ds.list_trials_multi(
            study_guids, states=_states_arg(status_matches)
        )


class PrefetchedPolicySupporter(PolicySupporter):
    """Serves GetTrials from a prefetched multi-study snapshot.

    ``snapshot`` maps study_guid -> state-name -> trials, as produced by one
    ``Datastore.list_trials_multi`` call per state of interest. Filters the
    snapshot can answer (status + id-range over a prefetched study/state) are
    served from memory; anything else falls through to ``base``. Writes
    (SendMetadata) always go to ``base``.
    """

    def __init__(self, base: PolicySupporter,
                 snapshot: Dict[str, Dict[str, List[Trial]]]):
        self._base = base
        self._snapshot = snapshot

    def GetStudyConfig(self, study_guid: str) -> StudyConfig:
        return self._base.GetStudyConfig(study_guid)

    def GetTrials(
        self,
        study_guid: str,
        *,
        status_matches: Optional[str] = None,
        min_trial_id: Optional[int] = None,
        max_trial_id: Optional[int] = None,
    ) -> List[Trial]:
        by_state = self._snapshot.get(study_guid)
        if by_state is None or status_matches not in by_state:
            return self._base.GetTrials(
                study_guid,
                status_matches=status_matches,
                min_trial_id=min_trial_id,
                max_trial_id=max_trial_id,
            )
        trials = by_state[status_matches]
        if min_trial_id is not None:
            trials = [t for t in trials if t.id >= min_trial_id]
        if max_trial_id is not None:
            trials = [t for t in trials if t.id <= max_trial_id]
        return list(trials)

    def SendMetadata(self, delta: MetadataDelta) -> None:
        self._base.SendMetadata(delta)


class RemotePolicySupporter(PolicySupporter):
    """Backed by RPCs to the API server (for the standalone Pythia service).

    ``prefetched`` (study_guid -> full, state-unfiltered list of *raw trial
    protos*) enables the coalesced-dispatch mode: the Pythia servicer
    fetches every batched study's trials in ONE GetTrialsMulti frame up
    front, and policies then filter locally instead of re-RPCing for trials
    the service already holds. Materialization is lazy and cached per study:
    a policy that never reads trials (e.g. random search) costs zero
    Trial.from_proto work. Studies absent from the prefetch (e.g.
    cross-study transfer reads) still go over the wire.

    ``buffer_metadata=True`` (the coalesced-dispatch mode) queues SendMetadata
    deltas in ``buffered_deltas`` instead of issuing an UpdateMetadata frame
    per policy; the batch servicer merges them into the response's
    metadata_delta, which the API server applies under the study lock when it
    finalizes the operation.

    ``configs`` (study_guid -> StudyConfig) serves GetStudyConfig from the
    snapshot the single GetTrialsMulti(include_studies) frame already
    carried — the transfer-learning path reads prior studies' configs with
    zero extra GetStudy frames. ``known_missing`` lists studies the API
    server reported absent in that same frame: trial reads for them return
    empty locally (the policy's defensive prior loading treats "no trials"
    and "no study" identically — skip the prior) instead of burning an RPC
    that is known to fail.
    """

    def __init__(self, rpc_client, study_guid: str, *,
                 prefetched: Optional[Dict[str, List[dict]]] = None,
                 buffer_metadata: bool = False,
                 configs: Optional[Dict[str, StudyConfig]] = None,
                 known_missing=()):
        self._rpc = rpc_client
        self._study_guid = study_guid
        self._prefetched = prefetched or {}
        self._buffer_metadata = buffer_metadata
        self._configs = dict(configs or {})
        self._known_missing = set(known_missing)
        self.buffered_deltas: List[MetadataDelta] = []
        # trial-id -> Trial, materialized on demand from the raw protos
        self._materialized: Dict[str, Dict[int, Trial]] = {}

    def _select_prefetched(self, study_guid: str, status_matches,
                           min_trial_id, max_trial_id) -> List[Trial]:
        """Filter on the raw protos, materialize only the matches (cached
        per trial): an incremental read of 1 new trial out of a 1000-trial
        prefetch costs one Trial.from_proto, not a thousand."""
        states = _states_arg(status_matches)
        state_values = {s.value for s in states} if states is not None else None
        cache = self._materialized.setdefault(study_guid, {})
        out = []
        for proto in self._prefetched[study_guid]:
            tid = int(proto.get("id", 0))
            if state_values is not None and proto.get("state") not in state_values:
                continue
            if min_trial_id is not None and tid < min_trial_id:
                continue
            if max_trial_id is not None and tid > max_trial_id:
                continue
            if tid not in cache:
                cache[tid] = Trial.from_proto(proto)
            out.append(cache[tid])
        return out

    def GetStudyConfig(self, study_guid: str) -> StudyConfig:
        if study_guid in self._configs:
            return self._configs[study_guid]
        result = self._rpc.call("GetStudy", {"name": study_guid})
        return StudyConfig.from_proto(result["study"]["study_spec"])

    def GetTrials(
        self,
        study_guid: str,
        *,
        status_matches: Optional[str] = None,
        min_trial_id: Optional[int] = None,
        max_trial_id: Optional[int] = None,
    ) -> List[Trial]:
        if study_guid in self._known_missing:
            return []  # server already reported it absent on the prefetch
        if study_guid in self._prefetched:
            return self._select_prefetched(study_guid, status_matches,
                                           min_trial_id, max_trial_id)
        params = {"parent": study_guid}
        if status_matches is not None:
            st = _states_arg(status_matches)[0]
            params["states"] = [st.value]
        if min_trial_id is not None:
            params["min_trial_id"] = min_trial_id
        result = self._rpc.call("ListTrials", params)
        trials = [Trial.from_proto(p) for p in result["trials"]]
        if max_trial_id is not None:
            trials = [t for t in trials if t.id <= max_trial_id]
        return trials

    def GetTrialsMulti(
        self, study_guids: List[str], *, status_matches: Optional[str] = None
    ) -> Dict[str, List[Trial]]:
        out: Dict[str, List[Trial]] = {}
        missing = []
        for guid in study_guids:
            if guid in self._known_missing:
                out[guid] = []
            elif guid in self._prefetched:
                out[guid] = self._select_prefetched(guid, status_matches,
                                                    None, None)
            else:
                missing.append(guid)
        if missing:
            params: dict = {"parents": missing}
            if status_matches is not None:
                params["states"] = [_states_arg(status_matches)[0].value]
            result = self._rpc.call("GetTrialsMulti", params)
            for guid in missing:
                out[guid] = [
                    Trial.from_proto(p)
                    for p in result["trials_by_study"].get(guid, [])
                ]
        return out

    def SendMetadata(self, delta: MetadataDelta) -> None:
        if delta.empty():
            return
        if self._buffer_metadata:
            self.buffered_deltas.append(delta)
            return
        self._rpc.call(
            "UpdateMetadata",
            {"name": self._study_guid, "delta": delta.to_proto()},
        )
