"""Algorithm registry: StudyConfig.algorithm -> Policy factory.

The Pythia service looks algorithms up here; contributors register new ones
with @register (the paper's "algorithms may easily be added as policies").
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.core.study_config import StudyConfig
from repro.pythia.baseline_designers import (
    GridSearchDesigner,
    HaltonDesigner,
    RandomSearchDesigner,
)
from repro.pythia.cmaes import CMAESDesigner
from repro.pythia.designers import DesignerPolicy, SerializableDesignerPolicy
from repro.pythia.evolution import NSGA2Designer, RegularizedEvolutionDesigner
from repro.pythia.gp_bandit import GPBanditPolicy
from repro.pythia.policy import Policy, PolicySupporter

PolicyFactory = Callable[[PolicySupporter, StudyConfig], Policy]

_REGISTRY: Dict[str, PolicyFactory] = {}


class PolicyConstructionError(ValueError):
    """The requested algorithm cannot be built for this study config.

    This is a PERMANENT client error (unknown algorithm name, or an
    algorithm/config mismatch like a single-objective designer on a
    multi-metric study), so it carries ``code`` = INVALID_ARGUMENT (3):
    ``fail_operation_from_exception`` duck-types on ``.code``, and clients
    stop retrying what used to surface as a retryable INTERNAL (13).
    """

    code = 3  # StatusCode.INVALID_ARGUMENT (registry stays transport-free)


def register(name: str):
    def deco(factory: PolicyFactory) -> PolicyFactory:
        _REGISTRY[name.upper()] = factory
        return factory

    return deco


def make_policy(algorithm: str, supporter: PolicySupporter, config: StudyConfig) -> Policy:
    name = (algorithm or "DEFAULT").upper()
    if name not in _REGISTRY:
        raise PolicyConstructionError(
            f"unknown algorithm {algorithm!r}; registered: {sorted(_REGISTRY)}"
        )
    try:
        return _REGISTRY[name](supporter, config)
    except (ValueError, KeyError, TypeError) as e:
        # e.g. REGULARIZED_EVOLUTION explicitly selected on a multi-metric
        # study: single_objective_metric() raises inside the factory
        raise PolicyConstructionError(
            f"algorithm {name!r} cannot serve this study config: "
            f"{type(e).__name__}: {e}") from e


def registered_algorithms():
    return sorted(_REGISTRY)


# -- built-ins ---------------------------------------------------------------


@register("RANDOM_SEARCH")
def _random(supporter, config):
    return SerializableDesignerPolicy(
        supporter, lambda cfg: RandomSearchDesigner(cfg), RandomSearchDesigner
    )


@register("GRID_SEARCH")
def _grid(supporter, config):
    return SerializableDesignerPolicy(
        supporter, lambda cfg: GridSearchDesigner(cfg), GridSearchDesigner
    )


@register("QUASI_RANDOM_SEARCH")
def _halton(supporter, config):
    return SerializableDesignerPolicy(
        supporter, lambda cfg: HaltonDesigner(cfg), HaltonDesigner
    )


@register("REGULARIZED_EVOLUTION")
def _regevo(supporter, config):
    # eager mismatch check: the designer itself is built lazily per request,
    # so validate here where make_policy maps the failure to INVALID_ARGUMENT
    config.single_objective_metric()
    return SerializableDesignerPolicy(
        supporter,
        lambda cfg: RegularizedEvolutionDesigner(cfg),
        RegularizedEvolutionDesigner,
    )


@register("NSGA2")
def _nsga2(supporter, config):
    return SerializableDesignerPolicy(
        supporter, lambda cfg: NSGA2Designer(cfg), NSGA2Designer
    )


@register("CMA_ES")
def _cmaes(supporter, config):
    return SerializableDesignerPolicy(
        supporter, lambda cfg: CMAESDesigner(cfg), CMAESDesigner
    )


@register("GP_UCB")
def _gp(supporter, config):
    return GPBanditPolicy(supporter)


@register("GAUSSIAN_PROCESS_BANDIT")
def _gp2(supporter, config):
    return GPBanditPolicy(supporter)


@register("DEFAULT")
def _default(supporter, config):
    """GP bandit for expensive studies, single- AND multi-objective: the
    multi-metric path fits one GP per metric on the shared engine buckets and
    acquires via hypervolume-scalarized UCB. NSGA-II stays registered as the
    explicit cheap-evaluation baseline (``algorithm="NSGA2"``)."""
    return GPBanditPolicy(supporter)
