"""Separable CMA-ES designer (diagonal covariance) in the unit hypercube.

Serializable: mean/sigma/paths/covariance diag round-trip through Metadata,
so restoring costs O(d) — another §6.3 demonstration, this time with
non-trivial numeric state.
"""

from __future__ import annotations

import json
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.metadata import Metadata
from repro.core.study import CompletedTrials, TrialSuggestion
from repro.core.study_config import StudyConfig
from repro.pythia.converters import TrialToArrayConverter
from repro.pythia.designers import PartiallySerializableDesignerMixin, SerializableDesigner


class CMAESDesigner(SerializableDesigner, PartiallySerializableDesignerMixin):
    def __init__(self, study_config: StudyConfig, *, population_size: Optional[int] = None,
                 sigma0: float = 0.25, seed: int = 0):
        self._config = study_config
        self._conv = TrialToArrayConverter(study_config.search_space, onehot_categorical=False)
        d = self._conv.dim
        self._d = d
        self._lam = population_size or (4 + int(3 * math.log(d + 1)))
        self._mu = self._lam // 2
        w = np.log(self._mu + 0.5) - np.log(np.arange(1, self._mu + 1))
        self._w = w / w.sum()
        self._mueff = 1.0 / np.sum(self._w**2)
        # standard sep-CMA-ES constants
        self._cs = (self._mueff + 2) / (d + self._mueff + 5)
        self._ds = 1 + 2 * max(0.0, math.sqrt((self._mueff - 1) / (d + 1)) - 1) + self._cs
        self._cc = (4 + self._mueff / d) / (d + 4 + 2 * self._mueff / d)
        self._c1 = 2 / ((d + 1.3) ** 2 + self._mueff)
        self._cmu = min(
            1 - self._c1,
            2 * (self._mueff - 2 + 1 / self._mueff) / ((d + 2) ** 2 + self._mueff),
        ) * (d + 2) / 3  # sep-CMA correction
        self._chiN = math.sqrt(d) * (1 - 1 / (4 * d) + 1 / (21 * d * d))
        self._rng = np.random.RandomState(seed)
        # mutable state
        self._mean = np.full(d, 0.5)
        self._sigma = sigma0
        self._ps = np.zeros(d)
        self._pc = np.zeros(d)
        self._C = np.ones(d)  # diagonal covariance
        self._gen = 0
        self._asked: List[List[float]] = []  # genotypes awaiting evaluation
        self._buffer: List[tuple] = []       # (genotype, fitness) pairs received

    # -- designer API ------------------------------------------------------------
    def suggest(self, count: Optional[int] = None) -> Sequence[TrialSuggestion]:
        out = []
        for _ in range(count or 1):
            z = self._rng.randn(self._d)
            xg = self._mean + self._sigma * np.sqrt(self._C) * z
            xg = np.clip(xg, 0.0, 1.0)
            self._asked.append(xg.tolist())
            params = self._conv.to_parameters(xg[None, :])[0]
            sug = TrialSuggestion(parameters=params)
            sug.metadata.ns("cmaes")["genotype"] = json.dumps(xg.tolist())
            out.append(sug)
        return out

    def update(self, delta: CompletedTrials) -> None:
        for t in delta.trials:
            obj = self._config.objective_values(t)
            if obj is None:
                continue
            g = t.metadata.ns("cmaes").get("genotype")
            if g is not None:
                x = np.asarray(json.loads(g if isinstance(g, str) else g.decode()))
            else:  # trial came from elsewhere: featurize
                x = self._conv.to_features([t.parameters])[0]
            self._buffer.append((x, obj[0]))
        while len(self._buffer) >= self._lam:
            batch, self._buffer = self._buffer[: self._lam], self._buffer[self._lam:]
            self._step([b[0] for b in batch], [b[1] for b in batch])

    def _step(self, xs: List[np.ndarray], fitness: List[float]) -> None:
        order = np.argsort(-np.asarray(fitness))  # maximize
        elite = np.stack([xs[i] for i in order[: self._mu]])
        old_mean = self._mean.copy()
        self._mean = self._w @ elite
        y = (self._mean - old_mean) / max(self._sigma, 1e-12)
        # step-size path
        self._ps = (1 - self._cs) * self._ps + math.sqrt(
            self._cs * (2 - self._cs) * self._mueff
        ) * y / np.sqrt(np.maximum(self._C, 1e-12))
        self._sigma *= math.exp(
            (self._cs / self._ds) * (np.linalg.norm(self._ps) / self._chiN - 1)
        )
        self._sigma = float(np.clip(self._sigma, 1e-4, 0.8))
        # covariance path + diagonal update
        hsig = 1.0 if np.linalg.norm(self._ps) / math.sqrt(
            1 - (1 - self._cs) ** (2 * (self._gen + 1))
        ) < (1.4 + 2 / (self._d + 1)) * self._chiN else 0.0
        self._pc = (1 - self._cc) * self._pc + hsig * math.sqrt(
            self._cc * (2 - self._cc) * self._mueff
        ) * y
        artmp = (elite - old_mean) / max(self._sigma, 1e-12)
        self._C = (
            (1 - self._c1 - self._cmu) * self._C
            + self._c1 * (self._pc**2 + (1 - hsig) * self._cc * (2 - self._cc) * self._C)
            + self._cmu * (self._w @ (artmp**2))
        )
        self._C = np.clip(self._C, 1e-8, 10.0)
        self._gen += 1

    # -- serialization (paper §6.3) --------------------------------------------
    def dump(self) -> Metadata:
        return self._dump_json(
            {
                "mean": self._mean.tolist(),
                "sigma": self._sigma,
                "ps": self._ps.tolist(),
                "pc": self._pc.tolist(),
                "C": self._C.tolist(),
                "gen": self._gen,
                "buffer": [(x.tolist() if isinstance(x, np.ndarray) else x, f)
                           for x, f in self._buffer],
            }
        )

    def load(self, metadata: Metadata) -> None:
        s = self._load_json(metadata)
        self._mean = np.asarray(s["mean"])
        self._sigma = float(s["sigma"])
        self._ps = np.asarray(s["ps"])
        self._pc = np.asarray(s["pc"])
        self._C = np.asarray(s["C"])
        self._gen = int(s["gen"])
        self._buffer = [(np.asarray(x), float(f)) for x, f in s.get("buffer", [])]
