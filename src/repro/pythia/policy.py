"""Pythia developer API (paper §6).

A Policy executes the blackbox-optimization algorithm server-side. Its
lifespan is one suggestion or early-stopping operation (paper §6.3), so any
long-lived state must round-trip through Metadata via the PolicySupporter.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional

from repro.core.metadata import Metadata, MetadataDelta
from repro.core.study import Trial, TrialSuggestion
from repro.core.study_config import ProblemStatement, StudyConfig


@dataclasses.dataclass(frozen=True)
class StudyDescriptor:
    """Identifies the study an operation acts on."""

    config: StudyConfig
    guid: str  # resource name owners/{o}/studies/{s}
    max_trial_id: int = 0


@dataclasses.dataclass
class SuggestRequest:
    study_descriptor: StudyDescriptor
    count: int = 1

    @property
    def study_config(self) -> StudyConfig:
        return self.study_descriptor.config

    @property
    def study_guid(self) -> str:
        return self.study_descriptor.guid

    @property
    def study_metadata(self) -> Metadata:
        """Study-level metadata — where persisted algorithm state lives
        (paper §6.3). The snapshot embedded in the StudyConfig; both
        topologies round-trip it with the config (the Figure-2 split ships
        it on the GetTrialsMulti(include_studies) frame)."""
        return self.study_descriptor.config.metadata


@dataclasses.dataclass
class SuggestDecision:
    suggestions: List[TrialSuggestion] = dataclasses.field(default_factory=list)
    metadata: MetadataDelta = dataclasses.field(default_factory=MetadataDelta)


@dataclasses.dataclass
class EarlyStopRequest:
    study_descriptor: StudyDescriptor
    trial_ids: List[int] = dataclasses.field(default_factory=list)

    @property
    def study_config(self) -> StudyConfig:
        return self.study_descriptor.config

    @property
    def study_guid(self) -> str:
        return self.study_descriptor.guid


@dataclasses.dataclass
class EarlyStopDecision:
    trial_id: int
    should_stop: bool
    reason: str = ""


@dataclasses.dataclass
class EarlyStopDecisions:
    decisions: List[EarlyStopDecision] = dataclasses.field(default_factory=list)
    metadata: MetadataDelta = dataclasses.field(default_factory=MetadataDelta)


class Policy(abc.ABC):
    """Minimal, general-purpose algorithm interface (paper §6.1)."""

    @abc.abstractmethod
    def suggest(self, request: SuggestRequest) -> SuggestDecision:
        """Computes the next suggestion batch."""

    def early_stop(self, request: EarlyStopRequest) -> EarlyStopDecisions:
        """Optional: decide whether pending trials should stop early."""
        return EarlyStopDecisions(
            decisions=[
                EarlyStopDecision(tid, False, "policy has no early-stopping rule")
                for tid in request.trial_ids
            ]
        )


class PolicySupporter(abc.ABC):
    """Mini-client for reading/filtering Trials and sending metadata (paper §6.2).

    Policies can meta-learn from *any* study in the database via
    GetStudyConfig/GetTrials — the transfer-learning hook.
    """

    @abc.abstractmethod
    def GetStudyConfig(self, study_guid: str) -> StudyConfig:
        ...

    @abc.abstractmethod
    def GetTrials(
        self,
        study_guid: str,
        *,
        status_matches: Optional[str] = None,  # 'ACTIVE' | 'SUCCEEDED' | ...
        min_trial_id: Optional[int] = None,
        max_trial_id: Optional[int] = None,
    ) -> List[Trial]:
        ...

    @abc.abstractmethod
    def SendMetadata(self, delta: MetadataDelta) -> None:
        """Persists algorithm state into the database (paper §6.3)."""

    def GetTrialsMulti(
        self,
        study_guids: List[str],
        *,
        status_matches: Optional[str] = None,
    ) -> "dict[str, List[Trial]]":
        """Trials for several studies at once (batched suggestion path).

        Default loops over GetTrials; datastore-backed supporters override
        with a single multi-study query.
        """
        return {
            guid: self.GetTrials(guid, status_matches=status_matches)
            for guid in study_guids
        }

    # convenience used by most policies
    def CompletedTrials(self, study_guid: str, min_trial_id: Optional[int] = None):
        return self.GetTrials(
            study_guid, status_matches="SUCCEEDED", min_trial_id=min_trial_id
        )

    def ActiveTrials(self, study_guid: str):
        return self.GetTrials(study_guid, status_matches="ACTIVE")
