"""Gaussian-Process bandit policy in JAX (paper Code Block 2).

Pipeline per suggestion operation (the Policy's lifespan):
  1. PolicySupporter loads completed trials.
  2. Featurize into [0,1]^d (scaling-aware; one-hot categoricals).
  3. Fit GP hyperparameters (ARD Matérn-5/2 + noise) by maximizing the log
     marginal likelihood with Adam (jax.grad), resuming a persisted Adam
     trajectory when one is stored (paper §6.3). Multi-metric studies fit
     one GP per metric in lockstep through ONE vmapped Adam step per
     iteration (``MultiMetricGP``), sharing the bucket-padded design.
  4. Maximize UCB over scrambled-Halton candidates + local perturbations of
     the incumbent; fantasize pending trials to avoid duplicate suggestions
     when ObservationNoise is LOW (paper Appendix B.2). Multi-metric
     studies maximize the hypervolume-scalarized UCB instead — random
     positive weights per batch member, reference point anchored below the
     observed Pareto frontier (``_suggest_multi``).

Acquisition runs on the factorized-posterior engine
(``repro.pythia.posterior.CholeskyPosterior``): K(X, X) is factorized ONCE
per suggest operation right after the fit, every mean/std/UCB query is
served from the cached (L, w), pending fantasies and batch members extend
the factor with O(n^2) rank-1 appends, and all shapes are padded to
power-of-two buckets so the jitted kernels stop retracing across
operations. Stack-level means go through the fused ``matern52_gram_matvec``
kernel — all levels batched into one device call, no (n, m) cross-Gram
materialization. The pre-engine path (one full Cholesky per batch member
inside jitted ``_ucb``/``_posterior``) is kept behind
``GPBanditPolicy(use_engine=False)`` as the numerical oracle and the
baseline for ``make bench-acquisition``; ``ucb_reference`` keeps the
per-candidate loop purely as the equivalence oracle for tests.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metadata import Metadata, MetadataDelta
from repro.core.pareto import default_reference_point, pareto_frontier_indices
from repro.core.study import TrialSuggestion
from repro.core.study_config import ObservationNoise, StudyConfig
from repro.kernels import ops as kops
from repro.pythia import halton
from repro.pythia.converters import (
    TrialToArrayConverter,
    align_prior_trials,
    trials_to_xy,
)
from repro.pythia.policy import (
    EarlyStopDecision,
    EarlyStopDecisions,
    EarlyStopRequest,
    Policy,
    PolicySupporter,
    SuggestDecision,
    SuggestRequest,
)
from repro.pythia.posterior import (
    CholeskyPosterior,
    pool_bucket,
    train_bucket,
)
from repro.pythia.sparse_posterior import (
    N_INDUCING,
    SPARSE_THRESHOLD,
    SparsePosterior,
)
from repro.pythia.state import (
    PolicyState,
    load_metric_states,
    load_prior_levels,
    load_state,
    store_state,
)

jax.config.update("jax_enable_x64", False)

# acquisition exploration weight (GaussianProcessBandit's default; the
# policy reads it here instead of constructing a throwaway instance)
DEFAULT_UCB_BETA = 1.8

# Weight of the linear augmentation term in the hypervolume scalarization:
# s_w(u) = min_j((u_j - ref_j)/w_j) + HV_AUGMENT * mean_j((u_j - ref_j)/w_j).
# The min alone is flat wherever one metric's UCB pins the scalarization;
# the small averaged term breaks those ties toward candidates that improve
# the OTHER metrics too (the augmented-Chebyshev trick).
HV_AUGMENT = 0.05

# Above SPARSE_THRESHOLD design rows the hyperparameter fit (Adam on the
# MLL) runs on this many evenly-strided rows instead of the full design —
# the fit cost stays bounded as the study grows, while the posterior itself
# still conditions on every observation through the inducing factorization.
FIT_SUBSAMPLE = 256

# Resumed (warm-started) sparse-path fits are capped at this many Adam
# steps per operation: the persisted trajectory sits at the optimum and
# only needs to track the slow drift of the label renormalization, but an
# uncapped resume occasionally burns 30+ steps chasing that drift and
# blows the large-n per-op latency budget (each step pays a fused
# grad+update dispatch whose cholesky dominates). Unconverged ops hand the
# trajectory to the next op via the persisted state, so the cap bounds
# per-op work without capping total optimization. Cold fits keep the full
# budget.
SPARSE_WARM_FIT_STEPS = 6


def _fit_subsample_idx(n: int) -> np.ndarray:
    """Deterministic evenly-strided row subsample for the sparse-path fit.

    The stride is floor(n / FIT_SUBSAMPLE), so the selected rows are
    IDENTICAL across consecutive operations while the study grows within a
    stride bucket — the warm-started fit re-converges in a couple of steps
    instead of chasing a subsample that shifts under it on every op.
    """
    stride = max(1, n // FIT_SUBSAMPLE)
    idx = np.arange(FIT_SUBSAMPLE, dtype=np.int64) * stride
    return idx[idx < n]


@dataclasses.dataclass
class GPParams:
    log_amp: jnp.ndarray      # ()
    log_ell: jnp.ndarray      # (d,)
    log_noise: jnp.ndarray    # ()


def _kernel(params: GPParams, x1: jnp.ndarray, x2: jnp.ndarray) -> jnp.ndarray:
    ell = jnp.exp(params.log_ell)
    amp = jnp.exp(params.log_amp)
    # impl="auto": Pallas kernel on TPU, XLA reference elsewhere; pools with
    # >= 4096 rows go through the blocked column-strip path either way.
    return kops.matern52_gram(x1 / ell, x2 / ell, amp, impl="auto")


@jax.jit
def _neg_mll(raw: dict, x: jnp.ndarray, y: jnp.ndarray,
             mask: jnp.ndarray) -> jnp.ndarray:
    """Masked negative log marginal likelihood over a bucket-padded design.

    Padding rows (mask 0, y 0) contribute an identity block to K, zero to
    the quadratic form and zero to the log-determinant, so the value differs
    from the unpadded MLL only in nothing at all — while the (x, y) shapes
    stay constant across trial counts within a bucket (no retrace per op).
    """
    params = GPParams(**raw)
    noise = jnp.exp(params.log_noise) + 1e-4
    K = _kernel(params, x, x) * (mask[:, None] * mask[None, :])
    K = K + jnp.diag(noise * mask + (1.0 - mask))
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    mll = (
        -0.5 * jnp.dot(y, alpha)
        - jnp.sum(jnp.log(jnp.diagonal(L)))
        - 0.5 * jnp.sum(mask) * jnp.log(2.0 * jnp.pi)
    )
    # weak log-normal priors keep hyperparameters sane on tiny datasets
    prior = (
        -0.5 * (params.log_amp**2)
        - 0.5 * jnp.sum((params.log_ell - jnp.log(0.3)) ** 2)
        - 0.5 * ((params.log_noise - jnp.log(1e-2)) ** 2) / 4.0
    )
    return -(mll + prior)


_mll_grad = jax.jit(jax.value_and_grad(_neg_mll))

# convergence check: one fused kernel per step instead of ~6 host-dispatched
# ops (the fit loop is the suggest hot path)
_step_norm = jax.jit(lambda a, b: jnp.sqrt(sum(
    jnp.sum((x - y) ** 2)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))))


@jax.jit
def _fit_step(raw, m, v, x, y, mask, bc1, bc2, lr_t):
    """One fused Adam step on the negative MLL: grad + moment update +
    clamped parameter step + convergence norm in a single device dispatch.

    The Python loop used to issue ~20 tiny jax ops and 2 host syncs per
    step, which dominated warm-fit latency at large n. ``bc1``/``bc2`` are
    the host-computed bias corrections (1 - beta**t) and ``lr_t`` the
    decayed learning rate — value changes don't retrace. Returns the
    updated (raw, m, v) plus a stacked [loss, step_norm] pair so the caller
    pays ONE transfer per step; on a non-finite loss the caller discards
    the returned state, preserving the old break-before-update semantics.
    """
    b1, b2, eps = 0.9, 0.999, 1e-8
    loss, g = jax.value_and_grad(_neg_mll)(raw, x, y, mask)
    g = jax.tree.map(lambda gg: jnp.nan_to_num(gg, nan=0.0,
                                               posinf=0.0, neginf=0.0), g)
    m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
    v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
    mhat = jax.tree.map(lambda mm: mm / bc1, m)
    vhat = jax.tree.map(lambda vv: vv / bc2, v)
    new_raw = jax.tree.map(
        lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + eps),
        raw, mhat, vhat)
    # clamp to numerically-safe ranges (f32 cholesky)
    new_raw = {
        "log_amp": jnp.clip(new_raw["log_amp"], -4.0, 4.0),
        "log_ell": jnp.clip(new_raw["log_ell"], jnp.log(0.01), jnp.log(10.0)),
        "log_noise": jnp.clip(new_raw["log_noise"], -9.0, 0.0),
    }
    norm = jnp.sqrt(sum(
        jnp.sum((a - b) ** 2)
        for a, b in zip(jax.tree.leaves(new_raw), jax.tree.leaves(raw))))
    return new_raw, m, v, jnp.stack([loss, norm])


@jax.jit
def _posterior(raw: dict, x: jnp.ndarray, y: jnp.ndarray, xq: jnp.ndarray):
    params = GPParams(**raw)
    n = x.shape[0]
    noise = jnp.exp(params.log_noise) + 1e-4
    K = _kernel(params, x, x) + noise * jnp.eye(n)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    Kq = _kernel(params, x, xq)  # (n, m)
    mean = Kq.T @ alpha
    vsolve = jax.scipy.linalg.solve_triangular(L, Kq, lower=True)  # (n, m)
    var = jnp.exp(params.log_amp) - jnp.sum(vsolve * vsolve, axis=0)
    return mean, jnp.sqrt(jnp.maximum(var, 1e-10))


def _ucb_from_posterior(raw: dict, x, y, xq, beta) -> jnp.ndarray:
    mean, std = _posterior(raw, x, y, xq)
    return mean + beta * std


# Pre-engine pool scoring: one full Cholesky per call. Kept as the legacy
# baseline (use_engine=False) and the oracle behind ``ucb_reference``.
_ucb = jax.jit(_ucb_from_posterior)

# Fantasized UCB: vmap over F fantasy outcome vectors for the SAME design
# matrix (x augmented with pending points) — shape (F, n_aug) in, (F, m)
# scores out, one batched Cholesky per fantasy instead of a Python loop.
_ucb_fantasy_vmap = jax.jit(
    jax.vmap(_ucb_from_posterior, in_axes=(None, None, 0, None, None))
)


@dataclasses.dataclass
class FitInfo:
    """Observability + resume record of one fit() call.

    ``result`` is the returned (best-loss) hyperparameters; ``raw``/``m``/
    ``v``/``t`` are the Adam trajectory end-point a later fit can resume from
    (after a divergence they are reset to the best point with cold moments,
    so a poisoned trajectory is never persisted).
    """

    result: dict
    raw: dict
    m: dict
    v: dict
    t: int
    steps_run: int
    warm: bool
    converged: bool
    diverged: bool
    seconds: float


class GaussianProcessBandit:
    """Stateless-per-call GP regressor + UCB acquisition.

    ``fit(x, y, init=state.fit_init())`` resumes Adam from a persisted
    trajectory (paper §6.3 state saving): steps past the cold budget use a
    1/sqrt(t) learning-rate decay so the resumed trajectory actually settles,
    and the fit exits as soon as the *effective* gradient norm — the Adam-
    preconditioned, clamp-projected step divided by the learning rate —
    drops under ``grad_tol``. The projection matters: on noiseless data the
    MLL pins log_noise to its clamp boundary where the raw gradient stays
    large forever, yet the parameters cannot move; the projected norm goes to
    zero there. A converged warm start costs one gradient evaluation instead
    of ``fit_steps``; a cold fit's first ``fit_steps`` steps are
    bit-identical to the pre-warm-start behavior unless it genuinely plateaus
    below ``grad_tol`` (cold trajectories sit well above it in practice).

    The design matrix is bucket-padded (``posterior.train_bucket``) with
    noise-masked rows before entering the jitted MLL, so the Adam loop
    compiles once per bucket instead of once per trial count.
    """

    def __init__(self, dim: int, *, fit_steps: int = 60, lr: float = 0.08,
                 ucb_beta: float = DEFAULT_UCB_BETA, seed: int = 0,
                 grad_tol: float = 0.01):
        self.dim = dim
        self.fit_steps = fit_steps
        self.lr = lr
        self.ucb_beta = ucb_beta
        self.seed = seed
        self.grad_tol = grad_tol
        self.last_fit: Optional[FitInfo] = None

    def _cold_init(self):
        raw = {
            "log_amp": jnp.asarray(0.0),
            "log_ell": jnp.full((self.dim,), jnp.log(0.3)),
            "log_noise": jnp.asarray(jnp.log(1e-2)),
        }
        return raw, jax.tree.map(jnp.zeros_like, raw), jax.tree.map(jnp.zeros_like, raw), 0

    @staticmethod
    def _tree_f32(tree: Dict) -> dict:
        return {k: jnp.asarray(v, jnp.float32) for k, v in tree.items()}

    def fit(self, x: np.ndarray, y: np.ndarray,
            init: Optional[Dict] = None) -> dict:
        """Returns raw GP hyperparameters after Adam on the marginal likelihood.

        ``init`` (optional) is a PolicyState.fit_init() dict: raw params plus
        Adam moments and step count; the optimizer resumes mid-trajectory.
        """
        t_wall = time.perf_counter()
        n, d = np.asarray(x).shape
        bucket = train_bucket(n)
        xb = np.zeros((bucket, d), np.float32)
        yb = np.zeros((bucket,), np.float32)
        mb = np.zeros((bucket,), np.float32)
        xb[:n], yb[:n], mb[:n] = x, y, 1.0
        x = jnp.asarray(xb)
        y = jnp.asarray(yb)
        mask = jnp.asarray(mb)
        warm = init is not None
        if warm:
            raw = self._tree_f32(init["raw"])
            m = self._tree_f32(init["adam_m"])
            v = self._tree_f32(init["adam_v"])
            t0 = int(init["adam_t"])
        else:
            raw, m, v, t0 = self._cold_init()
        b1, b2 = 0.9, 0.999  # mirrored in _fit_step (eps lives there too)
        best_raw, best_loss = raw, float("inf")
        steps = 0
        converged = diverged = False
        loss = float("inf")
        for t in range(t0 + 1, t0 + self.fit_steps + 1):
            # resumed steps (past the cold budget) decay the lr so the
            # trajectory settles instead of orbiting the optimum forever
            lr_t = self.lr if t <= self.fit_steps else (
                self.lr * (self.fit_steps / t) ** 0.5)
            new_raw, new_m, new_v, stats = _fit_step(
                raw, m, v, x, y, mask, 1 - b1**t, 1 - b2**t, lr_t)
            steps += 1
            loss, norm = (float(s) for s in np.asarray(stats))
            if not np.isfinite(loss):  # singular cholesky: keep best-so-far
                raw = best_raw         # (discard the device-side update)
                diverged = True
                break
            if loss < best_loss:
                best_loss, best_raw = loss, raw
            raw, m, v = new_raw, new_m, new_v
            if self.grad_tol > 0.0:
                # effective gradient: the clamp-projected step / lr
                if norm < self.grad_tol * lr_t:
                    converged = True  # plateaued: stop descending
                    break
        if diverged:
            if not np.isfinite(best_loss):
                # diverged before ANY finite loss: a warm restore point that
                # is singular on the current data. Fall back to the cold
                # init so the persisted checkpoint self-heals instead of
                # pinning every future fit to the same poisoned point.
                best_raw, _, _, _ = self._cold_init()
                raw = best_raw
            result = raw  # already best_raw
            traj_raw, traj_m, traj_v, traj_t = best_raw, \
                jax.tree.map(jnp.zeros_like, best_raw), \
                jax.tree.map(jnp.zeros_like, best_raw), 0
        elif converged:
            result = raw if loss <= best_loss else best_raw
            traj_raw, traj_m, traj_v, traj_t = raw, m, v, t0 + steps
        else:
            final_loss = float(_mll_grad(raw, x, y, mask)[0])
            if not np.isfinite(final_loss):
                # the never-evaluated post-update end-point is singular:
                # persist the best point with cold moments, exactly like the
                # diverged branch, so the poisoned trajectory never resumes
                raw = best_raw
                traj_raw, traj_m, traj_v, traj_t = best_raw, \
                    jax.tree.map(jnp.zeros_like, best_raw), \
                    jax.tree.map(jnp.zeros_like, best_raw), 0
            else:
                traj_raw, traj_m, traj_v, traj_t = raw, m, v, t0 + steps
                if final_loss > best_loss:
                    raw = best_raw
            result = raw
        self.last_fit = FitInfo(
            result=result, raw=traj_raw, m=traj_m, v=traj_v, t=traj_t,
            steps_run=steps, warm=warm, converged=converged, diverged=diverged,
            seconds=time.perf_counter() - t_wall,
        )
        return result

    def ucb(self, raw: dict, x, y, xq) -> jnp.ndarray:
        """UCB scores for the full candidate pool in one vectorized call."""
        return _ucb(raw, jnp.asarray(x, jnp.float32),
                    jnp.asarray(y, jnp.float32), jnp.asarray(xq, jnp.float32),
                    jnp.float32(self.ucb_beta))

    def ucb_fantasized(self, raw: dict, x, y, pending_x, xq,
                       rng: np.random.RandomState, *, n_fantasies: int = 4
                       ) -> jnp.ndarray:
        """UCB averaged over fantasy outcomes for pending trials.

        Draws ``n_fantasies`` outcome vectors for the pending points from the
        current posterior, augments the training set with each, and scores
        the whole candidate pool under every fantasy via one vmapped batched
        solve — qUCB-style duplicate avoidance without a per-fantasy loop.
        """
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        pend = jnp.asarray(pending_x, jnp.float32)
        xq = jnp.asarray(xq, jnp.float32)
        mean_p, std_p = _posterior(raw, x, y, pend)
        eps = jnp.asarray(rng.randn(n_fantasies, pend.shape[0]), jnp.float32)
        y_fant = jnp.concatenate(
            [jnp.broadcast_to(y, (n_fantasies,) + y.shape),
             mean_p[None, :] + std_p[None, :] * eps],
            axis=1,
        )  # (F, n + p)
        x_aug = jnp.concatenate([x, pend], axis=0)
        scores = _ucb_fantasy_vmap(raw, x_aug, y_fant, xq,
                                   jnp.float32(self.ucb_beta))  # (F, m)
        return jnp.mean(scores, axis=0)

    def ucb_reference(self, raw: dict, x, y, xq) -> np.ndarray:
        """Per-candidate loop oracle for the vectorized path (tests only)."""
        out = np.empty((len(xq),), np.float32)
        for i in range(len(xq)):
            out[i] = float(
                _ucb(raw, jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
                     jnp.asarray(xq[i:i + 1], jnp.float32),
                     jnp.float32(self.ucb_beta))[0]
            )
        return out


# Multi-metric fit kernels: the SAME `_fit_step` / `_neg_mll` bodies vmapped
# over a leading metric axis. raw/adam moments/labels are batched (k, ...);
# the design, mask and Adam schedule scalars are shared. One device dispatch
# advances every metric's Adam trajectory one step, and the compiled program
# depends only on (k, bucket) — a study's k is fixed, so steady-state multi-
# metric ops compile exactly as often as single-objective ones.
_fit_step_metrics = jax.jit(jax.vmap(
    _fit_step, in_axes=(0, 0, 0, None, 0, None, None, None, None)))
_neg_mll_metrics = jax.jit(jax.vmap(_neg_mll, in_axes=(0, None, 0, None)))


def _stack_trees(trees: Sequence[Dict]) -> dict:
    """k per-metric hyperparameter trees -> one tree with a leading k axis."""
    return {key: jnp.stack([jnp.asarray(t[key], jnp.float32) for t in trees])
            for key in ("log_amp", "log_ell", "log_noise")}


def _unstack_tree(tree: Dict, k: int) -> List[dict]:
    """Leading-axis tree -> k per-metric trees (device views, no copies)."""
    return [{key: tree[key][i] for key in tree} for i in range(k)]


def _tree_where(cond_k: jnp.ndarray, a: Dict, b: Dict) -> dict:
    """Per-metric tree select: ``cond_k`` is a (k,) bool mask broadcast over
    each leaf's trailing dims (leaves carry the leading metric axis)."""
    def sel(x, y):
        c = cond_k.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(c, x, y)
    return {key: sel(a[key], b[key]) for key in a}


@dataclasses.dataclass
class MultiFitInfo:
    """Observability + resume record of one MultiMetricGP.fit call.

    Per-metric lists are metric-ordered; ``t`` is the SHARED Adam clock (all
    metrics step in lockstep through the vmapped kernel). Same best-vs-
    trajectory split as ``FitInfo``: ``results`` are the returned best-loss
    hyperparameters, ``raws``/``ms``/``vs``/``t`` the resumable trajectory.
    """

    results: List[dict]
    raws: List[dict]
    ms: List[dict]
    vs: List[dict]
    t: int
    steps_run: int
    warm: bool
    converged: bool
    diverged: bool
    seconds: float


class MultiMetricGP:
    """k independent GPs (one per objective metric) fitted in lockstep.

    Fitting k metrics used to mean k sequential Adam loops — k compiled-
    kernel invocations and k host syncs per step. Here every metric shares
    the engine's bucket-padded design and advances through ONE vmapped
    ``_fit_step`` dispatch per step, with a single stacked (k, 2) loss/norm
    transfer. Divergence and best-loss tracking are per metric (a singular
    Cholesky in one metric's trajectory restores THAT metric to its best
    point — or the cold init — without discarding the others); the loop
    exits when every metric's projected step norm is under ``grad_tol``.

    ``fit`` consumes/produces per-metric hyperparameter trees so each
    metric's ``CholeskyPosterior``/``SparsePosterior`` conditions with its
    own kernel, while the schema-v4 checkpoint resumes all k trajectories
    from one shared Adam clock.
    """

    def __init__(self, dim: int, k: int, *, fit_steps: int = 60,
                 lr: float = 0.08, seed: int = 0, grad_tol: float = 0.01):
        self.dim = dim
        self.k = k
        self.fit_steps = fit_steps
        self.lr = lr
        self.seed = seed
        self.grad_tol = grad_tol
        self.last_fit: Optional[MultiFitInfo] = None

    def _cold_stack(self):
        single = {
            "log_amp": jnp.asarray(0.0),
            "log_ell": jnp.full((self.dim,), jnp.log(0.3)),
            "log_noise": jnp.asarray(jnp.log(1e-2)),
        }
        raw = _stack_trees([single] * self.k)
        zeros = {key: jnp.zeros_like(v) for key, v in raw.items()}
        return raw, zeros, dict(zeros), 0

    def fit(self, x: np.ndarray, y: np.ndarray,
            init: Optional[Dict] = None) -> List[dict]:
        """Per-metric raw hyperparameters after the lockstep Adam fit.

        ``y`` is (n, k), each column already z-scored by the caller. ``init``
        (optional) is ``PolicyState.metric_fit_init()``: per-metric raw
        params + Adam moments and the shared step count.
        """
        t_wall = time.perf_counter()
        n, d = np.asarray(x).shape
        bucket = train_bucket(n)
        xb = np.zeros((bucket, d), np.float32)
        yb = np.zeros((self.k, bucket), np.float32)
        mb = np.zeros((bucket,), np.float32)
        xb[:n] = x
        yb[:, :n] = np.asarray(y, np.float32).T
        mb[:n] = 1.0
        x = jnp.asarray(xb)
        yk = jnp.asarray(yb)
        mask = jnp.asarray(mb)
        warm = init is not None
        if warm:
            raw = _stack_trees(init["raws"])
            m = _stack_trees(init["adam_m"])
            v = _stack_trees(init["adam_v"])
            t0 = int(init["adam_t"])
        else:
            raw, m, v, t0 = self._cold_stack()
        b1, b2 = 0.9, 0.999  # mirrored in _fit_step (eps lives there too)
        cold_raw, _zm, _zv, _zt = self._cold_stack()
        best_raw = raw
        best_loss = np.full((self.k,), np.inf)
        losses = np.full((self.k,), np.inf)
        steps = 0
        converged = diverged = False
        for t in range(t0 + 1, t0 + self.fit_steps + 1):
            lr_t = self.lr if t <= self.fit_steps else (
                self.lr * (self.fit_steps / t) ** 0.5)
            new_raw, new_m, new_v, stats = _fit_step_metrics(
                raw, m, v, x, yk, mask, 1 - b1**t, 1 - b2**t, lr_t)
            steps += 1
            stats = np.asarray(stats)           # (k, 2): ONE transfer/step
            losses, norms = stats[:, 0], stats[:, 1]
            if not np.all(np.isfinite(losses)):
                # a singular cholesky in >=1 metric: keep best-so-far
                # everywhere (discard the whole device-side update — the
                # shared clock means partial acceptance would deschedule)
                raw = best_raw
                diverged = True
                break
            improved = losses < best_loss
            if improved.any():
                best_raw = _tree_where(jnp.asarray(improved), raw, best_raw)
                best_loss = np.where(improved, losses, best_loss)
            raw, m, v = new_raw, new_m, new_v
            if self.grad_tol > 0.0 and np.all(norms < self.grad_tol * lr_t):
                converged = True  # every metric plateaued
                break
        if diverged:
            # metrics that never saw a finite loss self-heal to the cold init
            ok = jnp.asarray(np.isfinite(best_loss))
            best_raw = _tree_where(ok, best_raw, cold_raw)
            result = best_raw
            zeros = {key: jnp.zeros_like(val) for key, val in best_raw.items()}
            traj_raw, traj_m, traj_v, traj_t = best_raw, zeros, dict(zeros), 0
        elif converged:
            result = _tree_where(jnp.asarray(losses <= best_loss),
                                 raw, best_raw)
            traj_raw, traj_m, traj_v, traj_t = raw, m, v, t0 + steps
        else:
            final = np.asarray(_neg_mll_metrics(raw, x, yk, mask))
            if not np.all(np.isfinite(final)):
                # never-evaluated post-update end-point singular somewhere:
                # persist best points with cold moments (see the single-
                # objective fit for the rationale)
                ok = jnp.asarray(np.isfinite(best_loss))
                best_raw = _tree_where(ok, best_raw, cold_raw)
                raw = best_raw
                zeros = {key: jnp.zeros_like(val)
                         for key, val in best_raw.items()}
                traj_raw, traj_m, traj_v, traj_t = best_raw, zeros, \
                    dict(zeros), 0
                result = raw
            else:
                traj_raw, traj_m, traj_v, traj_t = raw, m, v, t0 + steps
                result = _tree_where(jnp.asarray(final <= best_loss),
                                     raw, best_raw)
        self.last_fit = MultiFitInfo(
            results=_unstack_tree(result, self.k),
            raws=_unstack_tree(traj_raw, self.k),
            ms=_unstack_tree(traj_m, self.k),
            vs=_unstack_tree(traj_v, self.k),
            t=traj_t, steps_run=steps, warm=warm, converged=converged,
            diverged=diverged, seconds=time.perf_counter() - t_wall,
        )
        return self.last_fit.results


@jax.jit
def _stack_means(raw_stack: dict, xs: jnp.ndarray, alphas: jnp.ndarray,
                 xq: jnp.ndarray) -> jnp.ndarray:
    """Summed posterior means of a level stack in ONE device call.

    ``raw_stack`` leaves carry a leading level axis; ``xs`` (levels, B, d)
    and ``alphas`` (levels, B) are bucket-padded with zero alpha on padding,
    so padded rows contribute exactly nothing. Each level is a fused
    ``matern52_gram_matvec`` — the (n, m) cross-Gram is never materialized
    and there is no per-level host sync.
    """
    total = jnp.zeros((xq.shape[0],), jnp.float32)
    for i in range(xs.shape[0]):  # static depth: unrolled into one program
        ell = jnp.exp(raw_stack["log_ell"][i])
        amp = jnp.exp(raw_stack["log_amp"][i])
        total = total + kops.matern52_gram_matvec(
            xs[i] / ell, xq / ell, alphas[i], amp, impl="auto")
    return total


@dataclasses.dataclass
class StackLevel:
    """One fitted level of a residual stack: hyperparameters + the (x, y)
    design it conditions on. ``y`` is already residual to the levels below;
    ``posterior`` is the level's cached factorization (dense Cholesky up to
    ``SPARSE_THRESHOLD`` design rows, SGPR inducing-point above — built once
    at fit time, queries and appends never refactorize). ``mean_x`` /
    ``mean_alpha`` are the MEAN-BASIS arrays feeding the fused stack-mean
    matvec: mean(q) = K(q, mean_x) · mean_alpha. For a dense level that is
    the design itself with K^-1 y weights; for a sparse level it is the
    (n_inducing, d) inducing set with the inducing-basis weights — an O(m)
    contraction per level regardless of trial count. ``x``/``y`` always
    remain the REAL design (incumbent selection reads them)."""

    raw: dict
    x: jnp.ndarray          # (n, d) float32, current study's unit space
    y: jnp.ndarray          # (n,) float32 residual targets
    alpha: jnp.ndarray      # posterior mean weights in the mean basis
    posterior: "CholeskyPosterior | SparsePosterior"
    mean_x: np.ndarray      # (nb, d) mean-basis points (design or Z)
    mean_alpha: np.ndarray  # (nb,) weights: mean(q) = K(q, mean_x)·mean_alpha


def _zscore(y: np.ndarray) -> np.ndarray:
    """Per-study label normalization (each stack level owns its own scale)."""
    return (y - float(np.mean(y))) / float(np.std(y) + 1e-9)


class StackedResidualGP:
    """Sequential residual GP stack for transfer learning (paper's transfer
    capability; stacking per the Vizier GP-bandit design, arXiv:2408.11527).

    ``fit_level`` appends one base GP fitted on the residuals of the stack
    so far: level 0 models the first prior study, level 1 the second prior's
    residual to level 0, ..., and the final level the *current* study's
    residual to everything below. The stacked posterior has mean = sum of
    level means and the TOP level's variance (lower levels act as a learned
    mean prior, they do not inflate predictive uncertainty). Passing
    ``raw=`` reuses persisted hyperparameters (schema v3 per-prior-level
    checkpoints) and skips the Adam fit entirely — the level then costs one
    Cholesky instead of ``fit_steps`` likelihood evaluations.

    Level means are served by one batched ``_stack_means`` call over
    bucket-padded per-level arrays — a single device dispatch regardless of
    stack depth, with no cross-Gram materialization.
    """

    def __init__(self, dim: int, *, seed: int = 0):
        self.dim = dim
        self.seed = seed
        self.levels: List[StackLevel] = []
        self.last_fit: Optional[FitInfo] = None
        self._stacked_cache: Dict[int, tuple] = {}

    @property
    def depth(self) -> int:
        return len(self.levels)

    def _stacked_arrays(self, below: int):
        """Bucket-padded (raw_stack, xs, alphas) for levels[:below], cached
        per depth (rebuilt only when a new level is fitted)."""
        if below not in self._stacked_cache:
            levels = self.levels[:below]
            bucket = max(train_bucket(int(lvl.mean_x.shape[0]))
                         for lvl in levels)
            xs = np.zeros((len(levels), bucket, self.dim), np.float32)
            alphas = np.zeros((len(levels), bucket), np.float32)
            for i, lvl in enumerate(levels):
                n = int(lvl.mean_x.shape[0])
                xs[i, :n] = lvl.mean_x
                alphas[i, :n] = lvl.mean_alpha
            raw_stack = {
                k: jnp.stack([jnp.asarray(lvl.raw[k], jnp.float32)
                              for lvl in levels])
                for k in ("log_amp", "log_ell", "log_noise")
            }
            self._stacked_cache[below] = (
                raw_stack, jnp.asarray(xs), jnp.asarray(alphas))
        return self._stacked_cache[below]

    def mean(self, xq, *, below: Optional[int] = None) -> np.ndarray:
        """Summed posterior mean of the first ``below`` levels (default all)
        at the query points — every level folded into one fused batched
        gram-matvec dispatch (query shapes bucket-padded, so steady-state
        calls never retrace)."""
        below = self.depth if below is None else below
        m = len(xq)
        if below <= 0 or m == 0:
            return np.zeros((m,), np.float32)
        raw_stack, xs, alphas = self._stacked_arrays(below)
        xqp = np.zeros((pool_bucket(m), self.dim), np.float32)
        xqp[:m] = np.asarray(xq, np.float32)
        return np.asarray(
            _stack_means(raw_stack, xs, alphas, jnp.asarray(xqp)))[:m]

    def fit_level(self, x: np.ndarray, y: np.ndarray,
                  init: Optional[Dict] = None, raw: Optional[Dict] = None,
                  capacity: Optional[int] = None) -> dict:
        """Fits the next level on ``y`` minus the stack-so-far mean at ``x``.

        ``y`` must already be label-normalized for its own study. ``raw``
        (persisted v3 prior-level hyperparameters) skips the fit;
        ``capacity`` reserves rank-1 append headroom in the level's cached
        factorization (the policy passes pending + batch count for the
        level that will serve the acquisition). Returns the fitted raw
        hyperparameters; ``last_fit`` carries the FitInfo of the most recent
        *fitted* level (the top level's is what the warm-start checkpoint
        persists).

        Above ``SPARSE_THRESHOLD`` design rows the level goes sparse: the
        hyperparameter fit runs on a deterministic evenly-strided subsample
        (``FIT_SUBSAMPLE`` rows — the MLL stays O(bounded) as the study
        grows) and the cached factorization is the SGPR inducing-point
        posterior instead of the n×n Cholesky. At or below the threshold
        the dense path is bit-for-bit unchanged.
        """
        resid = np.asarray(y, np.float32) - self.mean(x)
        n = int(np.asarray(x).shape[0])
        sparse = n > SPARSE_THRESHOLD
        if raw is None:
            gp = GaussianProcessBandit(dim=self.dim, seed=self.seed)
            if sparse:
                if init is not None:
                    gp.fit_steps = min(gp.fit_steps, SPARSE_WARM_FIT_STEPS)
                idx = _fit_subsample_idx(n)
                raw = gp.fit(np.asarray(x)[idx], resid[idx], init=init)
            else:
                raw = gp.fit(x, resid, init=init)
            self.last_fit = gp.last_fit
        else:
            raw = {k: jnp.asarray(v, jnp.float32) for k, v in raw.items()}
        if sparse:
            post = SparsePosterior(raw, x, resid, n_inducing=N_INDUCING,
                                   seed=self.seed, capacity=capacity)
            mean_x = post.inducing_z
            mean_alpha = np.asarray(post.alpha)
        else:
            post = CholeskyPosterior(raw, x, resid, capacity=capacity)
            mean_x = np.asarray(x, np.float32)
            mean_alpha = np.asarray(post.alpha)[:n]
        # x/y stay host-side: every consumer reads them back as numpy, and a
        # device round-trip of the unpadded (n, d) design would compile a
        # fresh convert_element_type for every distinct n as the study grows.
        self.levels.append(StackLevel(
            raw=raw, x=np.asarray(x, np.float32),
            y=np.asarray(resid, np.float32),
            alpha=post.alpha, posterior=post,
            mean_x=mean_x, mean_alpha=mean_alpha,
        ))
        self._stacked_cache.clear()
        return raw

    def predict(self, xq) -> "tuple[np.ndarray, np.ndarray]":
        """Stacked posterior (mean of all levels, std of the top level) —
        served from the top level's cached factorization, no refit."""
        if not self.levels:
            raise ValueError("predict() on an empty stack")
        m_top, s_top = self.levels[-1].posterior.query(xq)
        return self.mean(xq, below=self.depth - 1) + m_top, s_top


class GPBanditPolicy(Policy):
    """The paper's GP-bandit example as a full Pythia policy.

    With ``warm_start=True`` (default) each suggest operation persists a
    versioned PolicyState record (kernel hyperparameters + Adam trajectory +
    per-prior-level hyperparameters) into the reserved ``repro.gp_bandit``
    study-metadata namespace and resumes the fit from it on the next
    operation — the paper's §6.3 state mechanism applied to the
    hyperparameter optimization. Incompatible or corrupt state silently
    degrades to a cold fit.

    Transfer learning: when the study lists ``prior_study_names``, their
    completed trials are aligned into the current study's feature space
    (``align_prior_trials``) and fitted as a sequential residual stack
    (``StackedResidualGP``) underneath the current study's GP; the
    acquisition maximizes stacked-mean + beta * top-level-std. A prior study
    that is missing, deleted, unreadable, or unalignable is skipped — the
    fully degraded case is exactly the single-study cold fit, never a failed
    operation. With priors present the policy suggests from the stack even
    before ``min_completed`` current trials exist (that head start is the
    point of transfer). Prior-level fits are reused from the persisted v3
    checkpoint for the longest prefix of priors whose aligned-trial
    fingerprints still match (``last_prior_levels_reused``).

    Multi-metric studies are first-class (they used to silently degrade to
    random sampling): ``_suggest_multi`` fits one GP per objective metric —
    all k Adam trajectories advancing through one vmapped step per
    iteration — builds one cached posterior per metric over the shared
    engine buckets, and acquires by hypervolume-scalarized UCB with
    random-weight Chebyshev scalarizations drawn per batch member. State
    persists under schema v4 with per-metric trajectories; transfer
    learning stays single-objective-only (``_load_priors`` skips
    multi-objective studies).

    ``use_engine=False`` switches the single-objective acquisition to the
    pre-engine path — one full Cholesky refactorization per batch member —
    kept as the numerical baseline for tests and ``make bench-acquisition``.
    Both paths share the candidate pool (one scrambled-Halton global half +
    local perturbations of the incumbent, drawn once per operation) and the
    fantasy outcomes, so their suggestions agree trial-for-trial.
    """

    def __init__(self, supporter: PolicySupporter, *, n_candidates: int = 2000,
                 min_completed: int = 5, seed: int = 0, warm_start: bool = True,
                 min_prior_trials: int = 5, use_engine: bool = True,
                 n_fantasies: int = 4):
        self._supporter = supporter
        self._n_candidates = n_candidates
        self._min_completed = min_completed
        self._seed = seed
        self._warm_start = warm_start
        self._min_prior_trials = min_prior_trials
        self._use_engine = use_engine
        self._n_fantasies = n_fantasies
        # per-instance suggest-op counter: part of the acquisition RNG nonce
        # (see suggest()), so repeated ops on ONE policy object never replay
        # the same candidate pool even at a fixed trial count
        self._op_count = 0
        # observability for tests/benchmarks (mirrors
        # SerializableDesignerPolicy.last_restore_was_incremental)
        self.last_fit_seconds: float = 0.0
        self.last_fit_steps: int = 0
        self.last_fit_warm: bool = False
        self.last_transfer_levels: int = 0
        self.last_prior_levels_reused: int = 0
        self.last_sparse: bool = False

    def _load_priors(self, request: SuggestRequest,
                     converter: TrialToArrayConverter):
        """[(study name, aligned features, labels)] per usable prior study.

        Defensive end to end: a deleted prior study, a failed multi-read, a
        config that no longer parses, or a trial set that does not align all
        degrade to skipping that prior — never to a failed operation.
        """
        config = request.study_config
        names = [n for n in config.prior_study_names if n != request.study_guid]
        if not names or config.is_multi_objective:
            return []
        try:
            multi = self._supporter.GetTrialsMulti(
                names, status_matches="SUCCEEDED")
        except Exception:  # noqa: BLE001 — one bad prior must not kill all
            multi = {}
        out = []
        for name in names:
            try:
                trials = multi.get(name)
                if trials is None:
                    trials = self._supporter.GetTrials(
                        name, status_matches="SUCCEEDED")
                if len(trials) < self._min_prior_trials:
                    continue
                prior_config = self._supporter.GetStudyConfig(name)
                px, py = align_prior_trials(trials, prior_config, converter)
                if px.shape[0] < self._min_prior_trials:
                    continue
                out.append((name, px, py))
            except Exception:  # noqa: BLE001 — degrade to a colder fit
                continue
        return out

    def _draw_pool(self, rng: np.random.RandomState, dim: int,
                   incumbent: np.ndarray) -> np.ndarray:
        """One candidate pool per suggest operation: a scrambled-Halton
        global half (low-discrepancy, seeded by the op rng) plus local
        perturbations sharpening exploitation around the incumbent."""
        glob = halton.scrambled_halton(self._n_candidates, dim, rng)
        local = np.clip(
            incumbent[None, :]
            + 0.08 * rng.randn(self._n_candidates // 4, dim),
            0.0, 1.0,
        )
        return np.vstack([glob, local])

    def suggest(self, request: SuggestRequest) -> SuggestDecision:
        config = request.study_config
        converter = TrialToArrayConverter(config.search_space)
        completed = self._supporter.CompletedTrials(request.study_guid)
        x, y_all = trials_to_xy(completed, config, converter)
        op_nonce = self._op_count
        self._op_count += 1

        priors = self._load_priors(request, converter)
        self.last_transfer_levels = len(priors)
        # reset per-operation observability: a priors-only suggest performs
        # no current-study fit and must not report the previous one's
        self.last_fit_seconds, self.last_fit_steps, self.last_fit_warm = \
            0.0, 0, False
        self.last_prior_levels_reused = 0

        if x.shape[0] < self._min_completed and not priors:
            # cold start: random until enough completed trials to fit
            suggestions = [
                TrialSuggestion(parameters=config.search_space.sample())
                for _ in range(request.count)
            ]
            return SuggestDecision(suggestions=suggestions)

        if config.is_multi_objective:
            return self._suggest_multi(request, config, converter, completed,
                                       x, y_all, op_nonce)

        # pending trials are loaded up front: the top level's factorization
        # reserves rank-1 headroom for their fantasies + the batch members
        pending = self._supporter.ActiveTrials(request.study_guid)
        fantasy_x = converter.to_features(
            [t.parameters for t in pending]) if pending else None
        n_pend = 0 if fantasy_x is None else len(fantasy_x)
        # Acquisition RNG: seeding by completed count ALONE meant consecutive
        # suggest ops at an unchanged completed count replayed the identical
        # Halton scrambling, local perturbations and fantasy draws — repeated
        # suggestions and zero batch diversity until a trial completed. The
        # nonce mixes in the pending count (service-side ops observe the
        # ACTIVE trials earlier suggestions created) and the per-instance op
        # counter (direct back-to-back suggest() calls on one object). Every
        # component is a deterministic function of the observed study
        # snapshot + op index, so identical snapshots still suggest
        # identically across topologies, replays and warm/cold servers.
        rng = np.random.RandomState(
            (self._seed + len(completed) + 1000003 * n_pend
             + 7919 * op_nonce) % (2 ** 32))
        has_current = x.shape[0] >= 1
        headroom = n_pend + request.count

        prior_fps = {name: int(px.shape[0]) for name, px, _py in priors}
        reusable: List[Dict] = []
        if self._warm_start and priors:
            reusable = load_prior_levels(
                request.study_metadata, dim=converter.dim,
                priors=[(name, int(px.shape[0])) for name, px, _py in priors])
        stack = StackedResidualGP(dim=converter.dim, seed=self._seed)
        for i, (_name, px, py) in enumerate(priors):
            top_prior = (i == len(priors) - 1) and not has_current
            stack.fit_level(
                px, _zscore(py),
                raw=reusable[i] if i < len(reusable) else None,
                capacity=px.shape[0] + headroom if top_prior else None)
        self.last_prior_levels_reused = min(len(reusable), len(priors))

        fit_info = None
        if has_current:
            yn = _zscore(y_all[:, 0])
            state = None
            if self._warm_start:
                state = load_state(request.study_metadata, dim=converter.dim,
                                   num_trials=x.shape[0],
                                   prior_fingerprints=prior_fps)
            stack.fit_level(
                x, yn, init=state.fit_init() if state is not None else None,
                capacity=x.shape[0] + headroom)
            fit_info = stack.last_fit
            self.last_fit_seconds = fit_info.seconds
            self.last_fit_steps = fit_info.steps_run
            self.last_fit_warm = fit_info.warm
        # acquisition works on the TOP level (the current study's residual GP
        # when any current trials exist, else the deepest prior level); the
        # levels below contribute a fixed mean shift.
        top = stack.levels[-1]
        self.last_sparse = isinstance(top.posterior, SparsePosterior)
        raw = top.raw
        n_below = stack.depth - 1
        xs = np.asarray(top.x, np.float64)
        ys = np.asarray(top.y, np.float64)
        mu_xs = stack.mean(xs, below=n_below).astype(np.float64)

        # one candidate pool per operation (incumbent = best STACKED value,
        # not best residual); pending-trial dedup with the empty-pool
        # fallback — a pending trial at every candidate must degrade to the
        # unfiltered pool, never to an argmax over zero candidates
        incumbent = xs[int(np.argmax(ys + mu_xs))]
        pool = self._draw_pool(rng, converter.dim, incumbent)
        fantasize = fantasy_x is not None and n_pend > 0 and (
            config.observation_noise != ObservationNoise.HIGH
        )
        if fantasize:
            d = np.linalg.norm(pool[:, None, :] - fantasy_x[None], axis=-1)
            filtered = pool[np.min(d, axis=1) > 1e-3]
            if len(filtered):
                pool = filtered
        pool_mu = stack.mean(pool, below=n_below) if n_below else \
            np.zeros((len(pool),), np.float32)

        beta = DEFAULT_UCB_BETA
        y_pend = None
        if fantasize:
            # pending outcomes fantasized from the current posterior; UCB is
            # linear in the mean, so averaging scores over F fantasy vectors
            # equals scoring once at the fantasy-averaged outcomes
            if self._use_engine:
                mean_p, std_p = top.posterior.query(fantasy_x)
            else:
                mp, sp = _posterior(raw, jnp.asarray(xs, jnp.float32),
                                    jnp.asarray(ys, jnp.float32),
                                    jnp.asarray(fantasy_x, jnp.float32))
                mean_p, std_p = np.asarray(mp), np.asarray(sp)
            eps = rng.randn(self._n_fantasies, n_pend)
            y_pend = mean_p + std_p * eps.mean(axis=0)

        if self._use_engine:
            picks = self._suggest_engine(top.posterior, pool, pool_mu, beta,
                                         fantasy_x if fantasize else None,
                                         y_pend, request.count)
        else:
            picks = self._suggest_legacy(raw, xs, ys, pool, pool_mu, beta,
                                         fantasy_x if fantasize else None,
                                         y_pend, request.count)
        suggestions = [
            TrialSuggestion(parameters=converter.to_parameters(p[None, :])[0])
            for p in picks
        ]

        if self._warm_start and fit_info is not None:
            # persist the fit checkpoint so the next (stateless) invocation
            # resumes Adam instead of refitting from scratch. SendMetadata is
            # the single write path: in-process it applies atomically through
            # the datastore, remote it is buffered into the batch response
            # (zero extra wire frames). The decision's own delta stays empty
            # so the service never applies the same checkpoint twice.
            delta = MetadataDelta()
            store_state(delta, PolicyState.from_fit(
                fit_info, dim=converter.dim, num_trials=x.shape[0],
                prior_fingerprints=prior_fps,
                prior_levels=[
                    (name, int(px.shape[0]), stack.levels[i].raw)
                    for i, (name, px, _py) in enumerate(priors)
                ]))
            self._supporter.SendMetadata(delta)
        return SuggestDecision(suggestions=suggestions)

    def _suggest_multi(self, request: SuggestRequest, config: StudyConfig,
                       converter: TrialToArrayConverter, completed,
                       x: np.ndarray, y_all: np.ndarray,
                       op_nonce: int) -> SuggestDecision:
        """Multi-metric acquisition: one GP per metric on the shared engine
        buckets, hypervolume-scalarized UCB over one candidate pool.

        Fit: all k metrics advance through ONE vmapped Adam step per
        iteration (``MultiMetricGP``), warm-started from the schema-v4
        per-metric trajectories. Each metric then gets its own
        ``CholeskyPosterior``/``SparsePosterior`` over the SAME z-scored
        design bucket — identical shapes, so every engine kernel stays on
        its single compiled program regardless of k.

        Acquire: per batch member, draw a positive weight vector w from the
        op RNG (batch diversity comes from the weights, not greedy
        fantasization alone) and maximize the hypervolume scalarization
        s_w(u) = min_j((u_j - ref_j)/w_j) (+ a small averaged term, see
        ``HV_AUGMENT``) of the per-metric UCB vector u over the pool, with
        the reference point anchored below the observed frontier
        (``default_reference_point``). Maximizing E_w[max s_w] targets
        hypervolume improvement (the Vizier GP-bandit scalarization,
        arXiv:2408.11527). Pending trials are fantasized per metric with
        rank-1 appends; picked members fantasize at their per-metric
        posterior means via ``append_pool_member``.
        """
        pending = self._supporter.ActiveTrials(request.study_guid)
        fantasy_x = converter.to_features(
            [t.parameters for t in pending]) if pending else None
        n_pend = 0 if fantasy_x is None else len(fantasy_x)
        # same acquisition-RNG nonce as the single-objective path (see
        # suggest()): deterministic per observed snapshot + op index
        rng = np.random.RandomState(
            (self._seed + len(completed) + 1000003 * n_pend
             + 7919 * op_nonce) % (2 ** 32))
        headroom = n_pend + request.count
        k = len(config.metrics)
        metric_names = [mi.name for mi in config.metrics]
        n = int(x.shape[0])

        # per-metric z-scoring: each objective owns its own scale, so one
        # wide-range metric cannot drown the others in the scalarization
        yz = np.stack([_zscore(y_all[:, j]) for j in range(k)], axis=1)

        state = None
        if self._warm_start:
            state = load_metric_states(
                request.study_metadata, dim=converter.dim, num_trials=n,
                metric_names=metric_names)
        gp = MultiMetricGP(dim=converter.dim, k=k, seed=self._seed)
        init = state.metric_fit_init() if state is not None else None
        sparse = n > SPARSE_THRESHOLD
        if sparse:
            if init is not None:
                gp.fit_steps = min(gp.fit_steps, SPARSE_WARM_FIT_STEPS)
            idx = _fit_subsample_idx(n)
            raws = gp.fit(x[idx], yz[idx], init=init)
        else:
            raws = gp.fit(x, yz, init=init)
        fit_info = gp.last_fit
        self.last_fit_seconds = fit_info.seconds
        self.last_fit_steps = fit_info.steps_run
        self.last_fit_warm = fit_info.warm
        self.last_sparse = sparse

        # one posterior per metric over the SAME design rows and capacity:
        # identical bucket shapes -> the engine kernels compiled for metric 0
        # serve metrics 1..k-1 (and every single-objective study) unchanged
        posts: List = []
        for j in range(k):
            if sparse:
                posts.append(SparsePosterior(
                    raws[j], x, yz[:, j], n_inducing=N_INDUCING,
                    seed=self._seed, capacity=n + headroom))
            else:
                posts.append(CholeskyPosterior(
                    raws[j], x, yz[:, j], capacity=n + headroom))

        # incumbent frontier + reference point from the OBSERVED (z-scored)
        # objectives; the pool sharpens around a balanced frontier member
        front_idx = pareto_frontier_indices(yz)
        ref = default_reference_point(yz)                     # (k,)
        front = yz[front_idx]
        incumbent = x[front_idx[int(np.argmax(front.sum(axis=1)))]]
        pool = self._draw_pool(rng, converter.dim, incumbent)

        fantasize = fantasy_x is not None and n_pend > 0 and (
            config.observation_noise != ObservationNoise.HIGH
        )
        if fantasize:
            d = np.linalg.norm(pool[:, None, :] - fantasy_x[None], axis=-1)
            filtered = pool[np.min(d, axis=1) > 1e-3]
            if len(filtered):
                pool = filtered
            # per-metric fantasy outcomes, conditioned with rank-1 appends;
            # ONE eps draw shared across metrics keeps the fantasies
            # consistent (a lucky pending trial is lucky on every metric)
            eps = rng.randn(self._n_fantasies, n_pend).mean(axis=0)
            for post in posts:
                mean_p, std_p = post.query(fantasy_x)
                for px, py in zip(fantasy_x, mean_p + std_p * eps):
                    post.append(px, py)

        for post in posts:
            post.set_pool(pool)

        beta = DEFAULT_UCB_BETA
        picks: List[np.ndarray] = []
        picked_idx: List[int] = []
        u = np.empty((k, len(pool)), np.float64)
        for b in range(request.count):
            # random positive scalarization weights per batch member: each
            # member chases a different frontier direction
            w = rng.rand(k) + 1e-3
            w = w / w.sum()
            for j, post in enumerate(posts):
                mean, std = post.pool_mean_std()   # fused, one sync/metric
                u[j] = mean + beta * std
            t = (u - ref[:, None]) / w[:, None]
            scores = np.min(t, axis=0) + HV_AUGMENT * np.mean(t, axis=0)
            scores[picked_idx] = -np.inf
            i = int(np.argmax(scores))
            picks.append(pool[i])
            picked_idx.append(i)
            if b + 1 < request.count:
                # fantasize the member at its posterior mean on EVERY metric
                for post in posts:
                    post.append_pool_member(i)
        suggestions = [
            TrialSuggestion(parameters=converter.to_parameters(p[None, :])[0])
            for p in picks
        ]

        if self._warm_start and fit_info is not None:
            # schema-v4 checkpoint: metric 0's trajectory doubles as the
            # top-level record (single-blob layout), metric_states carries
            # all k trajectories under the shared Adam clock
            info0 = FitInfo(
                result=fit_info.results[0], raw=fit_info.raws[0],
                m=fit_info.ms[0], v=fit_info.vs[0], t=fit_info.t,
                steps_run=fit_info.steps_run, warm=fit_info.warm,
                converged=fit_info.converged, diverged=fit_info.diverged,
                seconds=fit_info.seconds)
            delta = MetadataDelta()
            store_state(delta, PolicyState.from_fit(
                info0, dim=converter.dim, num_trials=n,
                metric_states=[
                    (metric_names[j], fit_info.raws[j], fit_info.ms[j],
                     fit_info.vs[j])
                    for j in range(k)
                ]))
            self._supporter.SendMetadata(delta)
        return SuggestDecision(suggestions=suggestions)

    def _suggest_engine(self, post: "CholeskyPosterior | SparsePosterior",
                        pool, pool_mu, beta, fantasy_x, y_pend,
                        count: int) -> List[np.ndarray]:
        """Factorized-posterior batch: pending fantasies and picked members
        extend the op's single factorization with rank-1 appends (dense: the
        n×n Cholesky; sparse: the m×m inducing factor); pool scores refresh
        incrementally per member from the cached cross-solve."""
        if fantasy_x is not None:
            for px, py in zip(fantasy_x, y_pend):
                post.append(px, py)
        post.set_pool(pool)
        picks: List[np.ndarray] = []
        picked_idx: List[int] = []
        for k in range(count):
            scores = post.pool_ucb(beta) + pool_mu
            scores[picked_idx] = -np.inf
            i = int(np.argmax(scores))
            picks.append(pool[i])
            picked_idx.append(i)
            if k + 1 < count:
                # fantasize the new member at its posterior mean (read from
                # the cached pool means ON DEVICE) so later members avoid it
                post.append_pool_member(i)
        return picks

    def _suggest_legacy(self, raw, xs, ys, pool, pool_mu, beta, fantasy_x,
                        y_pend, count: int) -> List[np.ndarray]:
        """Pre-engine baseline: one full Cholesky refactorization per batch
        member (plus one per fantasy-mean query) through the jitted
        ``_ucb``/``_posterior`` kernels — identical math, redundant
        factorizations and shape-driven retraces. Kept for
        ``make bench-acquisition`` and the engine-equivalence tests."""
        xs_aug = np.asarray(xs, np.float64)
        ys_aug = np.asarray(ys, np.float64)
        if fantasy_x is not None:
            xs_aug = np.vstack([xs_aug, fantasy_x])
            ys_aug = np.concatenate([ys_aug, y_pend])
        picks: List[np.ndarray] = []
        picked_idx: List[int] = []
        for k in range(count):
            scores = np.asarray(
                _ucb(raw, jnp.asarray(xs_aug, jnp.float32),
                     jnp.asarray(ys_aug, jnp.float32),
                     jnp.asarray(pool, jnp.float32), jnp.float32(beta))
            ) + pool_mu
            scores[picked_idx] = -np.inf
            i = int(np.argmax(scores))
            picks.append(pool[i])
            picked_idx.append(i)
            if k + 1 < count:
                mean, _ = _posterior(raw, jnp.asarray(xs_aug, jnp.float32),
                                     jnp.asarray(ys_aug, jnp.float32),
                                     jnp.asarray(pool[i][None, :], jnp.float32))
                xs_aug = np.vstack([xs_aug, pool[i][None, :]])
                ys_aug = np.concatenate([ys_aug, np.asarray(mean, np.float64)])
        return picks

    def early_stop(self, request: EarlyStopRequest) -> EarlyStopDecisions:
        from repro.core import early_stopping

        config = request.study_config
        all_trials = self._supporter.GetTrials(request.study_guid)
        by_id = {t.id: t for t in all_trials}
        decisions = []
        for tid in request.trial_ids:
            t = by_id.get(tid)
            if t is None:
                decisions.append(EarlyStopDecision(tid, False, "unknown trial"))
                continue
            stop = early_stopping.should_stop(t, all_trials, config)
            decisions.append(
                EarlyStopDecision(tid, stop, "automated stopping rule" if stop else "")
            )
        return EarlyStopDecisions(decisions=decisions)
