"""Seeded scrambled-Halton sampler for the GP-bandit's global candidate pool.

The suggest docstring always promised "quasi-random candidates" but the pool
was plain ``rng.rand`` — this module makes it true. Points are the radical
inverses of 0..n-1 in the first ``dim`` prime bases, with a random digit
permutation per (dimension, digit position) drawn from the caller's seeded
``RandomState`` (generalized van der Corput scrambling). Scrambling breaks
the strong inter-dimension correlations of the raw Halton sequence in higher
dimensions while keeping each 1-D projection a low-discrepancy permutation
of the base-b grid — strictly more uniform than iid uniforms, deterministic
per seed.
"""

from __future__ import annotations

import numpy as np

_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59,
           61, 67, 71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113)


def _more_primes(count: int) -> "list[int]":
    primes = list(_PRIMES)
    c = primes[-1]
    while len(primes) < count:
        c += 2
        if all(c % p for p in primes if p * p <= c):
            primes.append(c)
    return primes[:count]


def scrambled_halton(n: int, dim: int,
                     rng: np.random.RandomState) -> np.ndarray:
    """(n, dim) scrambled-Halton points in [0, 1).

    Deterministic for a given ``rng`` state; consecutive calls on the same
    generator yield fresh scramblings (the policy draws one pool per
    suggest operation).
    """
    if n <= 0:
        return np.zeros((0, dim), np.float64)
    bases = _more_primes(dim)
    out = np.empty((n, dim), np.float64)
    idx = np.arange(n, dtype=np.int64)
    for d, b in enumerate(bases):
        # digits needed to distinguish n indices, plus slack so the
        # permuted tail digits still dither the low-order bits
        n_digits = 1
        while b ** n_digits < max(n, 2):
            n_digits += 1
        n_digits += 2
        rem = idx.copy()
        value = np.zeros(n, np.float64)
        scale = 1.0 / b
        for _pos in range(n_digits):
            digit = rem % b
            rem //= b
            perm = rng.permutation(b)
            value += perm[digit] * scale
            scale /= b
        out[:, d] = value
    return out
