"""Logical-axis sharding rules (MaxText-style), with divisibility fallback.

Every parameter and activation carries a tuple of *logical* axis names; a
rules table maps logical names to mesh axes. ``logical_to_spec`` drops a mesh
axis when the dimension size is not divisible by it (e.g. yi-34b's 56 heads
on a 16-way model axis) instead of failing — the fallback is recorded so the
roofline report can call it out.

Baseline rules implement 2D parameter sharding (FSDP over ``data`` × tensor
over ``model``) with data-parallel activations; shape kinds adjust them:
  * decode shapes shard the KV cache batch over ``data``;
  * long-context decode (batch=1) context-parallelizes: KV sequence over
    ``data``;
  * sequence-parallel (SP) residual saving shards the scanned activations'
    sequence dim over ``model``.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)

LogicalAxes = Tuple[Optional[str], ...]
MeshAxes = Union[None, str, Tuple[str, ...]]


# -- rules -------------------------------------------------------------------

BASE_RULES: Dict[str, Tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "seq_sp": ("model",),          # sequence-parallel saved residuals
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_experts": ("model",),
    # parameters (2D: fsdp x tensor)
    "embed": ("data",),            # d_model dim of weights (FSDP shard)
    "mlp": ("model",),             # d_ff dim
    "heads": ("model",),           # attention head dim of weights
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),         # expert-parallel parameter dim
    "expert_mlp": (),              # per-expert hidden (kept unsharded; experts carry EP)
    "q_lora": (), "kv_lora": (),   # MLA latents (small)
    "head_dim": (),
    "ssm_inner": ("model",),       # mamba2 d_inner
    "ssm_state": (), "ssm_heads": ("model",), "conv": (),
    "layers": (),                  # scan dim
    # kv cache
    "kv_batch": ("pod", "data"),
    "kv_seq": (),
    # frontends
    "frames": (), "patches": (),
}


def make_rules(shape_kind: str = "train", *, context_parallel: bool = False,
               sp: bool = True, overrides: Optional[Dict[str, Tuple[str, ...]]] = None
               ) -> Dict[str, Tuple[str, ...]]:
    rules = dict(BASE_RULES)
    if not sp:
        rules["seq_sp"] = ()
    if shape_kind == "decode":
        # shard the KV-cache sequence over `model`: works for every kv-head
        # count (GQA kv=8 / MQA kv=1 can't split a 16-way model axis) and
        # the decode softmax reduction lowers to a tiny all-reduce
        rules["kv_seq"] = ("model",)
        rules["act_kv_heads"] = ()
    if context_parallel:
        # batch=1 long decode: context-parallel over BOTH axes
        rules["kv_batch"] = ()
        rules["kv_seq"] = ("data", "model")
        rules["batch"] = ()
    if overrides:
        rules.update(overrides)
    return rules


# -- translation -----------------------------------------------------------------


@dataclasses.dataclass
class ShardingCtx:
    """Mesh + rules + a log of divisibility fallbacks (for the perf report)."""

    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]
    fallbacks: list = dataclasses.field(default_factory=list)

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name] if name in self.mesh.shape else 1

    def spec_for(self, logical: Union[str, LogicalAxes], shape: Sequence[int]) -> P:
        """PartitionSpec honoring divisibility; drops non-dividing mesh axes.

        ``logical`` is either a tuple of names (None = unsharded) or a
        space-separated string where '-' means unsharded — strings keep
        logical-axes trees pytree-leaf-compatible.
        """
        logical = parse_axes(logical)
        assert len(logical) == len(shape), (logical, shape)
        out = []
        used: set = set()
        for dim, name in zip(shape, logical):
            if name is None:
                out.append(None)
                continue
            mesh_axes = self.rules.get(name, ())
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            picked = []
            remaining = dim
            for ax in mesh_axes:
                if ax in used or ax not in self.mesh.shape:
                    continue
                size = self.axis_size(ax)
                if size > 1 and remaining % size == 0:
                    picked.append(ax)
                    remaining //= size
                    used.add(ax)
                elif size > 1:
                    self.fallbacks.append((name, ax, dim))
            if not picked:
                out.append(None)
            elif len(picked) == 1:
                out.append(picked[0])
            else:
                out.append(tuple(picked))
        return P(*out)

    def shard(self, x, logical: Union[str, LogicalAxes]):
        """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
        spec = self.spec_for(logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def named_sharding(self, logical: Union[str, LogicalAxes], shape: Sequence[int]
                       ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical, shape))


def parse_axes(logical: Union[str, LogicalAxes]) -> LogicalAxes:
    """'vocab embed' -> ('vocab', 'embed'); '-' -> None."""
    if isinstance(logical, str):
        return tuple(None if t in ("-", "") else t for t in logical.split())
    return tuple(logical)


def tree_shardings(ctx: ShardingCtx, shapes_tree, axes_tree):
    """NamedShardings for a pytree of ShapeDtypeStructs + string-axes tree."""
    return jax.tree.map(
        lambda sds, axes: ctx.named_sharding(axes, sds.shape), shapes_tree, axes_tree
    )
