"""Elastic scaling: rebuild the mesh when hosts join/leave, reshard via
checkpoint.

On a real cluster the controller detects a failed host (missed heartbeats),
triggers a checkpoint-backed restart with the surviving host set, and the
job resumes on a smaller (or regrown) mesh. In this framework:

  * plan_elastic_mesh picks the largest (data, model) grid that fits the
    surviving device count while preserving the model axis (TP degree is a
    property of the compiled program; DP shrinks first);
  * reshard_state reloads a checkpoint under the new mesh — the checkpoint
    format is mesh-agnostic (full arrays), so resharding is just re-placing
    with the new NamedShardings;
  * ElasticController simulates the heartbeat/failure/recovery cycle (used
    by tests and the parallel-tuning benchmark).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax

from repro.distributed.sharding import ShardingCtx, make_rules, tree_shardings


def plan_elastic_mesh(n_devices: int, *, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid with the fixed TP degree."""
    if n_devices < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} devices to preserve TP degree, "
            f"have {n_devices}")
    data = n_devices // model_parallel
    return data, model_parallel


def make_elastic_mesh(devices: List, *, model_parallel: int):
    data, model = plan_elastic_mesh(len(devices), model_parallel=model_parallel)
    import numpy as np

    grid = np.asarray(devices[: data * model]).reshape(data, model)
    from jax.sharding import Mesh

    return Mesh(grid, ("data", "model"))


def reshard_state(state, axes_tree, mesh, rules: Optional[dict] = None):
    """Re-places a (restored) state under a new mesh's shardings."""
    ctx = ShardingCtx(mesh=mesh, rules=rules or make_rules("train"))
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    shardings = tree_shardings(ctx, shapes, axes_tree)
    return jax.tree.map(jax.device_put, state, shardings)


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    alive: bool = True


class ElasticController:
    """Heartbeat-based failure detector + re-mesh planner (simulation)."""

    def __init__(self, n_hosts: int, *, heartbeat_timeout: float = 5.0,
                 model_parallel: int = 1):
        now = time.monotonic()
        self.hosts: Dict[int, HostState] = {
            i: HostState(i, now) for i in range(n_hosts)}
        self.heartbeat_timeout = heartbeat_timeout
        self.model_parallel = model_parallel
        self.generation = 0  # bumps on every re-mesh

    def heartbeat(self, host_id: int) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = time.monotonic()
        if not h.alive:
            h.alive = True           # host rejoined
            self.generation += 1

    def fail(self, host_id: int) -> None:
        """Test hook: simulate a crash."""
        self.hosts[host_id].alive = False
        self.hosts[host_id].last_heartbeat = -1e18
        self.generation += 1

    def check(self) -> List[int]:
        """Marks hosts with stale heartbeats dead; returns dead host ids."""
        now = time.monotonic()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_heartbeat > self.heartbeat_timeout:
                h.alive = False
                self.generation += 1
            if not h.alive:
                dead.append(h.host_id)
        return dead

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]

    def plan(self, devices_per_host: int) -> Tuple[int, int]:
        n = len(self.alive_hosts()) * devices_per_host
        return plan_elastic_mesh(n, model_parallel=self.model_parallel)
