"""Gradient compression for the DP all-reduce path (distributed-optimization
trick; off by default, enabled via TrainConfig.grad_compression).

int8 block-quantization with error feedback: grads are quantized to int8 with
per-block fp32 scales before the data-parallel reduction; the quantization
residual is carried to the next step (error feedback keeps the scheme
unbiased in the long run). Cuts DP all-reduce bytes ~4x vs fp32 / ~2x vs bf16
at the cost of one extra buffer.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (f32) -> (int8 codes, f32 scales per block)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def _dequantize(codes: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


def compress_with_feedback(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads to feed the optimizer, new error feedback).

    The round-trip through int8 models what the wire carries; XLA sees int8
    tensors at the psum boundary when this wraps a shard_map'd reduction.
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        codes, scale = _quantize(gf)
        deq = _dequantize(codes, scale, gf.shape)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, error)
    is_l = lambda x: isinstance(x, tuple) and not hasattr(x, "_fields")
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=is_l)
    new_err = jax.tree.map(lambda o: o[1], out, is_leaf=is_l)
    return deq, new_err


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
