"""jit'd dispatch wrappers over the Pallas kernels.

Default behavior:
  * on TPU backends -> Pallas kernel path
  * on CPU (this container) -> XLA reference path (fast, compiles everywhere)
  * force the Pallas path under interpret=True with REPRO_FORCE_PALLAS=1 or
    the explicit ``impl=`` argument (tests do this for kernel validation).
"""

from __future__ import annotations

import os
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

Impl = Literal["auto", "xla", "pallas", "pallas_interpret"]


def _default_impl() -> str:
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return "pallas_interpret"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# Candidate pools at or above this row count go through the blocked path:
# K(x1, x2) is built in (n, GRAM_BLOCK_ROWS) column strips, bounding the
# per-call workspace (Pallas grid / XLA temp) instead of materializing one
# n x m product for arbitrarily large acquisition batches.
GRAM_BLOCK_ROWS = 4096


def matern52_gram(
    x1: jnp.ndarray,
    x2: jnp.ndarray,
    amplitude=1.0,
    *,
    impl: Impl = "auto",
    block_rows: Optional[int] = None,
) -> jnp.ndarray:
    """Matérn-5/2 Gram matrix of lengthscale-scaled features.

    ``block_rows``: strip width over x2's rows. None = auto (blocked once
    x2 has >= GRAM_BLOCK_ROWS rows); 0 = never block.
    """
    impl = _default_impl() if impl == "auto" else impl
    m = x2.shape[0]
    if block_rows is None:
        block_rows = GRAM_BLOCK_ROWS if m >= GRAM_BLOCK_ROWS else 0
    if block_rows and m > block_rows:
        strips = [
            matern52_gram(x1, x2[i:i + block_rows], amplitude,
                          impl=impl, block_rows=0)
            for i in range(0, m, block_rows)
        ]
        return jnp.concatenate(strips, axis=1)
    if impl == "xla":
        return ref.matern52_gram(x1, x2, amplitude)
    from repro.kernels.gram import matern52_gram_pallas

    return matern52_gram_pallas(
        x1, x2, jnp.asarray(amplitude), interpret=(impl == "pallas_interpret")
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    impl: Impl = "auto",
) -> jnp.ndarray:
    """Attention dispatch: Pallas flash kernel on TPU, chunked-XLA otherwise."""
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        from repro.models.attention import chunked_attention

        return chunked_attention(q, k, v, causal=causal, q_offset=q_offset)
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset,
        interpret=(impl == "pallas_interpret"),
    )


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    init_state: Optional[jnp.ndarray] = None,
    chunk: int = 256,
    impl: Impl = "auto",
):
    """Mamba2 SSD scan dispatch (chunked parallel form)."""
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        from repro.models.mamba2 import ssd_chunked

        return ssd_chunked(x, dt, A, Bm, Cm, init_state=init_state, chunk=chunk)
    from repro.kernels.mamba2_ssd import ssd_scan_pallas

    return ssd_scan_pallas(
        x, dt, A, Bm, Cm, init_state=init_state, chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )
