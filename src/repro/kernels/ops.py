"""jit'd dispatch wrappers over the Pallas kernels.

Default behavior:
  * on TPU backends -> Pallas kernel path
  * on CPU (this container) -> XLA reference path (fast, compiles everywhere)
  * force the Pallas path under interpret=True with REPRO_FORCE_PALLAS=1 or
    the explicit ``impl=`` argument (tests do this for kernel validation).
"""

from __future__ import annotations

import os
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

Impl = Literal["auto", "xla", "pallas", "pallas_interpret"]


def _default_impl() -> str:
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return "pallas_interpret"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# Candidate pools at or above this row count go through the blocked path:
# K(x1, x2) is built in (n, GRAM_BLOCK_ROWS) column strips, bounding the
# per-call workspace (Pallas grid / XLA temp) instead of materializing one
# n x m product for arbitrarily large acquisition batches.
GRAM_BLOCK_ROWS = 4096

# XLA matvec strip width over x1's rows: bounds the temporary cross-Gram to
# (MATVEC_BLOCK_ROWS, m) — the Pallas path needs no strips at all.
MATVEC_BLOCK_ROWS = 256


def matern52_gram(
    x1: jnp.ndarray,
    x2: jnp.ndarray,
    amplitude=1.0,
    *,
    impl: Impl = "auto",
    block_rows: Optional[int] = None,
) -> jnp.ndarray:
    """Matérn-5/2 Gram matrix of lengthscale-scaled features.

    ``block_rows``: strip width over x2's rows. None = auto (blocked once
    x2 has >= GRAM_BLOCK_ROWS rows); 0 = never block.
    """
    impl = _default_impl() if impl == "auto" else impl
    m = x2.shape[0]
    if block_rows is None:
        block_rows = GRAM_BLOCK_ROWS if m >= GRAM_BLOCK_ROWS else 0
    if block_rows and m > block_rows:
        # Every strip is computed at the full block width: the final partial
        # strip is zero-padded up to ``block_rows`` and its result columns
        # sliced back off. A ragged tail would hand the jitted kernels a
        # distinct x2 shape per distinct pool size — one fresh compile per
        # tail shape, breaking the retrace-free serving invariant.
        strips = []
        for i in range(0, m, block_rows):
            strip = x2[i:i + block_rows]
            w = strip.shape[0]
            if w < block_rows:
                strip = jnp.pad(strip, ((0, block_rows - w), (0, 0)))
            out = matern52_gram(x1, strip, amplitude, impl=impl, block_rows=0)
            strips.append(out[:, :w] if w < block_rows else out)
        return jnp.concatenate(strips, axis=1)
    if impl == "xla":
        return ref.matern52_gram(x1, x2, amplitude)
    from repro.kernels.gram import matern52_gram_pallas

    return matern52_gram_pallas(
        x1, x2, jnp.asarray(amplitude), interpret=(impl == "pallas_interpret")
    )


def matern52_gram_matvec(
    x1: jnp.ndarray,
    x2: jnp.ndarray,
    alpha: jnp.ndarray,
    amplitude=1.0,
    *,
    impl: Impl = "auto",
    block_rows: Optional[int] = None,
) -> jnp.ndarray:
    """Fused posterior-mean contraction K(x1, x2)^T · alpha -> (m,).

    Never materializes the (n, m) cross-Gram: the Pallas kernel accumulates
    tile-by-tile on TPU; the XLA path folds x1 row-strips into the output so
    the peak temporary is (block_rows, m) instead of (n, m).

    ``block_rows``: strip width over x1's rows on the XLA path. None = auto
    (strips of MATVEC_BLOCK_ROWS once x1 has more rows than that); 0 = one
    unblocked contraction.
    """
    impl = _default_impl() if impl == "auto" else impl
    if impl != "xla":
        from repro.kernels.gram import matern52_gram_matvec_pallas

        return matern52_gram_matvec_pallas(
            x1, x2, alpha, jnp.asarray(amplitude),
            interpret=(impl == "pallas_interpret"))
    n = x1.shape[0]
    if block_rows is None:
        block_rows = MATVEC_BLOCK_ROWS
    if not block_rows or n <= block_rows:
        return ref.matern52_gram_matvec(x1, x2, alpha, amplitude)
    alpha = alpha.astype(jnp.float32)
    pad = (-n) % block_rows
    x1p = jnp.pad(x1.astype(jnp.float32), ((0, pad), (0, 0)))
    ap = jnp.pad(alpha, (0, pad))  # zero alpha rows contribute nothing
    strips = n // block_rows + (1 if pad else 0)

    def fold(acc, strip):
        xs, als = strip
        return acc + ref.matern52_gram_matvec(xs, x2, als, amplitude), None

    acc0 = jnp.zeros((x2.shape[0],), jnp.float32)
    out, _ = jax.lax.scan(
        fold, acc0,
        (x1p.reshape(strips, block_rows, x1.shape[1]),
         ap.reshape(strips, block_rows)))
    return out


def tri_solve(
    L: jnp.ndarray,
    b: jnp.ndarray,
    *,
    trans: bool = False,
    impl: Impl = "auto",
) -> jnp.ndarray:
    """x with L x = b (``trans``: L^T x = b); L (m, m) lower-triangular.

    ``b`` may be (m,) or (m, k); the result matches b's shape. The Pallas
    path runs the blocked forward-substitution kernel; transposed solves go
    through the flip trick (reverse both axes of L, transpose, reverse b's
    rows) so the SAME compiled kernel serves both orientations — no second
    kernel, no extra compile.
    """
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        return ref.tri_solve(L, b, trans=trans)
    from repro.kernels.tri_solve import tri_solve_pallas

    interpret = impl == "pallas_interpret"
    vec = b.ndim == 1
    bm = b[:, None] if vec else b
    if trans:
        out = tri_solve_pallas(L[::-1, ::-1].T, bm[::-1],
                               interpret=interpret)[::-1]
    else:
        out = tri_solve_pallas(L, bm, interpret=interpret)
    return out[:, 0] if vec else out


def cholupdate(
    L: jnp.ndarray,
    v: jnp.ndarray,
    *,
    impl: Impl = "auto",
) -> jnp.ndarray:
    """chol(L L^T + v v^T) in O(m^2): the sparse posterior's rank-1 append
    against the m×m inducing factor. L (m, m) lower-triangular, v (m,)."""
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        return ref.cholupdate(L, v)
    from repro.kernels.tri_solve import cholupdate_pallas

    return cholupdate_pallas(L, v, interpret=(impl == "pallas_interpret"))


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    impl: Impl = "auto",
) -> jnp.ndarray:
    """Attention dispatch: Pallas flash kernel on TPU, chunked-XLA otherwise."""
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        from repro.models.attention import chunked_attention

        return chunked_attention(q, k, v, causal=causal, q_offset=q_offset)
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset,
        interpret=(impl == "pallas_interpret"),
    )


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    init_state: Optional[jnp.ndarray] = None,
    chunk: int = 256,
    impl: Impl = "auto",
):
    """Mamba2 SSD scan dispatch (chunked parallel form)."""
    impl = _default_impl() if impl == "auto" else impl
    if impl == "xla":
        from repro.models.mamba2 import ssd_chunked

        return ssd_chunked(x, dt, A, Bm, Cm, init_state=init_state, chunk=chunk)
    from repro.kernels.mamba2_ssd import ssd_scan_pallas

    return ssd_scan_pallas(
        x, dt, A, Bm, Cm, init_state=init_state, chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )
