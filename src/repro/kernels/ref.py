"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Matérn-5/2 Gram matrix (GP bandit)
# ---------------------------------------------------------------------------


def matern52_gram(x1: jnp.ndarray, x2: jnp.ndarray, amplitude) -> jnp.ndarray:
    """K[i,j] = amp * (1 + a + a^2/3) exp(-a), a = sqrt(5) * ||x1_i - x2_j||.

    Inputs are already lengthscale-scaled: x / ell.
    x1: (n, d), x2: (m, d) -> (n, m), computed in float32.
    """
    x1 = x1.astype(jnp.float32)
    x2 = x2.astype(jnp.float32)
    d2 = (
        jnp.sum(x1 * x1, axis=1)[:, None]
        - 2.0 * x1 @ x2.T
        + jnp.sum(x2 * x2, axis=1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    a = jnp.sqrt(5.0 * d2)
    return amplitude * (1.0 + a + (a * a) / 3.0) * jnp.exp(-a)


def matern52_gram_matvec(
    x1: jnp.ndarray, x2: jnp.ndarray, alpha: jnp.ndarray, amplitude
) -> jnp.ndarray:
    """out[j] = sum_i K(x1_i, x2_j) * alpha[i] — the GP posterior mean at x2.

    x1: (n, d), x2: (m, d), alpha: (n,) -> (m,). The oracle materializes the
    full cross-Gram; the Pallas kernel (gram.py) and the blocked XLA dispatch
    (ops.py) compute the same contraction tile-by-tile in O(m) memory.
    """
    K = matern52_gram(x1, x2, amplitude)  # (n, m)
    return K.T @ alpha.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Blocked triangular solve + rank-1 Cholesky update (sparse GP posterior)
# ---------------------------------------------------------------------------


def tri_solve(L: jnp.ndarray, b: jnp.ndarray, *, trans: bool = False) -> jnp.ndarray:
    """x with L x = b (or L^T x = b when ``trans``), L lower-triangular.

    L: (m, m), b: (m,) or (m, k) -> same shape as b, computed in float32.
    """
    return jax.scipy.linalg.solve_triangular(
        L.astype(jnp.float32), b.astype(jnp.float32),
        lower=True, trans=1 if trans else 0)


def cholupdate(L: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """chol(L L^T + v v^T) by sequential column rotations: O(m^2).

    L: (m, m) lower-triangular with positive diagonal, v: (m,) -> (m, m).
    Identity-padded trailing rows (diag 1, v 0) pass through untouched, so
    bucket-padded callers stay exact. The test oracle is a fresh
    ``jnp.linalg.cholesky`` of the updated matrix; this column sweep is the
    XLA dispatch path (and the maths the Pallas kernel mirrors).
    """
    L = L.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m = L.shape[0]
    idx = jnp.arange(m)

    def step(carry, k):
        Lc, vc = carry
        col = Lc[:, k]
        Lkk = col[k]
        vk = vc[k]
        r = jnp.sqrt(Lkk * Lkk + vk * vk)
        c = r / Lkk
        s = vk / Lkk
        below = idx > k
        newcol = jnp.where(idx == k, r,
                           jnp.where(below, (col + s * vc) / c, col))
        vc = jnp.where(below, c * vc - s * newcol, vc)
        return (Lc.at[:, k].set(newcol), vc), None

    (L, _), _ = jax.lax.scan(step, (L, v), idx)
    return L


# ---------------------------------------------------------------------------
# Flash attention (causal / non-causal), GQA-aware
# ---------------------------------------------------------------------------


def attention(
    q: jnp.ndarray,  # (B, Sq, Hq, Dh)
    k: jnp.ndarray,  # (B, Sk, Hkv, Dh)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dh)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference full-materialization attention. q_offset positions queries
    within the kv sequence (for decode / chunked prefill)."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (Dh**0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to match q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space dual) chunked scan
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jnp.ndarray,   # (B, S, H, P)   inputs per head
    dt: jnp.ndarray,  # (B, S, H)      softplus'd step sizes (>0)
    A: jnp.ndarray,   # (H,)           negative decay rates (A < 0)
    Bm: jnp.ndarray,  # (B, S, G, N)   input projection (G groups)
    Cm: jnp.ndarray,  # (B, S, G, N)   output projection
    *,
    init_state: jnp.ndarray | None = None,  # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential reference of the Mamba2 SSD recurrence.

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B, S, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    def step(h, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(Af[None, :] * dtt)  # (B,H)
        h = h * decay[..., None, None] + (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    return y.astype(x.dtype), hT
