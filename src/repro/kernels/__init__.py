"""Pallas TPU kernels for compute hot-spots, each with a pure-jnp oracle.

Layout per kernel:
  <name>.py   pl.pallas_call + BlockSpec implementation (TPU target; validated
              on CPU via interpret=True)
  ref.py      pure-jnp oracles (the correctness ground truth)
  ops.py      jit'd dispatch wrappers: XLA path by default on CPU, Pallas path
              on TPU (or interpret=True when forced)

Kernels:
  gram            Matérn-5/2 Gram matrix + fused Gram·vector (K^T·alpha
                  without materializing the cross-Gram) — the GP-bandit
                  hot-spots (paper §6.3 notes cubic-cost GP suggestion; the
                  Gram build is the bandwidth-bound part)
  flash_attention chunked online-softmax attention for the model zoo
  mamba2_ssd      chunked state-space-dual scan (zamba2 hybrid blocks)
"""

from repro.kernels import ops, ref
