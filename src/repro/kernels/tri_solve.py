"""Pallas TPU kernels: blocked triangular solve + rank-1 Cholesky update.

These are the sparse-posterior (SGPR) hot-spots: every cross-solve against
the m×m inducing factor is a lower-triangular solve with a wide right-hand
side (candidate pools, fantasy batches), and every rank-1 append rotates the
m×m ``LB`` factor with one update vector. Both stay on-device.

``tri_solve_pallas`` solves L X = B for lower-triangular L via blocked
forward substitution: the grid walks BLOCK_K-column strips of B (one strip
per program, L resident in VMEM across the strip); within a strip the row
dimension advances in RB=8-row blocks (the f32 sublane height) — one
(RB, M) × (M, BLOCK_K) MXU contraction folds the already-solved prefix into
the block's right-hand side, then the RB×RB diagonal block is solved with a
statically unrolled substitution. Transposed solves (L^T x = b) are handled
by the ops.py wrapper with the flip trick — reverse both axes of L and the
rows of b, solve forward, reverse back — so one kernel serves both.

``cholupdate_pallas`` computes chol(L L^T + v v^T) with the classic column
sweep: for each column k a Givens-style rotation (c, s) derived from the
diagonal and v[k] updates the column and the remainder of v — O(m^2) total,
a single grid-less program with L in VMEM.

Padding: wrappers pad m up to a lane-aligned multiple with an IDENTITY
diagonal block (and zero right-hand-side rows / update entries), so padded
solutions are exactly zero and padded columns rotate by the identity —
results are exact, and bucket-padded callers never retrace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_K = 256   # right-hand-side column strip width
LANE = 128      # m padded to lane multiples (f32 tiling)
RB = 8          # row-block height (f32 sublane)


def _tri_solve_kernel(l_ref, b_ref, out_ref):
    """One BLOCK_K-column strip of X with L X = B, L lower-triangular."""
    M = l_ref.shape[0]

    def row_block(rb, X):
        start = rb * RB
        rows = pl.load(l_ref, (pl.ds(start, RB), slice(None)))  # (RB, M)
        # fold the solved prefix: X rows >= start are still zero, so the
        # full-width contraction only picks up columns < start
        S = jax.lax.dot_general(
            rows, X, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (RB, K)
        bblk = pl.load(b_ref, (pl.ds(start, RB), slice(None))) - S
        diag = jax.lax.dynamic_slice(rows, (0, start), (RB, RB))
        xblk = jnp.zeros_like(bblk)
        for i in range(RB):  # static unroll: RB sequential pivots
            ri = diag[i]     # (RB,); entries past i are zero in xblk
            xi = (bblk[i] - ri @ xblk) / ri[i]
            xblk = xblk.at[i].set(xi)
        return jax.lax.dynamic_update_slice(X, xblk, (start, 0))

    X = jax.lax.fori_loop(
        0, M // RB, row_block, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = X


@functools.partial(jax.jit, static_argnames=("interpret",))
def tri_solve_pallas(
    L: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = False
) -> jnp.ndarray:
    """X with L X = B; L (m, m) lower-triangular, B (m, k) -> (m, k)."""
    m = L.shape[0]
    k = b.shape[1]
    pad_m = (-m) % LANE
    pad_k = (-k) % BLOCK_K
    Lp = jnp.pad(L.astype(jnp.float32), ((0, pad_m), (0, pad_m)))
    if pad_m:
        eye_tail = (jnp.arange(m + pad_m) >= m).astype(jnp.float32)
        Lp = Lp + jnp.diag(eye_tail)  # identity block: padded rows solve to 0
    bp = jnp.pad(b.astype(jnp.float32), ((0, pad_m), (0, pad_k)))
    mp, kp = m + pad_m, k + pad_k

    out = pl.pallas_call(
        _tri_solve_kernel,
        grid=(kp // BLOCK_K,),
        in_specs=[
            pl.BlockSpec((mp, mp), lambda j: (0, 0)),
            pl.BlockSpec((mp, BLOCK_K), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mp, BLOCK_K), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.float32),
        interpret=interpret,
    )(Lp, bp)
    return out[:m, :k]


def _cholupdate_kernel(l_ref, v_ref, out_ref):
    """Column sweep of the rank-1 update, in place over out_ref."""
    M = l_ref.shape[0]
    out_ref[...] = l_ref[...].astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (M, 1), 0)

    def body(k, v):
        col = pl.load(out_ref, (slice(None), pl.ds(k, 1)))   # (M, 1)
        Lkk = jax.lax.dynamic_slice(col, (k, 0), (1, 1))
        vk = jax.lax.dynamic_slice(v, (k, 0), (1, 1))
        r = jnp.sqrt(Lkk * Lkk + vk * vk)
        c = r / Lkk
        s = vk / Lkk
        below = rows > k
        newcol = jnp.where(rows == k, r,
                           jnp.where(below, (col + s * v) / c, col))
        pl.store(out_ref, (slice(None), pl.ds(k, 1)), newcol)
        return jnp.where(below, c * v - s * newcol, v)

    jax.lax.fori_loop(0, M, body, v_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def cholupdate_pallas(
    L: jnp.ndarray, v: jnp.ndarray, *, interpret: bool = False
) -> jnp.ndarray:
    """chol(L L^T + v v^T); L (m, m) lower-triangular, v (m,) -> (m, m)."""
    m = L.shape[0]
    pad_m = (-m) % LANE
    Lp = jnp.pad(L.astype(jnp.float32), ((0, pad_m), (0, pad_m)))
    if pad_m:
        eye_tail = (jnp.arange(m + pad_m) >= m).astype(jnp.float32)
        Lp = Lp + jnp.diag(eye_tail)  # identity block rotates by identity
    vp = jnp.pad(v.astype(jnp.float32), (0, pad_m)).reshape(-1, 1)
    mp = m + pad_m

    out = pl.pallas_call(
        _cholupdate_kernel,
        in_specs=[
            pl.BlockSpec((mp, mp), lambda: (0, 0)),
            pl.BlockSpec((mp, 1), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((mp, mp), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=interpret,
    )(Lp, vp)
    return out[:m, :m]
