"""Pallas TPU kernels: Matérn-5/2 Gram matrix + fused Gram·vector (GP-bandit
hot-spots).

The GP suggestion path builds K(X, X) ∈ R^{n×n} from lengthscale-scaled
features X ∈ R^{n×d}. On TPU the natural layout is (8,128)-aligned blocks:
each grid cell computes a (BN, BM) tile of K from a (BN, D) and a (BM, D)
VMEM-resident strip, contracting D on the MXU via dot(x1, x2^T).

Tiling: BN = BM = 256 (f32: 256·256·4 = 256 KiB out-tile; two in-strips of
256·D·4; for D ≤ 512 the working set stays ≪ 16 MiB VMEM).

``matern52_gram_matvec_pallas`` fuses the posterior-mean contraction
out = K(x1, x2)^T · alpha into the tile loop: each (BM, BN) grid step folds
its K tile into a (1, BM) accumulator, so the (n, m) cross-Gram is never
materialized in HBM — O(m) output traffic instead of O(n·m). The n-tile grid
axis is innermost, so the output block stays resident across the
accumulation (Pallas revisiting rule).

Inputs are zero-padded to block multiples by the wrapper (ops.py); padding
contributes K values that the wrapper slices away (matvec padding rows carry
alpha = 0, so they contribute exactly nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256
BLOCK_M = 256


def _gram_kernel(x1_ref, x2_ref, amp_ref, out_ref):
    """One (BN, BM) tile: d2 = |x1|^2 - 2 x1 x2^T + |x2|^2, then Matérn-5/2."""
    x1 = x1_ref[...].astype(jnp.float32)  # (BN, D)
    x2 = x2_ref[...].astype(jnp.float32)  # (BM, D)
    amp = amp_ref[0, 0]
    # MXU contraction for the cross term; VPU for the norms.
    cross = jax.lax.dot_general(
        x1, x2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BN, BM)
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)  # (BN, 1)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True).T  # (1, BM)
    d2 = jnp.maximum(n1 - 2.0 * cross + n2, 0.0)
    a = jnp.sqrt(5.0 * d2)
    out_ref[...] = amp * (1.0 + a + (a * a) * (1.0 / 3.0)) * jnp.exp(-a)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_gram_pallas(
    x1: jnp.ndarray, x2: jnp.ndarray, amplitude: jnp.ndarray, *, interpret: bool = False
) -> jnp.ndarray:
    """K(x1, x2) with x already scaled by 1/lengthscale. Shapes (n,d),(m,d)."""
    n, d = x1.shape
    m = x2.shape[0]
    pad_n = (-n) % BLOCK_N
    pad_m = (-m) % BLOCK_M
    pad_d = (-d) % 128  # MXU lane alignment
    x1p = jnp.pad(x1.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    x2p = jnp.pad(x2.astype(jnp.float32), ((0, pad_m), (0, pad_d)))
    amp = jnp.asarray(amplitude, jnp.float32).reshape((1, 1))
    np_, mp_ = n + pad_n, m + pad_m
    dp_ = d + pad_d

    out = pl.pallas_call(
        _gram_kernel,
        grid=(np_ // BLOCK_N, mp_ // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, dp_), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, dp_), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        interpret=interpret,
    )(x1p, x2p, amp)
    return out[:n, :m]


def _matvec_kernel(x1_ref, x2_ref, alpha_ref, amp_ref, out_ref):
    """One n-tile's contribution to a (1, BM) slice of K^T·alpha.

    Grid is (m_tiles, n_tiles) with n innermost: the out block is revisited
    across the n sweep, zeroed on the first step and accumulated after.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x1 = x1_ref[...].astype(jnp.float32)      # (BN, D)
    x2 = x2_ref[...].astype(jnp.float32)      # (BM, D)
    alpha = alpha_ref[...].astype(jnp.float32)  # (1, BN)
    amp = amp_ref[0, 0]
    cross = jax.lax.dot_general(
        x1, x2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BN, BM)
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True).T
    d2 = jnp.maximum(n1 - 2.0 * cross + n2, 0.0)
    a = jnp.sqrt(5.0 * d2)
    k = amp * (1.0 + a + (a * a) * (1.0 / 3.0)) * jnp.exp(-a)  # (BN, BM)
    out_ref[...] += jax.lax.dot_general(
        alpha, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (1, BM)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_gram_matvec_pallas(
    x1: jnp.ndarray, x2: jnp.ndarray, alpha: jnp.ndarray, amplitude,
    *, interpret: bool = False,
) -> jnp.ndarray:
    """out = K(x1, x2)^T · alpha without materializing the (n, m) cross-Gram.

    x1: (n, d), x2: (m, d), alpha: (n,) -> (m,); x already 1/lengthscale
    scaled. Zero-padded rows of x1 are neutralized by alpha's zero padding.
    """
    n, d = x1.shape
    m = x2.shape[0]
    pad_n = (-n) % BLOCK_N
    pad_m = (-m) % BLOCK_M
    pad_d = (-d) % 128
    x1p = jnp.pad(x1.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    x2p = jnp.pad(x2.astype(jnp.float32), ((0, pad_m), (0, pad_d)))
    ap = jnp.pad(alpha.astype(jnp.float32), (0, pad_n)).reshape(1, n + pad_n)
    amp = jnp.asarray(amplitude, jnp.float32).reshape((1, 1))
    np_, mp_, dp_ = n + pad_n, m + pad_m, d + pad_d

    out = pl.pallas_call(
        _matvec_kernel,
        grid=(mp_ // BLOCK_M, np_ // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_N, dp_), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_M, dp_), lambda i, j: (i, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_M), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp_), jnp.float32),
        interpret=interpret,
    )(x1p, x2p, ap, amp)
    return out[0, :m]
