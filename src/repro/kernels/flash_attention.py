"""Pallas TPU kernel: causal flash attention (GQA-aware), online softmax.

Grid: (B, Hq, nQ, nK) — the trailing kv axis is sequential on TPU, so the
running max / sum / accumulator live in VMEM scratch across kv steps and the
output tile is written once at the last kv block.

Tiling: q tile (BQ, D), kv tiles (BK, D) — BQ = BK = 512 by default, D padded
to a 128 multiple by the wrapper. VMEM working set per step:
(BQ·D + 2·BK·D + BQ·BK) · 4B ≈ 2.6 MB at BQ=BK=512, D=128 — well under the
~16 MB VMEM budget, MXU-aligned on every matmul dim.

Causal masking skips fully-masked kv blocks via pl.when (block-level
early-out, the flash trick that halves causal FLOPs).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, scale: float, q_offset: int, bq: int, bk: int,
                  n_k: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + qi * bq
    k_start = ki * bk

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (BQ, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (BK, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ, BK)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # block-level early-out: skip kv blocks entirely above the diagonal
        pl.when(q_start + bq - 1 >= k_start)(_compute)
    else:
        _compute()

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "bq", "bk", "interpret", "scale"))
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)

    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    pad_d = (-D) % 128
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, pad_d)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    Sqp, Skp, Dp = Sq + pad_q, Sk + pad_k, D + pad_d
    n_q, n_k = Sqp // bq, Skp // bk

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, q_offset=q_offset,
        bq=bq, bk=bk, n_k=n_k, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dp), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, Dp), lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dp), lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dp), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sqp, Hq, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq, :, :D]
