"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid: (B, H, nc) — the chunk axis is sequential on TPU, so the (P, N) SSM
state lives in VMEM scratch and flows across chunks (the cross-chunk
recurrence), while each chunk's intra-chunk work is two (L, L)·(L, ·) MXU
matmuls — the "state-space dual" form.

Per grid step VMEM: x (L,P) + B/C (L,N) + scores (L,L) + state (P,N), all
f32: at L=256, P=N=128 that is ≈ 0.6 MB — tiny; L can grow to 1024 before
the score matrix dominates.

The wrapper takes the generalized inputs (log-decay ``a``, multiplier
``mult``) shared with models.mamba2.ssd_core, so the same kernel serves
Mamba2 (a = A·dt, mult = dt) and mLSTM (a = log σ(f), mult = i-gate).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, m_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref,
                state_ref, *, n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (L, P)
    a = a_ref[0, :, 0].astype(jnp.float32)         # (L,)
    mult = m_ref[0, :, 0].astype(jnp.float32)      # (L,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)     # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)     # (L, N)

    seg = jnp.cumsum(a)                            # (L,)
    total = seg[-1]

    # intra-chunk: M[i,j] = exp(seg_i - seg_j) * mult_j  for j <= i
    li = seg[:, None]
    lj = seg[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = iota_i >= iota_j
    decay = jnp.where(causal, jnp.exp(li - lj), 0.0) * mult[None, :]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    y_intra = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk: y_inter = exp(seg_i) * C_i @ state^T
    h = state_ref[...]                              # (P, N)
    y_inter = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)
    y_ref[0, :, 0, :] = (y_intra + jnp.exp(seg)[:, None] * y_inter).astype(y_ref.dtype)

    # state update: h' = exp(total) h + sum_j exp(total - seg_j) mult_j x_j B_j^T
    w = jnp.exp(total - seg) * mult                 # (L,)
    upd = jax.lax.dot_general(x * w[:, None], Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = h * jnp.exp(total) + upd

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        hT_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,    # (B, S, H, P)
    dt: jnp.ndarray,   # (B, S, H)
    A: jnp.ndarray,    # (H,)
    Bm: jnp.ndarray,   # (B, S, G, N)
    Cm: jnp.ndarray,   # (B, S, G, N)
    *,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    chunk: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = A.astype(jnp.float32)[None, None, :] * dt.astype(jnp.float32)
    return ssd_core_pallas(x, a, dt, Bm, Cm, init_state=init_state, chunk=chunk,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_core_pallas(
    x: jnp.ndarray,     # (B, S, H, P)
    a: jnp.ndarray,     # (B, S, H) log-decay
    mult: jnp.ndarray,  # (B, S, H)
    Bm: jnp.ndarray,    # (B, S, G, N)
    Cm: jnp.ndarray,    # (B, S, G, N)
    *,
    init_state: Optional[jnp.ndarray] = None,
    chunk: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    group = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, chunk=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, g=group: (b, c, h // g, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, g=group: (b, c, h // g, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, mult, Bm, Cm, init_state)
    return y, hT
