"""Long-running Operations (paper §3.2).

SuggestTrials returns an Operation immediately; the actual Pythia computation
runs asynchronously server-side. Operations are persisted in the datastore
*before* computation starts and contain enough information (study, client,
count) to restart the computation after a server crash — the paper's
server-side fault-tolerance mechanism.

Execution contract (scale-out serving tier):

* Suggest ops are enqueued on a study-sharded work queue
  (``shard_of(study_name, n_shards)``; one study always lands on one shard)
  and executed by a pool of Pythia workers, each leasing one shard's backlog
  as a coalesced batch (see ``work_queue``). A worker that dies mid-lease has
  its in-flight ops requeued — ``requeues`` counts how many times an op was
  handed to a new worker — and re-run idempotently: a requeued op that
  already completed is skipped, never re-dispatched.
* Clients learn of completion through the ``WaitOperation`` long-poll RPC
  (the server parks the request on a per-op event until the op finishes or
  the wait deadline lapses); the classic ``GetOperation`` polling loop
  remains for old clients and as the fallback when the server predates
  WaitOperation.
"""

from __future__ import annotations

import time
import uuid
import zlib
from typing import List, Optional


def shard_of(study_name: str, n_shards: int) -> int:
    """Stable shard key: one study never splits across queue shards.

    CRC32 rather than ``hash()`` because Python salts str hashes per process
    — the shard of a study must not change across server restarts while its
    persisted ops are being recovered into the queue.
    """
    return zlib.crc32(study_name.encode("utf-8")) % n_shards


def note_requeued(op: dict) -> dict:
    """Stamp an op handed back to the queue after its worker died."""
    op = dict(op)
    op["requeues"] = int(op.get("requeues", 0)) + 1
    return op


def new_suggest_operation(study_name: str, client_id: str, count: int) -> dict:
    return {
        "name": f"{study_name}/operations/{uuid.uuid4().hex}",
        "type": "suggest",
        "study_name": study_name,
        "client_id": client_id,
        "suggestion_count": int(count),
        "done": False,
        "create_time": time.time(),
        "requeues": 0,
        "result": None,
        "error": None,
    }


def new_early_stopping_operation(study_name: str, trial_id: int) -> dict:
    return {
        "name": f"{study_name}/operations/{uuid.uuid4().hex}",
        "type": "early_stopping",
        "study_name": study_name,
        "client_id": None,
        "trial_id": int(trial_id),
        "done": False,
        "create_time": time.time(),
        "result": None,
        "error": None,
    }


def complete_operation(op: dict, result: dict) -> dict:
    op = dict(op)
    op["result"] = result
    op["done"] = True
    op["complete_time"] = time.time()
    return op


def fail_operation(op: dict, code: int, message: str) -> dict:
    op = dict(op)
    op["error"] = {"code": int(code), "message": str(message)}
    op["done"] = True
    op["complete_time"] = time.time()
    return op


def fail_operation_from_exception(op: dict, e: Exception,
                                  default_code: int = 13) -> dict:
    """Fail an op preserving the RPC status code when the cause carries one.

    A remote Pythia dispatch surfaces per-study failures as VizierRpcError
    objects (e.g. NOT_FOUND for a study deleted mid-flight); collapsing them
    all to INTERNAL would hide whether a client should retry. Duck-typed on
    ``.code`` so this module stays transport-agnostic.
    """
    code = getattr(e, "code", None)
    if not isinstance(code, int):
        code = default_code
    message = getattr(e, "message", None) or f"{type(e).__name__}: {e}"
    return fail_operation(op, code, message)
