"""Long-running Operations (paper §3.2).

SuggestTrials returns an Operation immediately; the actual Pythia computation
runs in a server thread. Clients poll GetOperation until done. Operations are
persisted in the datastore *before* computation starts and contain enough
information (study, client, count) to restart the computation after a server
crash — the paper's server-side fault-tolerance mechanism.
"""

from __future__ import annotations

import time
import uuid
from typing import List, Optional


def new_suggest_operation(study_name: str, client_id: str, count: int) -> dict:
    return {
        "name": f"{study_name}/operations/{uuid.uuid4().hex}",
        "type": "suggest",
        "study_name": study_name,
        "client_id": client_id,
        "suggestion_count": int(count),
        "done": False,
        "create_time": time.time(),
        "result": None,
        "error": None,
    }


def new_early_stopping_operation(study_name: str, trial_id: int) -> dict:
    return {
        "name": f"{study_name}/operations/{uuid.uuid4().hex}",
        "type": "early_stopping",
        "study_name": study_name,
        "client_id": None,
        "trial_id": int(trial_id),
        "done": False,
        "create_time": time.time(),
        "result": None,
        "error": None,
    }


def complete_operation(op: dict, result: dict) -> dict:
    op = dict(op)
    op["result"] = result
    op["done"] = True
    op["complete_time"] = time.time()
    return op


def fail_operation(op: dict, code: int, message: str) -> dict:
    op = dict(op)
    op["error"] = {"code": int(code), "message": str(message)}
    op["done"] = True
    op["complete_time"] = time.time()
    return op


def fail_operation_from_exception(op: dict, e: Exception,
                                  default_code: int = 13) -> dict:
    """Fail an op preserving the RPC status code when the cause carries one.

    A remote Pythia dispatch surfaces per-study failures as VizierRpcError
    objects (e.g. NOT_FOUND for a study deleted mid-flight); collapsing them
    all to INTERNAL would hide whether a client should retry. Duck-typed on
    ``.code`` so this module stays transport-agnostic.
    """
    code = getattr(e, "code", None)
    if not isinstance(code, int):
        code = default_code
    message = getattr(e, "message", None) or f"{type(e).__name__}: {e}"
    return fail_operation(op, code, message)
