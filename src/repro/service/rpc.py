"""RPC transport (paper §3.1-3.2).

The paper's infrastructure is gRPC + protobuf; this container has neither, so
we reproduce the *protocol semantics* over a small, robust transport:

* Frames: 4-byte big-endian length prefix + msgpack body.
* Request:  {"id", "method", "params", "deadline_ms"}
* Response: {"id", "ok", "result"} or {"id", "ok": False,
             "error": {"code", "message"}}
* Server: threaded TCP server; one thread per connection, sequential frames
  per connection (clients pool connections for concurrency).
* Client: lazy connect, automatic reconnect, exponential-backoff retries for
  UNAVAILABLE/connection errors, per-call deadlines. Retry semantics mirror
  gRPC: only idempotent failures (transport-level) are retried; application
  errors surface as VizierRpcError.
* Batching: ``RpcClient.call_many`` pipelines N requests over one connection
  (send all frames, then read all responses in order — the server processes
  frames sequentially per connection), collapsing N network round-trips into
  one. The batched service methods (BatchSuggestTrials / BatchCompleteTrials)
  ride on top of single frames carrying request lists; call_many is the
  transport-level complement used e.g. to poll many operations at once.

A LocalTransport dispatches in-process — the paper notes the server may run
in the same process as the client when evaluation is cheap (§3.2).
"""

from __future__ import annotations

import logging
import random
import socket
import socketserver
import struct
import threading
import time
import uuid
from typing import Any, Callable, Dict, Optional

import msgpack

from repro.service import chaos
from repro.service._lockwitness import make_lock

log = logging.getLogger(__name__)

MAX_FRAME = 256 * 1024 * 1024  # 256 MiB


class StatusCode:
    OK = 0
    UNAVAILABLE = 14
    DEADLINE_EXCEEDED = 4
    NOT_FOUND = 5
    INVALID_ARGUMENT = 3
    ALREADY_EXISTS = 6
    FAILED_PRECONDITION = 9
    INTERNAL = 13
    UNIMPLEMENTED = 12


class VizierRpcError(Exception):
    def __init__(self, code: int, message: str):
        super().__init__(f"[code={code}] {message}")
        self.code = code
        self.message = message


def _pack(obj: dict) -> bytes:
    body = msgpack.packb(obj, use_bin_type=True)
    if len(body) > MAX_FRAME:
        raise VizierRpcError(StatusCode.INVALID_ARGUMENT, "frame too large")
    return struct.pack(">I", len(body)) + body


def _read_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed connection")
        buf += chunk
    return buf


def _read_frame(sock: socket.socket) -> dict:
    (length,) = struct.unpack(">I", _read_exact(sock, 4))
    if length > MAX_FRAME:
        raise VizierRpcError(StatusCode.INVALID_ARGUMENT, "frame too large")
    return msgpack.unpackb(_read_exact(sock, length), raw=False, strict_map_key=False)


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


class Transport:
    """Abstract: issue a single request dict, get a response dict."""

    def call_raw(self, request: dict, timeout: float) -> dict:
        raise NotImplementedError

    def call_raw_many(self, requests: "list[dict]", timeout: float) -> "list[dict]":
        """Issue N requests, responses in request order. Default: sequential.

        On a transport error the responses already read are attached to the
        raised VizierRpcError as ``delivered`` so RpcClient.call_many can
        resend only the undelivered sub-requests.
        """
        out: "list[dict]" = []
        for r in requests:
            try:
                out.append(self.call_raw(r, timeout))
            except VizierRpcError as e:
                e.delivered = list(out)
                raise
        return out

    def close(self) -> None:
        pass


class LocalTransport(Transport):
    """In-process dispatch straight into a servicer (no sockets)."""

    def __init__(self, servicer: "Servicer"):
        self._servicer = servicer

    def call_raw(self, request: dict, timeout: float) -> dict:
        return self._servicer.dispatch(request)


class TcpTransport(Transport):
    """Socket transport with reconnect-on-failure."""

    def __init__(self, address: str):
        host, port = address.rsplit(":", 1)
        self._addr = (host, int(port))
        self._sock: Optional[socket.socket] = None
        self._lock = make_lock("TcpTransport._lock")

    def _connect(self, timeout: float) -> socket.socket:
        sock = socket.create_connection(self._addr, timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def call_raw(self, request: dict, timeout: float) -> dict:
        with self._lock:  # one in-flight request per transport
            try:
                if self._sock is None:
                    self._sock = self._connect(timeout)
                self._sock.settimeout(timeout)
                # archlint: disable=chaos-call-under-lock — the transport lock
                # IS the per-frame serializer: an injected sever must tear
                # *this* connection's frame, so it has to fire inside it
                chaos.inject("transport.send", method=request.get("method"))
                # archlint: disable=lock-blocking-call — this lock IS the
                # per-connection request serializer; blocking socket I/O under
                # it is the design (one in-flight frame per transport)
                self._sock.sendall(_pack(request))
                # archlint: disable=chaos-call-under-lock — a drop models the
                # response frame lost after the server applied the request;
                # only this point in the serializer has that meaning
                chaos.inject("transport.recv", method=request.get("method"))
                return _read_frame(self._sock)
            except (OSError, ConnectionError, struct.error) as e:
                self._drop()
                raise VizierRpcError(StatusCode.UNAVAILABLE, f"transport: {e}") from e

    def call_raw_many(self, requests: "list[dict]", timeout: float) -> "list[dict]":
        """Pipelined: all frames go out, then all responses are read in order.

        Correct because the server handler loop reads/serves/replies one frame
        at a time per connection, so response order == request order. On a
        transport error the responses already read are attached to the raised
        VizierRpcError as ``delivered`` (see Transport.call_raw_many).
        """
        with self._lock:
            delivered: "list[dict]" = []
            try:
                if self._sock is None:
                    self._sock = self._connect(timeout)
                self._sock.settimeout(timeout)
                # archlint: disable=chaos-call-under-lock — the transport lock
                # IS the per-frame serializer; a batch sever must tear this
                # connection's pipelined frames, so it fires inside it
                chaos.inject("transport.send", method=requests[0].get("method"))
                # archlint: disable=lock-blocking-call — pipelined frames ride
                # the same per-connection serializer lock by design
                self._sock.sendall(b"".join(_pack(r) for r in requests))
                for i in range(len(requests)):
                    # archlint: disable=chaos-call-under-lock — a drop at
                    # index i loses response i after the server applied it;
                    # only this point in the serializer has that meaning
                    chaos.inject("transport.recv",
                                 method=requests[i].get("method"), index=i)
                    delivered.append(_read_frame(self._sock))
                return delivered
            except (OSError, ConnectionError, struct.error) as e:
                self._drop()
                err = VizierRpcError(StatusCode.UNAVAILABLE, f"transport: {e}")
                err.delivered = delivered
                raise err from e

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._drop()


# ---------------------------------------------------------------------------
# Client with retries/deadlines (gRPC-style fault tolerance)
# ---------------------------------------------------------------------------


class RetryBudget:
    """Token-bucket retry budget shared by every call on one client.

    Each retry spends a token; the bucket refills at ``refill_per_s`` and
    every success refunds ``success_credit``. When the bucket runs dry the
    client stops retrying and surfaces the UNAVAILABLE immediately, so an
    injected (or real) outage costs a caller one failed attempt instead of
    ``max_retries`` backoff cycles — retries track the *success* rate of the
    backend rather than amplifying its failure rate into a retry storm
    (gRPC retryThrottling semantics).
    """

    def __init__(self, capacity: float = 32.0, refill_per_s: float = 2.0,
                 success_credit: float = 1.0):
        self.capacity = float(capacity)
        self.refill_per_s = float(refill_per_s)
        self.success_credit = float(success_credit)
        self._tokens = self.capacity
        self._stamp = time.monotonic()
        self._lock = make_lock("RetryBudget._lock")

    def _refill_locked(self) -> None:
        now = time.monotonic()
        self._tokens = min(
            self.capacity,
            self._tokens + (now - self._stamp) * self.refill_per_s)
        self._stamp = now

    def try_spend(self, cost: float = 1.0) -> bool:
        with self._lock:
            self._refill_locked()
            if self._tokens < cost:
                return False
            self._tokens -= cost
            return True

    def record_success(self) -> None:
        with self._lock:
            self._refill_locked()
            self._tokens = min(self.capacity,
                               self._tokens + self.success_credit)

    @property
    def tokens(self) -> float:
        with self._lock:
            self._refill_locked()
            return self._tokens


class CircuitBreaker:
    """Consecutive-transport-failure breaker: closed → open → half-open.

    ``failure_threshold`` consecutive transport failures open the breaker;
    while open, ``allow()`` is False so the client backs off without touching
    the socket (no reconnect storm against a dead or drowning server). After
    ``cooldown_s`` exactly one probe is let through: success closes the
    breaker, failure re-opens it for another cooldown. Only transport-level
    failures count — an application error proves the server is up.
    """

    def __init__(self, failure_threshold: int = 16, cooldown_s: float = 1.0):
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._failures = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self._lock = make_lock("CircuitBreaker._lock")

    def allow(self) -> bool:
        with self._lock:
            if self._opened_at is None:
                return True
            if time.monotonic() - self._opened_at < self.cooldown_s:
                return False
            if self._probing:
                return False
            self._probing = True  # half-open: single probe in flight
            return True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            self._probing = False
            if self._failures >= self.failure_threshold:
                self._opened_at = time.monotonic()

    @property
    def is_open(self) -> bool:
        with self._lock:
            return (self._opened_at is not None
                    and time.monotonic() - self._opened_at < self.cooldown_s)


class RpcClient:
    def __init__(
        self,
        target: "str | Servicer",
        *,
        default_timeout: float = 30.0,
        max_retries: int = 5,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        retry_budget: Optional[RetryBudget] = None,
        circuit_breaker: Optional[CircuitBreaker] = None,
    ):
        if isinstance(target, str):
            self._transport: Transport = TcpTransport(target)
        else:
            self._transport = LocalTransport(target)
        self.default_timeout = default_timeout
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.retry_budget = (retry_budget if retry_budget is not None
                             else RetryBudget())
        self.circuit_breaker = (circuit_breaker if circuit_breaker is not None
                                else CircuitBreaker())

    def _backoff_sleep(self, attempt: int, deadline: float) -> None:
        """Jittered exponential backoff, clamped to the request deadline.

        Unclamped, the last retry could sleep a full backoff (up to
        1.5 * backoff_cap) *past* the deadline before the next loop
        iteration noticed and raised — callers saw DEADLINE_EXCEEDED
        seconds after their deadline. Clamping the sleep to the remaining
        budget makes the error surface at the deadline, not after it.
        """
        delay = min(self.backoff_cap, self.backoff_base * (2**attempt))
        delay *= 0.5 + random.random()
        remaining = deadline - time.monotonic()
        if remaining > 0:
            time.sleep(min(delay, remaining))

    def call(self, method: str, params: dict, *, timeout: Optional[float] = None) -> Any:
        timeout = timeout if timeout is not None else self.default_timeout
        deadline = time.monotonic() + timeout
        request = {
            "id": uuid.uuid4().hex,
            "method": method,
            "params": params,
            "deadline_ms": int(timeout * 1000),
        }
        attempt = 0
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise VizierRpcError(StatusCode.DEADLINE_EXCEEDED, f"{method} deadline")
            if not self.circuit_breaker.allow():
                # open breaker: back off without touching the socket; keep
                # retrying (within budget) so a recovering server is re-probed
                if attempt >= self.max_retries or not self.retry_budget.try_spend():
                    raise VizierRpcError(
                        StatusCode.UNAVAILABLE, f"{method}: circuit breaker open")
                attempt += 1
                self._backoff_sleep(attempt, deadline)
                continue
            try:
                resp = self._transport.call_raw(request, remaining)
            except VizierRpcError as e:
                if e.code != StatusCode.UNAVAILABLE:
                    raise
                self.circuit_breaker.record_failure()
                if attempt >= self.max_retries or not self.retry_budget.try_spend():
                    raise
                attempt += 1
                self._backoff_sleep(attempt, deadline)
                continue
            self.circuit_breaker.record_success()
            if resp.get("ok"):
                self.retry_budget.record_success()
                return resp.get("result")
            err = resp.get("error") or {}
            code = err.get("code", StatusCode.INTERNAL)
            if (code == StatusCode.UNAVAILABLE and attempt < self.max_retries
                    and self.retry_budget.try_spend()):
                attempt += 1
                self._backoff_sleep(attempt, deadline)
                continue
            raise VizierRpcError(code, err.get("message", "unknown error"))

    def call_many(
        self,
        method: str,
        params_list: "list[dict]",
        *,
        timeout: Optional[float] = None,
        return_exceptions: bool = False,
    ) -> "list[Any]":
        """N calls of one method, pipelined over a single connection.

        Results come back in params order. On a mid-batch transport failure
        the responses already read are kept and only the *undelivered*
        sub-requests are resent — a sub-request whose response was read is
        never re-sent, so batching non-idempotent methods cannot double-apply
        work the server already acknowledged. (A sub-request whose response
        was lost in flight is still at-least-once, same as any single call:
        services dedupe those via client-chosen operation ids.) The first
        application error is raised after all responses are read, so the
        connection stays frame-aligned. With ``return_exceptions=True``
        application errors are returned in-place as VizierRpcError objects
        instead — per-item fault isolation for pipelined reads where one bad
        key must not fail its siblings.
        """
        if not params_list:
            return []
        timeout = timeout if timeout is not None else self.default_timeout
        deadline = time.monotonic() + timeout
        requests = [
            {
                "id": uuid.uuid4().hex,
                "method": method,
                "params": params,
                "deadline_ms": int(timeout * 1000),
            }
            for params in params_list
        ]
        responses_by_id: Dict[str, dict] = {}

        def _absorb(resps: "list[dict]") -> None:
            for resp in resps:
                rid = resp.get("id")
                if rid is not None:
                    responses_by_id[rid] = resp

        pending = list(requests)
        attempt = 0
        while pending:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise VizierRpcError(StatusCode.DEADLINE_EXCEEDED, f"{method} deadline")
            if not self.circuit_breaker.allow():
                if attempt >= self.max_retries or not self.retry_budget.try_spend():
                    raise VizierRpcError(
                        StatusCode.UNAVAILABLE, f"{method}: circuit breaker open")
                attempt += 1
                self._backoff_sleep(attempt, deadline)
                continue
            try:
                _absorb(self._transport.call_raw_many(pending, remaining))
            except VizierRpcError as e:
                _absorb(getattr(e, "delivered", None) or [])
                pending = [r for r in pending if r["id"] not in responses_by_id]
                if e.code != StatusCode.UNAVAILABLE:
                    raise
                self.circuit_breaker.record_failure()
                if attempt >= self.max_retries or not self.retry_budget.try_spend():
                    raise
                attempt += 1
                self._backoff_sleep(attempt, deadline)
                continue
            self.circuit_breaker.record_success()
            self.retry_budget.record_success()
            pending = [r for r in pending if r["id"] not in responses_by_id]
        results = []
        first_error: Optional[VizierRpcError] = None
        for req in requests:
            resp = responses_by_id.get(req["id"]) or {}
            if resp.get("ok"):
                results.append(resp.get("result"))
                continue
            err = resp.get("error") or {}
            error = VizierRpcError(
                err.get("code", StatusCode.INTERNAL),
                err.get("message", "unknown error"),
            )
            if first_error is None:
                first_error = error
            results.append(error if return_exceptions else None)
        if first_error is not None and not return_exceptions:
            raise first_error
        return results

    def close(self) -> None:
        self._transport.close()


class PooledRpcClient:
    """Thread-affine RpcClient pool: one connection per calling thread.

    A single RpcClient over TCP serializes concurrent callers on its
    transport lock — fine for one client thread, a bottleneck for the
    Pythia worker pool, where N workers dispatch coalesced batches
    concurrently to the same Pythia service. Each thread lazily gets its own
    RpcClient (same retry/deadline semantics); close() tears down every
    connection ever created.
    """

    def __init__(self, target: "str | Servicer", **client_kwargs):
        self._target = target
        self._kwargs = client_kwargs
        self._local = threading.local()
        self._all: "list[RpcClient]" = []
        self._all_lock = make_lock("PooledRpcClient._all_lock")

    def _client(self) -> RpcClient:
        client = getattr(self._local, "client", None)
        if client is None:
            client = RpcClient(self._target, **self._kwargs)
            self._local.client = client
            with self._all_lock:
                self._all.append(client)
        return client

    def call(self, method: str, params: dict, *, timeout: Optional[float] = None) -> Any:
        return self._client().call(method, params, timeout=timeout)

    def call_many(self, method: str, params_list: "list[dict]", **kwargs) -> "list[Any]":
        return self._client().call_many(method, params_list, **kwargs)

    def close(self) -> None:
        with self._all_lock:
            clients, self._all = self._all, []
        for c in clients:
            c.close()


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class Servicer:
    """Registry of method handlers. Subclasses register via expose().

    Every dispatched frame is tallied in ``method_counts`` — the
    frame-counting regression tests assert the coalesced suggestion path
    really does collapse to one GetTrialsMulti + one PythiaBatchSuggest
    frame per batch.
    """

    def __init__(self):
        self._methods: Dict[str, Callable[[dict], Any]] = {}
        self._counts: Dict[str, int] = {}
        self._counts_lock = make_lock("Servicer._counts_lock")

    def expose(self, name: str, fn: Callable[[dict], Any]) -> None:
        self._methods[name] = fn

    def method_counts(self) -> Dict[str, int]:
        """Frames dispatched per method since construction (or last reset)."""
        with self._counts_lock:
            return dict(self._counts)

    def reset_method_counts(self) -> None:
        with self._counts_lock:
            self._counts.clear()

    def dispatch(self, request: dict) -> dict:
        rid = request.get("id")
        method = request.get("method", "")
        with self._counts_lock:
            self._counts[method] = self._counts.get(method, 0) + 1
        fn = self._methods.get(method)
        if fn is None:
            return {
                "id": rid,
                "ok": False,
                "error": {"code": StatusCode.UNIMPLEMENTED, "message": f"no method {method!r}"},
            }
        try:
            result = fn(request.get("params") or {})
            return {"id": rid, "ok": True, "result": result}
        except VizierRpcError as e:
            return {"id": rid, "ok": False, "error": {"code": e.code, "message": e.message}}
        except Exception as e:  # noqa: BLE001 - server must not die on handler bugs
            log.exception("handler %s failed", method)
            # duck-type a carried status code so exceptions like
            # PolicyConstructionError keep INVALID_ARGUMENT over the wire
            code = getattr(e, "code", None)
            if not isinstance(code, int):
                code = StatusCode.INTERNAL
            return {
                "id": rid,
                "ok": False,
                "error": {"code": code, "message": f"{type(e).__name__}: {e}"},
            }


class _Handler(socketserver.BaseRequestHandler):
    def handle(self):
        sock: socket.socket = self.request
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        servicer: Servicer = self.server.servicer  # type: ignore[attr-defined]
        while True:
            try:
                request = _read_frame(sock)
            except (ConnectionError, OSError, struct.error):
                return  # client went away
            response = servicer.dispatch(request)
            try:
                sock.sendall(_pack(response))
            except (OSError, ConnectionError):
                return


class _ThreadingTCPServer(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True
    # the socketserver default backlog of 5 drops SYNs when hundreds of
    # clients dial at once (the scale-out benchmark's 256-client storm
    # surfaced as DEADLINE_EXCEEDED on first calls); match a production
    # listen queue instead
    request_queue_size = 1024


class RpcServer:
    """Threaded TCP server wrapping a Servicer (paper Code Block 4)."""

    def __init__(self, servicer: Servicer, host: str = "127.0.0.1", port: int = 0):
        self._server = _ThreadingTCPServer((host, port), _Handler)
        self._server.servicer = servicer  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> str:
        host, port = self._server.server_address[:2]
        return f"{host}:{port}"

    def start(self) -> "RpcServer":
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.05}, daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
