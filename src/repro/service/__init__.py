"""Distributed fault-tolerant service layer (paper §3)."""

from repro.service.client import BatchSuggestionError, VizierBatchClient, VizierClient
from repro.service.datastore import (
    Datastore,
    InMemoryDatastore,
    KeyAlreadyExistsError,
    NotFoundError,
    SQLiteDatastore,
)
from repro.service.rpc import (
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)
from repro.service.server import DefaultVizierServer, DistributedVizierServer
from repro.service.vizier_service import (
    InProcessPythia,
    PythiaConnector,
    RemotePythia,
    VizierService,
)

__all__ = [
    "BatchSuggestionError", "VizierBatchClient", "VizierClient", "Datastore",
    "InMemoryDatastore", "KeyAlreadyExistsError",
    "NotFoundError", "SQLiteDatastore", "RpcClient", "RpcServer", "Servicer",
    "StatusCode", "VizierRpcError", "DefaultVizierServer",
    "DistributedVizierServer", "InProcessPythia", "PythiaConnector",
    "RemotePythia", "VizierService",
]
