"""Distributed fault-tolerant service layer (paper §3)."""

from repro.service import chaos
from repro.service.chaos import ChaosError, Fault, FaultInjector
from repro.service.client import (
    BatchSuggestionError,
    OperationFailedError,
    VizierBatchClient,
    VizierClient,
)
from repro.service.datastore import (
    Datastore,
    DatastoreBusyError,
    InMemoryDatastore,
    KeyAlreadyExistsError,
    NotFoundError,
    ShardedSqliteDatastore,
    SQLiteDatastore,
)
from repro.service.rpc import (
    CircuitBreaker,
    PooledRpcClient,
    RetryBudget,
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)
from repro.service.server import DefaultVizierServer, DistributedVizierServer
from repro.service.vizier_service import (
    InProcessPythia,
    PythiaConnector,
    RemotePythia,
    VizierService,
)
from repro.service.work_queue import PythiaWorkerPool, ShardedWorkQueue

__all__ = [
    "BatchSuggestionError", "OperationFailedError", "VizierBatchClient",
    "VizierClient", "Datastore", "DatastoreBusyError", "InMemoryDatastore",
    "KeyAlreadyExistsError", "NotFoundError", "ShardedSqliteDatastore",
    "SQLiteDatastore", "CircuitBreaker", "PooledRpcClient", "RetryBudget",
    "RpcClient", "RpcServer", "Servicer", "StatusCode", "VizierRpcError",
    "DefaultVizierServer", "DistributedVizierServer", "InProcessPythia",
    "PythiaConnector", "RemotePythia", "VizierService", "PythiaWorkerPool",
    "ShardedWorkQueue", "chaos", "ChaosError", "Fault", "FaultInjector",
]
