"""Service stand-up helpers (paper Code Block 4).

    server = DefaultVizierServer(host='localhost')   # in one process
    client = VizierClient.load_or_create_study(..., target=server.address)

Modes:
  * DefaultVizierServer        — API server with in-process Pythia.
  * DistributedVizierServer    — API server + separate Pythia service, the
    full Figure-2 topology (two servers, three RPC hops).
  * Local mode — pass the servicer object itself as the client target; no
    sockets at all (paper §3.2 "launched in the same local process").
"""

from __future__ import annotations

import os
from typing import Optional

from repro.service import chaos
from repro.service.datastore import (
    Datastore,
    InMemoryDatastore,
    ShardedSqliteDatastore,
    SQLiteDatastore,
)
from repro.service.pythia_service import PythiaServicer
from repro.service.rpc import PooledRpcClient, RpcServer
from repro.service.vizier_service import InProcessPythia, RemotePythia, VizierService


def _make_datastore(database_path: Optional[str],
                    database_shards: int,
                    database_synchronous: str = "NORMAL") -> Datastore:
    """Storage tier selection, shared by both server shapes.

    ``database_shards`` > 0 selects the per-shard-file SQLite backend
    (``database_path`` is then a directory); a plain ``database_path``
    keeps the single-file store; neither means in-memory.
    ``database_synchronous`` sets the SQLite durability level for either
    file-backed shape ("FULL" fsyncs every commit — acked work survives
    power loss, not just process death). The datastore is wrapped for chaos
    injection only when ``CHAOS_SEED`` is active.
    """
    if database_shards > 0:
        if not database_path:
            raise ValueError("database_shards > 0 requires database_path")
        ds: Datastore = ShardedSqliteDatastore(
            database_path, n_shards=database_shards,
            synchronous=database_synchronous)
    elif database_path:
        ds = SQLiteDatastore(database_path, synchronous=database_synchronous)
    else:
        ds = InMemoryDatastore()
    chaos.install_from_env()
    return chaos.wrap_datastore(ds)


class DefaultVizierServer:
    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        database_path: Optional[str] = None,
        database_shards: int = 0,
        database_synchronous: str = "NORMAL",
        reassign_stalled_after: Optional[float] = None,
        recover: bool = True,
        n_pythia_workers: int = 0,
        n_shards: int = 8,
        lease_timeout: float = 30.0,
    ):
        """``n_pythia_workers`` > 0 enables the scale-out serving tier: a
        pool of Pythia workers pulling coalesced batches off an
        ``n_shards``-way study-sharded work queue (0 keeps the classic
        direct thread-pool dispatch). ``database_shards`` > 0 stores each
        study shard in its own SQLite file under the ``database_path``
        directory."""
        self.datastore: Datastore = _make_datastore(database_path,
                                                    database_shards,
                                                    database_synchronous)
        self.servicer = VizierService(
            self.datastore,
            InProcessPythia(self.datastore),
            reassign_stalled_after=reassign_stalled_after,
            n_pythia_workers=n_pythia_workers,
            n_shards=n_shards,
            lease_timeout=lease_timeout,
        )
        self._server = RpcServer(self.servicer, host=host, port=port).start()
        if recover:
            self.servicer.recover_pending_operations()

    @property
    def address(self) -> str:
        return self._server.address

    def stop_pythia_worker(self, worker_id: int) -> int:
        """Fault injection: kill one Pythia worker; in-flight ops requeue."""
        return self.servicer.worker_pool.stop_worker(worker_id)

    def restart_pythia_worker(self, worker_id: int) -> None:
        self.servicer.worker_pool.restart_worker(worker_id)

    def stop(self) -> None:
        self.servicer.shutdown()
        self._server.stop()


class DistributedVizierServer:
    """API service + standalone Pythia service (paper Figure 2).

    ``coalesce_remote=False`` forces the per-study PythiaSuggest loop instead
    of the single-frame PythiaBatchSuggest dispatch — the baseline the
    throughput benchmark compares against. ``stop_pythia``/``restart_pythia``
    exist for fault-injection tests: the Pythia service can be killed and
    brought back on the same port mid-operation, and in-flight suggestion
    operations must ride the RPC client's retry/backoff to completion (the
    paper's "remains fully fault-tolerant" claim for the Figure-2 split).
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        *,
        database_path: Optional[str] = None,
        database_shards: int = 0,
        database_synchronous: str = "NORMAL",
        reassign_stalled_after: Optional[float] = None,
        coalesce_remote: bool = True,
        pythia_single_fetch: bool = True,
        n_pythia_workers: int = 0,
        n_shards: int = 8,
        lease_timeout: float = 30.0,
    ):
        self.datastore: Datastore = _make_datastore(database_path,
                                                    database_shards,
                                                    database_synchronous)
        # 1. API server comes up first (Pythia dials back into it).
        self.servicer = VizierService(
            self.datastore, pythia=None,
            reassign_stalled_after=reassign_stalled_after,
            n_pythia_workers=n_pythia_workers,
            n_shards=n_shards,
            lease_timeout=lease_timeout,
        )
        self._api_server = RpcServer(self.servicer, host=host, port=0).start()
        # 2. Pythia server, pointed at the API server.
        self._host = host
        self._pythia_single_fetch = pythia_single_fetch
        self.pythia_servicer = PythiaServicer(
            self._api_server.address, single_fetch=pythia_single_fetch)
        self._pythia_server = RpcServer(self.pythia_servicer, host=host, port=0).start()
        # 3. Rewire the API server's connector to the remote Pythia. The
        # enlarged retry budget (8 attempts, capped exponential backoff)
        # lets in-flight suggest ops ride out a Pythia restart of roughly
        # ten seconds; see stop_pythia/restart_pythia. The pooled client
        # gives each Pythia worker its own connection, so concurrent
        # coalesced dispatches don't serialize on one transport lock.
        self.servicer._pythia = RemotePythia(
            PooledRpcClient(self._pythia_server.address, max_retries=8),
            coalesce=coalesce_remote,
        )
        self.servicer.recover_pending_operations()

    @property
    def address(self) -> str:
        return self._api_server.address

    @property
    def pythia_address(self) -> str:
        return self._pythia_server.address

    def stop_pythia(self) -> None:
        """Kill the Pythia service (fault injection). The API server keeps
        running; in-flight suggest dispatches retry with capped exponential
        backoff (8 attempts, ~10 s of tolerance) — an outage that outlives
        the retry budget fails those ops with UNAVAILABLE, and the client
        surfaces the error so callers can re-request (their op is
        persisted, so recover_pending_operations also re-runs any op that
        never reached dispatch)."""
        self._pythia_server.stop()

    def restart_pythia(self) -> None:
        """Bring Pythia back on the SAME address a client already dials."""
        port = int(self._pythia_server.address.rsplit(":", 1)[1])
        self.pythia_servicer.close()  # drop the dead servicer's pooled conns
        self.pythia_servicer = PythiaServicer(
            self._api_server.address, single_fetch=self._pythia_single_fetch)
        self._pythia_server = RpcServer(
            self.pythia_servicer, host=self._host, port=port
        ).start()

    def stop_pythia_worker(self, worker_id: int) -> int:
        """Worker-granular fault injection (vs stop_pythia's whole-process
        kill): one Pythia worker dies mid-lease; its in-flight ops requeue
        onto surviving workers. Returns the number of requeued ops."""
        return self.servicer.worker_pool.stop_worker(worker_id)

    def restart_pythia_worker(self, worker_id: int) -> None:
        self.servicer.worker_pool.restart_worker(worker_id)

    def stop(self) -> None:
        self.servicer.shutdown()
        self.pythia_servicer.close()
        self._pythia_server.stop()
        self._api_server.stop()
