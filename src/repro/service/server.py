"""Service stand-up helpers (paper Code Block 4).

    server = DefaultVizierServer(host='localhost')   # in one process
    client = VizierClient.load_or_create_study(..., target=server.address)

Modes:
  * DefaultVizierServer        — API server with in-process Pythia.
  * DistributedVizierServer    — API server + separate Pythia service, the
    full Figure-2 topology (two servers, three RPC hops).
  * Local mode — pass the servicer object itself as the client target; no
    sockets at all (paper §3.2 "launched in the same local process").
"""

from __future__ import annotations

import os
from typing import Optional

from repro.service.datastore import Datastore, InMemoryDatastore, SQLiteDatastore
from repro.service.pythia_service import PythiaServicer
from repro.service.rpc import RpcClient, RpcServer
from repro.service.vizier_service import InProcessPythia, RemotePythia, VizierService


class DefaultVizierServer:
    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        database_path: Optional[str] = None,
        reassign_stalled_after: Optional[float] = None,
        recover: bool = True,
    ):
        self.datastore: Datastore = (
            SQLiteDatastore(database_path) if database_path else InMemoryDatastore()
        )
        self.servicer = VizierService(
            self.datastore,
            InProcessPythia(self.datastore),
            reassign_stalled_after=reassign_stalled_after,
        )
        self._server = RpcServer(self.servicer, host=host, port=port).start()
        if recover:
            self.servicer.recover_pending_operations()

    @property
    def address(self) -> str:
        return self._server.address

    def stop(self) -> None:
        self.servicer.shutdown()
        self._server.stop()


class DistributedVizierServer:
    """API service + standalone Pythia service (paper Figure 2).

    ``coalesce_remote=False`` forces the per-study PythiaSuggest loop instead
    of the single-frame PythiaBatchSuggest dispatch — the baseline the
    throughput benchmark compares against. ``stop_pythia``/``restart_pythia``
    exist for fault-injection tests: the Pythia service can be killed and
    brought back on the same port mid-operation, and in-flight suggestion
    operations must ride the RPC client's retry/backoff to completion (the
    paper's "remains fully fault-tolerant" claim for the Figure-2 split).
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        *,
        database_path: Optional[str] = None,
        reassign_stalled_after: Optional[float] = None,
        coalesce_remote: bool = True,
        pythia_single_fetch: bool = True,
    ):
        self.datastore: Datastore = (
            SQLiteDatastore(database_path) if database_path else InMemoryDatastore()
        )
        # 1. API server comes up first (Pythia dials back into it).
        self.servicer = VizierService(
            self.datastore, pythia=None, reassign_stalled_after=reassign_stalled_after
        )
        self._api_server = RpcServer(self.servicer, host=host, port=0).start()
        # 2. Pythia server, pointed at the API server.
        self._host = host
        self._pythia_single_fetch = pythia_single_fetch
        self.pythia_servicer = PythiaServicer(
            self._api_server.address, single_fetch=pythia_single_fetch)
        self._pythia_server = RpcServer(self.pythia_servicer, host=host, port=0).start()
        # 3. Rewire the API server's connector to the remote Pythia. The
        # enlarged retry budget (8 attempts, capped exponential backoff)
        # lets in-flight suggest ops ride out a Pythia restart of roughly
        # ten seconds; see stop_pythia/restart_pythia.
        self.servicer._pythia = RemotePythia(
            RpcClient(self._pythia_server.address, max_retries=8),
            coalesce=coalesce_remote,
        )
        self.servicer.recover_pending_operations()

    @property
    def address(self) -> str:
        return self._api_server.address

    @property
    def pythia_address(self) -> str:
        return self._pythia_server.address

    def stop_pythia(self) -> None:
        """Kill the Pythia service (fault injection). The API server keeps
        running; in-flight suggest dispatches retry with capped exponential
        backoff (8 attempts, ~10 s of tolerance) — an outage that outlives
        the retry budget fails those ops with UNAVAILABLE, and the client
        surfaces the error so callers can re-request (their op is
        persisted, so recover_pending_operations also re-runs any op that
        never reached dispatch)."""
        self._pythia_server.stop()

    def restart_pythia(self) -> None:
        """Bring Pythia back on the SAME address a client already dials."""
        port = int(self._pythia_server.address.rsplit(":", 1)[1])
        self.pythia_servicer = PythiaServicer(
            self._api_server.address, single_fetch=self._pythia_single_fetch)
        self._pythia_server = RpcServer(
            self.pythia_servicer, host=self._host, port=port
        ).start()

    def stop(self) -> None:
        self.servicer.shutdown()
        self._pythia_server.stop()
        self._api_server.stop()
