"""Runtime lock-order witness (the dynamic half of archlint's lock pass).

The static pass can't see orders that only materialize across threads or
through dynamic dispatch (e.g. the ``op_guard`` lambda the worker pool hands
to finalize, which takes the queue CV under the study lock). This module
records the *actual* acquisition graph while tests run and fails on cycles.

Opt-in: the service creates every lock through the ``make_lock`` /
``make_rlock`` / ``make_condition`` factories below. They return plain
``threading`` primitives unless ``ARCHLINT_WITNESS=1`` is set, so production
code pays zero overhead. Unit tests exercise private :class:`LockWitness`
instances directly (never the global ``WITNESS``, which the conftest
session hook audits at the end of a witnessed run).

Witness semantics:

* a thread-local stack tracks the locks each thread currently holds;
* an edge ``A -> B`` is recorded when a thread holding ``A`` *attempts* to
  acquire ``B`` (attempt time, not success time — a deadlocked acquire must
  still contribute its edge);
* re-acquiring the lock at the top of your own stack (RLock reentrancy,
  ``Condition`` re-entry) records no edge;
* edges are keyed by lock *name*, so every per-study lock shares one node —
  two different studies' locks nesting is exactly the ordering hazard the
  witness exists to catch.

``assert_acyclic()`` raises :class:`LockOrderViolation` with the offending
cycle and one sample stack per edge.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Set, Tuple

_ENV_FLAG = "ARCHLINT_WITNESS"


def witness_enabled() -> bool:
    return os.environ.get(_ENV_FLAG, "") not in ("", "0")


class LockOrderViolation(AssertionError):
    def __init__(self, cycle: List[str], samples: Dict[Tuple[str, str], str]):
        self.cycle = cycle
        edge_lines = []
        for a, b in zip(cycle, cycle[1:] + cycle[:1]):
            where = samples.get((a, b), "<unrecorded>")
            edge_lines.append(f"  {a} -> {b}   (first seen: {where})")
        super().__init__(
            "lock-order cycle witnessed at runtime:\n" + "\n".join(edge_lines))


class LockWitness:
    """Process-global acquisition-order recorder."""

    def __init__(self) -> None:
        self._guard = threading.Lock()      # protects the edge map only
        self._local = threading.local()
        # (holder name, acquired name) -> "thread/site" sample
        self._edges: Dict[Tuple[str, str], str] = {}

    # -- called by _WitnessedLock -------------------------------------------
    def _stack(self) -> List[Tuple[str, int]]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def note_acquire_attempt(self, name: str, obj_id: int,
                             reentrant: bool) -> None:
        stack = self._stack()
        held_same_object = any(s == (name, obj_id) for s in stack)
        if held_same_object and reentrant:
            return              # RLock/Condition re-entry can never block
        if held_same_object:
            # non-reentrant self-acquire: certain deadlock; the self-edge
            # makes the cycle checker report it
            edge = (name, name)
        elif stack:
            # note: two *different* objects sharing a name (two per-study
            # locks) also produce a (name, name) self-edge here — nesting
            # distinct study locks IS the ordering hazard
            edge = (stack[-1][0], name)
        else:
            return
        with self._guard:
            if edge not in self._edges:
                t = threading.current_thread().name
                self._edges[edge] = f"thread {t!r}"

    def note_acquired(self, name: str, obj_id: int) -> None:
        self._stack().append((name, obj_id))

    def note_release(self, name: str, obj_id: int) -> None:
        stack = self._stack()
        # release may be out of LIFO order (rare but legal): drop last match
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == (name, obj_id):
                del stack[i]
                return

    # -- inspection ----------------------------------------------------------
    def edges(self) -> Set[Tuple[str, str]]:
        with self._guard:
            return set(self._edges)

    def reset(self) -> None:
        with self._guard:
            self._edges.clear()

    def find_cycle(self) -> Optional[List[str]]:
        with self._guard:
            graph: Dict[str, Set[str]] = {}
            for a, b in self._edges:
                graph.setdefault(a, set()).add(b)
        WHITE, GREY, BLACK = 0, 1, 2
        color = {n: WHITE for n in graph}
        path: List[str] = []

        def dfs(n: str) -> Optional[List[str]]:
            color[n] = GREY
            path.append(n)
            for m in sorted(graph.get(n, ())):
                if m == n:
                    return [n]              # self-edge: same-name nesting
                c = color.get(m, WHITE)
                if c == GREY:
                    return path[path.index(m):]
                if c == WHITE and m in color:
                    found = dfs(m)
                    if found is not None:
                        return found
            path.pop()
            color[n] = BLACK
            return None

        for n in sorted(graph):
            if color[n] == WHITE:
                found = dfs(n)
                if found is not None:
                    return found
            path.clear()
        return None

    def assert_acyclic(self) -> None:
        cycle = self.find_cycle()
        if cycle is not None:
            with self._guard:
                samples = dict(self._edges)
            raise LockOrderViolation(cycle, samples)


WITNESS = LockWitness()


class _WitnessedLock:
    """Wraps a threading primitive, reporting acquire/release to WITNESS.

    Unknown attributes delegate to the wrapped lock so ``threading.Condition``
    still finds ``_is_owned`` / ``_release_save`` / ``_acquire_restore`` on a
    wrapped RLock (Condition's wait/notify protocol probes for them).
    """

    def __init__(self, inner, name: str, witness: LockWitness,
                 reentrant: bool = False):
        self._inner = inner
        self._name = name
        self._witness = witness
        self._reentrant = reentrant

    def acquire(self, *args, **kwargs):
        self._witness.note_acquire_attempt(
            self._name, id(self._inner), self._reentrant)
        ok = self._inner.acquire(*args, **kwargs)
        if ok:
            self._witness.note_acquired(self._name, id(self._inner))
        return ok

    def release(self):
        self._inner.release()
        self._witness.note_release(self._name, id(self._inner))

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    def __getattr__(self, item):
        return getattr(self._inner, item)

    def __repr__(self):
        return f"<witnessed {self._name} {self._inner!r}>"


def make_lock(name: str) -> threading.Lock:
    if not witness_enabled():
        return threading.Lock()
    return _WitnessedLock(threading.Lock(), name, WITNESS)  # type: ignore


def make_rlock(name: str) -> threading.RLock:
    if not witness_enabled():
        return threading.RLock()
    return _WitnessedLock(threading.RLock(), name, WITNESS,
                          reentrant=True)  # type: ignore


def make_condition(name: str) -> threading.Condition:
    """A Condition over a witnessed RLock.

    ``Condition.wait`` releases the underlying lock via ``_release_save`` on
    the *inner* primitive (reached through ``__getattr__`` delegation), so the
    witness sees the CV as held for the whole wait. That is intentional: the
    hazard being witnessed is what else a CV holder tries to acquire, and
    wait-side wakeups re-acquire before returning to user code.
    """
    if not witness_enabled():
        return threading.Condition()
    return threading.Condition(
        _WitnessedLock(threading.RLock(), name, WITNESS,
                       reentrant=True))  # type: ignore
