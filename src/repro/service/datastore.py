"""Persistent datastore (paper §3.1 "Persistent Datastore", §3.2 fault tolerance).

Two implementations behind one interface:

* InMemoryDatastore — dict-based, thread-safe; for tests/benchmarks.
* SQLiteDatastore — durable SQL store (WAL journal). Studies/trials/operations
  are stored as msgpack'd wire protos, so the schema is stable across code
  versions; secondary columns support the filtered queries PolicySupporter
  needs without deserializing everything (paper §6.2).

Server-side fault tolerance rests on this layer: `Operation`s are persisted
with enough information to restart suggestion computations after a crash.

Batched reads: ``list_trials_multi`` fetches the trials of N studies in one
call (one SQL query / one lock acquisition) so the batched suggestion path
(BatchSuggestTrials) can assemble feature matrices for a whole coalesced
request without N round-trips into the store. Secondary indexes cover the
(study_name, state) and (study_name, client_id) filters plus the pending-
operation scan used by crash recovery.
"""

from __future__ import annotations

import contextlib
import json
import os
import sqlite3
import threading
from typing import Dict, List, Optional, Tuple

import msgpack

from repro.core.metadata import Metadata
from repro.service._lockwitness import make_rlock
from repro.core.study import Study, StudyState, Trial, TrialState


class KeyAlreadyExistsError(Exception):
    pass


class NotFoundError(Exception):
    pass


class DatastoreBusyError(Exception):
    """The storage backend is transiently contended (SQLite busy/locked).

    Carries ``code`` = UNAVAILABLE so the RPC dispatch surfaces a retryable
    status instead of INTERNAL — a handler must never leak a raw
    ``sqlite3.OperationalError: database is locked`` (error-discipline
    invariant); clients treat it like any other brownout and retry within
    their budget.
    """

    code = 14  # StatusCode.UNAVAILABLE (duck-typed; storage stays below rpc)


class Datastore:
    """Interface. All methods are thread-safe."""

    # studies
    def create_study(self, study: Study) -> str:
        raise NotImplementedError

    def get_study(self, study_name: str) -> Study:
        raise NotImplementedError

    def list_studies(self, owner_prefix: str = "") -> List[Study]:
        raise NotImplementedError

    def update_study(self, study: Study) -> None:
        raise NotImplementedError

    def delete_study(self, study_name: str) -> None:
        raise NotImplementedError

    # trials
    def create_trial(self, study_name: str, trial: Trial) -> Trial:
        """Assigns the next sequential id if trial.id == 0; stores; returns it."""
        raise NotImplementedError

    def get_trial(self, study_name: str, trial_id: int) -> Trial:
        raise NotImplementedError

    def list_trials(
        self,
        study_name: str,
        *,
        states: Optional[List[TrialState]] = None,
        client_id: Optional[str] = None,
        min_trial_id: Optional[int] = None,
    ) -> List[Trial]:
        raise NotImplementedError

    def update_trial(self, study_name: str, trial: Trial) -> None:
        raise NotImplementedError

    def delete_trial(self, study_name: str, trial_id: int) -> None:
        raise NotImplementedError

    def max_trial_id(self, study_name: str) -> int:
        raise NotImplementedError

    def list_trials_multi(
        self,
        study_names: List[str],
        *,
        states: Optional[List[TrialState]] = None,
    ) -> Dict[str, List[Trial]]:
        """Trials of several studies in one call (batched suggestion path).

        Returns {study_name: [trials sorted by id]}; every requested study is
        a key (possibly mapping to []). Raises NotFoundError naming the first
        missing study. Default implementation loops; backends override with a
        single query / single lock acquisition.
        """
        return {name: self.list_trials(name, states=states) for name in study_names}

    def list_trials_multi_raw(
        self,
        study_names: List[str],
        *,
        states: Optional[List[TrialState]] = None,
    ) -> Dict[str, List[dict]]:
        """Like list_trials_multi but returns wire protos, not Trial objects.

        The GetTrialsMulti RPC is proto-in/proto-out: materializing a Trial
        per row on the server just to call to_proto() again doubles the
        serialization cost of the coalesced prefetch. Backends serve the
        stored proto dicts directly (trials are written by whole-proto
        replacement, so returned dicts are never mutated in place). Default
        implementation falls back through Trial objects.
        """
        return {
            name: [t.to_proto() for t in trials]
            for name, trials in self.list_trials_multi(
                study_names, states=states).items()
        }

    def study_transaction(self, study_name: str):
        """Context manager making every write inside it atomic and durable
        as one unit (the exactly-once-finalize write set: metadata delta +
        new trials + the done operation). A crash inside the block must
        leave either all of it or none of it; ``recover_pending_operations``
        relies on that to re-run interrupted ops cleanly. Default: no extra
        atomicity (single-write backends).
        """
        return contextlib.nullcontext()

    def close(self) -> None:
        pass

    # operations (long-running computations; paper §3.2)
    def put_operation(self, op: dict) -> None:
        raise NotImplementedError

    def get_operation(self, op_name: str) -> dict:
        raise NotImplementedError

    def list_operations(
        self, study_name: str, *, client_id: Optional[str] = None, only_pending: bool = False
    ) -> List[dict]:
        raise NotImplementedError

    # study-level metadata (Pythia state saving; paper §6.3)
    def update_study_metadata(self, study_name: str, metadata: Metadata) -> None:
        study = self.get_study(study_name)
        study.study_config.metadata.attach(metadata)
        self.update_study(study)

    def update_trial_metadata(self, study_name: str, trial_id: int, metadata: Metadata) -> None:
        trial = self.get_trial(study_name, trial_id)
        trial.metadata.attach(metadata)
        self.update_trial(study_name, trial)

    def apply_metadata_delta(self, study_name: str, delta) -> List[int]:
        """Applies a policy MetadataDelta (study + per-trial) in one go.

        This is how persisted algorithm state (e.g. the GP-bandit's
        ``repro.gp_bandit`` checkpoint) reaches the store. Per-trial updates
        naming a trial that no longer exists are skipped — a policy may
        reference ids deleted mid-operation — and the skipped ids are
        returned so RPC callers can surface them. Backends override to hold
        their lock across the whole read-modify-write so concurrent deltas
        cannot interleave and lose writes.
        """
        if delta.on_study._store:
            self.update_study_metadata(study_name, delta.on_study)
        skipped: List[int] = []
        for trial_id, md in delta.on_trials.items():
            try:
                self.update_trial_metadata(study_name, trial_id, md)
            except NotFoundError:
                skipped.append(trial_id)
        return skipped


# ---------------------------------------------------------------------------


# proto ``state`` values whose trials never change again once stored —
# safe to cache their materialized Trial objects across list_trials calls
_TERMINAL_STATE_VALUES = frozenset(
    s.value for s in TrialState if s.is_terminal)


class InMemoryDatastore(Datastore):
    def __init__(self):
        self._lock = make_rlock("InMemoryDatastore._lock")
        self._studies: Dict[str, dict] = {}
        self._trials: Dict[str, Dict[int, dict]] = {}
        self._ops: Dict[str, dict] = {}
        # Terminal-trial materialization cache: {study: {tid: (proto, Trial)}}.
        # list_trials deserializes every stored proto on every call, which
        # dominates suggestion latency once studies reach thousands of
        # completed trials (the Pythia supporter re-reads the full study per
        # operation). Terminal trials are immutable by whole-proto
        # replacement: update_trial swaps the stored dict, so an IDENTITY
        # check against the cached proto detects any write (including
        # metadata attach, which goes get_trial -> update_trial) and
        # invalidates the entry. Non-terminal trials are never cached — the
        # stalled-trial reassignment path mutates ACTIVE trials it listed.
        self._term_cache: Dict[str, Dict[int, tuple]] = {}

    def _materialize(self, study_name: str, tid: int, p: dict) -> Trial:
        """Trial for a stored proto, cached when the trial is terminal."""
        if p.get("state") not in _TERMINAL_STATE_VALUES:
            return Trial.from_proto(p)
        cache = self._term_cache.setdefault(study_name, {})
        hit = cache.get(tid)
        if hit is not None and hit[0] is p:
            return hit[1]
        trial = Trial.from_proto(p)
        cache[tid] = (p, trial)
        return trial

    # studies ----------------------------------------------------------------
    def create_study(self, study: Study) -> str:
        with self._lock:
            if study.name in self._studies:
                raise KeyAlreadyExistsError(study.name)
            self._studies[study.name] = study.to_proto()
            self._trials[study.name] = {}
            return study.name

    def get_study(self, study_name: str) -> Study:
        with self._lock:
            if study_name not in self._studies:
                raise NotFoundError(study_name)
            return Study.from_proto(self._studies[study_name])

    def list_studies(self, owner_prefix: str = "") -> List[Study]:
        with self._lock:
            return [
                Study.from_proto(p)
                for name, p in sorted(self._studies.items())
                if name.startswith(owner_prefix)
            ]

    def update_study(self, study: Study) -> None:
        with self._lock:
            if study.name not in self._studies:
                raise NotFoundError(study.name)
            self._studies[study.name] = study.to_proto()

    def delete_study(self, study_name: str) -> None:
        with self._lock:
            if study_name not in self._studies:
                raise NotFoundError(study_name)
            del self._studies[study_name]
            self._trials.pop(study_name, None)
            self._term_cache.pop(study_name, None)
            self._ops = {k: v for k, v in self._ops.items() if v.get("study_name") != study_name}

    # trials -------------------------------------------------------------------
    def create_trial(self, study_name: str, trial: Trial) -> Trial:
        with self._lock:
            if study_name not in self._studies:
                raise NotFoundError(study_name)
            bucket = self._trials[study_name]
            if trial.id == 0:
                trial.id = (max(bucket) + 1) if bucket else 1
            elif trial.id in bucket:
                raise KeyAlreadyExistsError(f"{study_name}/trials/{trial.id}")
            trial.study_name = study_name
            bucket[trial.id] = trial.to_proto()
            return trial

    def get_trial(self, study_name: str, trial_id: int) -> Trial:
        with self._lock:
            bucket = self._trials.get(study_name)
            if bucket is None or trial_id not in bucket:
                raise NotFoundError(f"{study_name}/trials/{trial_id}")
            return Trial.from_proto(bucket[trial_id])

    def list_trials(self, study_name, *, states=None, client_id=None, min_trial_id=None):
        with self._lock:
            if study_name not in self._trials:
                raise NotFoundError(study_name)
            out = []
            state_values = {s.value for s in states} if states else None
            for tid in sorted(self._trials[study_name]):
                p = self._trials[study_name][tid]
                if state_values and p.get("state") not in state_values:
                    continue
                if client_id is not None and p.get("client_id") != client_id:
                    continue
                if min_trial_id is not None and tid < min_trial_id:
                    continue
                out.append(self._materialize(study_name, tid, p))
            return out

    def update_trial(self, study_name: str, trial: Trial) -> None:
        with self._lock:
            bucket = self._trials.get(study_name)
            if bucket is None or trial.id not in bucket:
                raise NotFoundError(f"{study_name}/trials/{trial.id}")
            trial.study_name = study_name
            bucket[trial.id] = trial.to_proto()

    def delete_trial(self, study_name: str, trial_id: int) -> None:
        with self._lock:
            bucket = self._trials.get(study_name)
            if bucket is None or trial_id not in bucket:
                raise NotFoundError(f"{study_name}/trials/{trial_id}")
            del bucket[trial_id]
            self._term_cache.get(study_name, {}).pop(trial_id, None)

    def max_trial_id(self, study_name: str) -> int:
        with self._lock:
            bucket = self._trials.get(study_name)
            if bucket is None:
                raise NotFoundError(study_name)
            return max(bucket) if bucket else 0

    def list_trials_multi(self, study_names, *, states=None):
        # one lock acquisition for the whole batch: a consistent snapshot
        # across studies, which the coalesced Pythia dispatch relies on
        with self._lock:
            out: Dict[str, List[Trial]] = {}
            state_values = {s.value for s in states} if states else None
            for name in study_names:
                bucket = self._trials.get(name)
                if bucket is None:
                    raise NotFoundError(name)
                out[name] = [
                    self._materialize(name, tid, bucket[tid])
                    for tid in sorted(bucket)
                    if state_values is None or bucket[tid].get("state") in state_values
                ]
            return out

    def list_trials_multi_raw(self, study_names, *, states=None):
        with self._lock:
            out: Dict[str, List[dict]] = {}
            state_values = {s.value for s in states} if states else None
            for name in study_names:
                bucket = self._trials.get(name)
                if bucket is None:
                    raise NotFoundError(name)
                out[name] = [
                    bucket[tid]
                    for tid in sorted(bucket)
                    if state_values is None or bucket[tid].get("state") in state_values
                ]
            return out

    # metadata ----------------------------------------------------------------
    def update_study_metadata(self, study_name: str, metadata: Metadata) -> None:
        with self._lock:  # atomic read-modify-write (RLock: reentrant)
            super().update_study_metadata(study_name, metadata)

    def update_trial_metadata(self, study_name, trial_id, metadata) -> None:
        with self._lock:
            super().update_trial_metadata(study_name, trial_id, metadata)

    def apply_metadata_delta(self, study_name: str, delta) -> List[int]:
        with self._lock:
            return super().apply_metadata_delta(study_name, delta)

    # ops -------------------------------------------------------------------------
    def put_operation(self, op: dict) -> None:
        with self._lock:
            self._ops[op["name"]] = dict(op)

    def get_operation(self, op_name: str) -> dict:
        with self._lock:
            if op_name not in self._ops:
                raise NotFoundError(op_name)
            return dict(self._ops[op_name])

    def list_operations(self, study_name, *, client_id=None, only_pending=False):
        with self._lock:
            out = []
            for op in self._ops.values():
                if op.get("study_name") != study_name:
                    continue
                if client_id is not None and op.get("client_id") != client_id:
                    continue
                if only_pending and op.get("done"):
                    continue
                out.append(dict(op))
            return sorted(out, key=lambda o: o.get("create_time", 0))

    def study_transaction(self, study_name: str):
        # one backend lock ⇒ holding it makes the write set atomic w.r.t.
        # every reader; durability is moot for an in-memory store
        return self._lock


# ---------------------------------------------------------------------------


_SYNCHRONOUS_MODES = {"OFF", "NORMAL", "FULL", "EXTRA"}


def _open_conn(path: str, busy_timeout_ms: int,
               synchronous: str) -> sqlite3.Connection:
    """Open a connection in manual-transaction mode.

    ``isolation_level=None`` disables sqlite3's implicit BEGIN so our
    explicit BEGIN IMMEDIATE / COMMIT below are the *only* transactions —
    the stdlib's autobegin interacts badly with reentrant write scopes
    (a nested ``with conn`` commits the outer transaction early).
    """
    if synchronous.upper() not in _SYNCHRONOUS_MODES:
        raise ValueError(f"bad synchronous mode {synchronous!r}")
    conn = sqlite3.connect(path, check_same_thread=False, isolation_level=None)
    conn.execute("PRAGMA journal_mode=WAL")
    # without a busy timeout a cross-process writer collision surfaces
    # instantly as "database is locked"; with it SQLite spins internally
    conn.execute(f"PRAGMA busy_timeout={int(busy_timeout_ms)}")
    conn.execute(f"PRAGMA synchronous={synchronous.upper()}")
    return conn


def _init_schema(conn: sqlite3.Connection) -> None:
    conn.execute(
        "CREATE TABLE IF NOT EXISTS studies ("
        " name TEXT PRIMARY KEY, proto BLOB NOT NULL)"
    )
    conn.execute(
        "CREATE TABLE IF NOT EXISTS trials ("
        " study_name TEXT NOT NULL, trial_id INTEGER NOT NULL,"
        " state TEXT NOT NULL, client_id TEXT, proto BLOB NOT NULL,"
        " PRIMARY KEY (study_name, trial_id))"
    )
    conn.execute(
        "CREATE TABLE IF NOT EXISTS operations ("
        " name TEXT PRIMARY KEY, study_name TEXT NOT NULL,"
        " client_id TEXT, done INTEGER NOT NULL, create_time REAL,"
        " proto BLOB NOT NULL)"
    )
    conn.execute(
        "CREATE INDEX IF NOT EXISTS trials_by_state"
        " ON trials (study_name, state)"
    )
    conn.execute(
        "CREATE INDEX IF NOT EXISTS trials_by_client"
        " ON trials (study_name, client_id)"
    )
    conn.execute(
        "CREATE INDEX IF NOT EXISTS ops_pending"
        " ON operations (study_name, done)"
    )


class SQLiteDatastore(Datastore):
    """Durable datastore; survives process crashes (server-side fault tolerance).

    All writes run inside explicit BEGIN IMMEDIATE transactions via
    ``_txn()`` (reentrant: nested scopes join the outer transaction, commit
    happens once at depth 0), so multi-row write sets — apply_metadata_delta,
    the finalize region under ``study_transaction`` — hit disk atomically:
    after a hard kill, recovery sees either the whole write set or none of
    it. Busy/locked contention surfaces as DatastoreBusyError (UNAVAILABLE),
    never a raw sqlite3.OperationalError.
    """

    def __init__(self, path: str = ":memory:", *,
                 busy_timeout_ms: int = 10_000, synchronous: str = "NORMAL"):
        self._path = path
        self._lock = make_rlock("SQLiteDatastore._lock")
        self._txn_depth = 0
        if path != ":memory:":
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._conn = _open_conn(path, busy_timeout_ms, synchronous)
        with self._txn():
            _init_schema(self._conn)

    @contextlib.contextmanager
    def _txn(self):
        """Reentrant write scope: BEGIN IMMEDIATE at depth 0, COMMIT when
        the outermost scope exits cleanly, ROLLBACK if it raises."""
        with self._lock:
            if self._txn_depth == 0:
                try:
                    self._conn.execute("BEGIN IMMEDIATE")
                except sqlite3.OperationalError as e:
                    raise DatastoreBusyError(str(e)) from e
            self._txn_depth += 1
            try:
                yield self._conn
            except BaseException:
                self._txn_depth -= 1
                if self._txn_depth == 0:
                    try:
                        self._conn.execute("ROLLBACK")
                    except sqlite3.Error:
                        pass  # connection torn down mid-failure
                raise
            self._txn_depth -= 1
            if self._txn_depth == 0:
                try:
                    self._conn.execute("COMMIT")
                except sqlite3.OperationalError as e:
                    try:
                        self._conn.execute("ROLLBACK")
                    except sqlite3.Error:
                        pass
                    raise DatastoreBusyError(str(e)) from e

    def study_transaction(self, study_name: str):
        return self._txn()

    # studies --------------------------------------------------------------------
    def create_study(self, study: Study) -> str:
        blob = msgpack.packb(study.to_proto(), use_bin_type=True)
        with self._txn():
            try:
                self._conn.execute(
                    "INSERT INTO studies (name, proto) VALUES (?, ?)", (study.name, blob)
                )
            except sqlite3.IntegrityError as e:
                raise KeyAlreadyExistsError(study.name) from e
        return study.name

    def get_study(self, study_name: str) -> Study:
        with self._lock:
            row = self._conn.execute(
                "SELECT proto FROM studies WHERE name = ?", (study_name,)
            ).fetchone()
        if row is None:
            raise NotFoundError(study_name)
        return Study.from_proto(msgpack.unpackb(row[0], raw=False))

    def list_studies(self, owner_prefix: str = "") -> List[Study]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT proto FROM studies WHERE name LIKE ? ORDER BY name",
                (owner_prefix + "%",),
            ).fetchall()
        return [Study.from_proto(msgpack.unpackb(r[0], raw=False)) for r in rows]

    def update_study(self, study: Study) -> None:
        blob = msgpack.packb(study.to_proto(), use_bin_type=True)
        with self._txn():
            cur = self._conn.execute(
                "UPDATE studies SET proto = ? WHERE name = ?", (blob, study.name)
            )
            if cur.rowcount == 0:
                raise NotFoundError(study.name)

    def delete_study(self, study_name: str) -> None:
        with self._txn():
            cur = self._conn.execute("DELETE FROM studies WHERE name = ?", (study_name,))
            if cur.rowcount == 0:
                raise NotFoundError(study_name)
            self._conn.execute("DELETE FROM trials WHERE study_name = ?", (study_name,))
            self._conn.execute("DELETE FROM operations WHERE study_name = ?", (study_name,))

    # trials -------------------------------------------------------------------------
    def create_trial(self, study_name: str, trial: Trial) -> Trial:
        with self._txn():
            exists = self._conn.execute(
                "SELECT 1 FROM studies WHERE name = ?", (study_name,)
            ).fetchone()
            if exists is None:
                raise NotFoundError(study_name)
            if trial.id == 0:
                row = self._conn.execute(
                    "SELECT COALESCE(MAX(trial_id), 0) FROM trials WHERE study_name = ?",
                    (study_name,),
                ).fetchone()
                trial.id = int(row[0]) + 1
            trial.study_name = study_name
            blob = msgpack.packb(trial.to_proto(), use_bin_type=True)
            try:
                self._conn.execute(
                    "INSERT INTO trials (study_name, trial_id, state, client_id, proto)"
                    " VALUES (?, ?, ?, ?, ?)",
                    (study_name, trial.id, trial.state.value, trial.client_id, blob),
                )
            except sqlite3.IntegrityError as e:
                raise KeyAlreadyExistsError(f"{study_name}/trials/{trial.id}") from e
        return trial

    def get_trial(self, study_name: str, trial_id: int) -> Trial:
        with self._lock:
            row = self._conn.execute(
                "SELECT proto FROM trials WHERE study_name = ? AND trial_id = ?",
                (study_name, trial_id),
            ).fetchone()
        if row is None:
            raise NotFoundError(f"{study_name}/trials/{trial_id}")
        return Trial.from_proto(msgpack.unpackb(row[0], raw=False))

    def list_trials(self, study_name, *, states=None, client_id=None, min_trial_id=None):
        query = "SELECT proto FROM trials WHERE study_name = ?"
        args: list = [study_name]
        if states:
            marks = ",".join("?" * len(states))
            query += f" AND state IN ({marks})"
            args += [s.value for s in states]
        if client_id is not None:
            query += " AND client_id = ?"
            args.append(client_id)
        if min_trial_id is not None:
            query += " AND trial_id >= ?"
            args.append(min_trial_id)
        query += " ORDER BY trial_id"
        with self._lock:
            exists = self._conn.execute(
                "SELECT 1 FROM studies WHERE name = ?", (study_name,)
            ).fetchone()
            if exists is None:
                raise NotFoundError(study_name)
            rows = self._conn.execute(query, args).fetchall()
        return [Trial.from_proto(msgpack.unpackb(r[0], raw=False)) for r in rows]

    def update_trial(self, study_name: str, trial: Trial) -> None:
        trial.study_name = study_name
        blob = msgpack.packb(trial.to_proto(), use_bin_type=True)
        with self._txn():
            cur = self._conn.execute(
                "UPDATE trials SET proto = ?, state = ?, client_id = ?"
                " WHERE study_name = ? AND trial_id = ?",
                (blob, trial.state.value, trial.client_id, study_name, trial.id),
            )
            if cur.rowcount == 0:
                raise NotFoundError(f"{study_name}/trials/{trial.id}")

    def delete_trial(self, study_name: str, trial_id: int) -> None:
        with self._txn():
            cur = self._conn.execute(
                "DELETE FROM trials WHERE study_name = ? AND trial_id = ?",
                (study_name, trial_id),
            )
            if cur.rowcount == 0:
                raise NotFoundError(f"{study_name}/trials/{trial_id}")

    def max_trial_id(self, study_name: str) -> int:
        with self._lock:
            exists = self._conn.execute(
                "SELECT 1 FROM studies WHERE name = ?", (study_name,)
            ).fetchone()
            if exists is None:
                raise NotFoundError(study_name)
            row = self._conn.execute(
                "SELECT COALESCE(MAX(trial_id), 0) FROM trials WHERE study_name = ?",
                (study_name,),
            ).fetchone()
        return int(row[0])

    def _fetch_trial_blobs_or_missing(
            self, study_names, states) -> "Tuple[Dict[str, list], List[str]]":
        """Single-query fetch returning (blobs by study, missing studies).

        Missing studies are *returned*, not raised, so the sharded backend
        can merge per-shard results and still report the first missing study
        in the caller's request order.
        """
        study_names = list(study_names)
        if not study_names:
            return {}, []
        marks = ",".join("?" * len(study_names))
        query = f"SELECT study_name, proto FROM trials WHERE study_name IN ({marks})"
        args: list = list(study_names)
        if states:
            smarks = ",".join("?" * len(states))
            query += f" AND state IN ({smarks})"
            args += [s.value for s in states]
        query += " ORDER BY study_name, trial_id"
        with self._lock:
            known = {
                r[0]
                for r in self._conn.execute(
                    f"SELECT name FROM studies WHERE name IN ({marks})", study_names
                ).fetchall()
            }
            missing = [name for name in study_names if name not in known]
            rows = (self._conn.execute(query, args).fetchall()
                    if not missing else [])
        out: Dict[str, list] = {name: [] for name in study_names}
        for study_name, blob in rows:
            out[study_name].append(blob)
        return out, missing

    def _fetch_trial_blobs_multi(self, study_names, states) -> Dict[str, list]:
        """Shared single-query/single-lock fetch for the multi-study reads."""
        out, missing = self._fetch_trial_blobs_or_missing(study_names, states)
        if missing:
            raise NotFoundError(missing[0])
        return out

    def list_trials_multi(self, study_names, *, states=None):
        return {
            name: [Trial.from_proto(msgpack.unpackb(blob, raw=False))
                   for blob in blobs]
            for name, blobs in self._fetch_trial_blobs_multi(
                study_names, states).items()
        }

    def list_trials_multi_raw(self, study_names, *, states=None):
        return {
            name: [msgpack.unpackb(blob, raw=False) for blob in blobs]
            for name, blobs in self._fetch_trial_blobs_multi(
                study_names, states).items()
        }

    # metadata ----------------------------------------------------------------
    def update_study_metadata(self, study_name: str, metadata: Metadata) -> None:
        with self._txn():  # atomic RMW, one durable commit
            super().update_study_metadata(study_name, metadata)

    def update_trial_metadata(self, study_name, trial_id, metadata) -> None:
        with self._txn():
            super().update_trial_metadata(study_name, trial_id, metadata)

    def apply_metadata_delta(self, study_name: str, delta) -> List[int]:
        # the whole delta (study checkpoint + N trial rows) commits as one
        # transaction: a crash mid-delta must not leave half a GP state
        with self._txn():
            return super().apply_metadata_delta(study_name, delta)

    # ops ---------------------------------------------------------------------------
    def put_operation(self, op: dict) -> None:
        blob = msgpack.packb(op, use_bin_type=True)
        with self._txn():
            self._conn.execute(
                "INSERT INTO operations (name, study_name, client_id, done, create_time, proto)"
                " VALUES (?, ?, ?, ?, ?, ?)"
                " ON CONFLICT(name) DO UPDATE SET done = excluded.done, proto = excluded.proto",
                (
                    op["name"],
                    op.get("study_name", ""),
                    op.get("client_id"),
                    1 if op.get("done") else 0,
                    op.get("create_time", 0.0),
                    blob,
                ),
            )

    def get_operation(self, op_name: str) -> dict:
        with self._lock:
            row = self._conn.execute(
                "SELECT proto FROM operations WHERE name = ?", (op_name,)
            ).fetchone()
        if row is None:
            raise NotFoundError(op_name)
        return msgpack.unpackb(row[0], raw=False)

    def list_operations(self, study_name, *, client_id=None, only_pending=False):
        query = "SELECT proto FROM operations WHERE study_name = ?"
        args: list = [study_name]
        if client_id is not None:
            query += " AND client_id = ?"
            args.append(client_id)
        if only_pending:
            query += " AND done = 0"
        query += " ORDER BY create_time"
        with self._lock:
            rows = self._conn.execute(query, args).fetchall()
        return [msgpack.unpackb(r[0], raw=False) for r in rows]

    def close(self) -> None:
        with self._lock:
            self._conn.close()


# ---------------------------------------------------------------------------


class ShardedSqliteDatastore(Datastore):
    """Per-shard SQLite files keyed by ``operations.shard_of(study_name)``.

    The single-file backend serializes every write on one connection lock —
    under N Pythia workers the storage tier is a single point of contention
    (ROADMAP open item 1). Here each shard owns its own file, connection,
    and lock, so writes to different studies commit (and fsync) in parallel;
    a study's trials, operations, and metadata always live in the *same*
    shard file, so the ``study_transaction`` write set stays atomic within
    one SQLite transaction.

    Layout: ``<path>/layout.json`` ({"n_shards": N}, written once, adopted
    on reopen — the shard count is a property of the data on disk, not the
    process config) plus ``<path>/shard-00.sqlite3`` … ``shard-NN.sqlite3``,
    each with the full schema. The shard index of study S is
    ``shard_of(S, n_shards)`` (stable crc32, same function the work queue
    uses), and an operation name ``<study>/operations/<uuid>`` routes to its
    study's shard.
    """

    def __init__(self, path: str, *, n_shards: int = 8,
                 busy_timeout_ms: int = 10_000, synchronous: str = "NORMAL"):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self._path = os.path.abspath(path)
        os.makedirs(self._path, exist_ok=True)
        layout_path = os.path.join(self._path, "layout.json")
        if os.path.exists(layout_path):
            with open(layout_path, "r", encoding="utf-8") as f:
                persisted = int(json.load(f)["n_shards"])
            n_shards = persisted  # disk wins: rekeying would orphan studies
        else:
            tmp = layout_path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump({"n_shards": n_shards}, f)
            os.replace(tmp, layout_path)
        self.n_shards = n_shards
        self._shards = [
            SQLiteDatastore(
                os.path.join(self._path, f"shard-{i:02d}.sqlite3"),
                busy_timeout_ms=busy_timeout_ms, synchronous=synchronous)
            for i in range(n_shards)
        ]

    def _shard(self, study_name: str) -> SQLiteDatastore:
        from repro.service.operations import shard_of
        return self._shards[shard_of(study_name, self.n_shards)]

    def _shard_of_op(self, op_name: str) -> Optional[SQLiteDatastore]:
        study_name, sep, _ = op_name.partition("/operations/")
        return self._shard(study_name) if sep else None

    # studies --------------------------------------------------------------------
    def create_study(self, study: Study) -> str:
        return self._shard(study.name).create_study(study)

    def get_study(self, study_name: str) -> Study:
        return self._shard(study_name).get_study(study_name)

    def list_studies(self, owner_prefix: str = "") -> List[Study]:
        # shards visited one at a time (never two shard locks at once)
        out: List[Study] = []
        for shard in self._shards:
            out.extend(shard.list_studies(owner_prefix))
        out.sort(key=lambda s: s.name)
        return out

    def update_study(self, study: Study) -> None:
        self._shard(study.name).update_study(study)

    def delete_study(self, study_name: str) -> None:
        self._shard(study_name).delete_study(study_name)

    # trials -------------------------------------------------------------------------
    def create_trial(self, study_name: str, trial: Trial) -> Trial:
        return self._shard(study_name).create_trial(study_name, trial)

    def get_trial(self, study_name: str, trial_id: int) -> Trial:
        return self._shard(study_name).get_trial(study_name, trial_id)

    def list_trials(self, study_name, *, states=None, client_id=None, min_trial_id=None):
        return self._shard(study_name).list_trials(
            study_name, states=states, client_id=client_id,
            min_trial_id=min_trial_id)

    def update_trial(self, study_name: str, trial: Trial) -> None:
        self._shard(study_name).update_trial(study_name, trial)

    def delete_trial(self, study_name: str, trial_id: int) -> None:
        self._shard(study_name).delete_trial(study_name, trial_id)

    def max_trial_id(self, study_name: str) -> int:
        return self._shard(study_name).max_trial_id(study_name)

    def _multi_blobs(self, study_names, states) -> Dict[str, list]:
        """Group the request by shard, fetch per shard, and keep the
        single-backend contract: NotFoundError names the first missing
        study in the *request* order even when it lives on a later shard."""
        study_names = list(study_names)
        by_shard: Dict[int, List[str]] = {}
        from repro.service.operations import shard_of
        for name in study_names:
            by_shard.setdefault(shard_of(name, self.n_shards), []).append(name)
        merged: Dict[str, list] = {}
        missing: List[str] = []
        for idx, names in by_shard.items():
            out, miss = self._shards[idx]._fetch_trial_blobs_or_missing(
                names, states)
            merged.update(out)
            missing.extend(miss)
        if missing:
            missing_set = set(missing)
            first = next(n for n in study_names if n in missing_set)
            raise NotFoundError(first)
        return {name: merged[name] for name in study_names}

    def list_trials_multi(self, study_names, *, states=None):
        return {
            name: [Trial.from_proto(msgpack.unpackb(blob, raw=False))
                   for blob in blobs]
            for name, blobs in self._multi_blobs(study_names, states).items()
        }

    def list_trials_multi_raw(self, study_names, *, states=None):
        return {
            name: [msgpack.unpackb(blob, raw=False) for blob in blobs]
            for name, blobs in self._multi_blobs(study_names, states).items()
        }

    # metadata ----------------------------------------------------------------
    def update_study_metadata(self, study_name: str, metadata: Metadata) -> None:
        self._shard(study_name).update_study_metadata(study_name, metadata)

    def update_trial_metadata(self, study_name, trial_id, metadata) -> None:
        self._shard(study_name).update_trial_metadata(
            study_name, trial_id, metadata)

    def apply_metadata_delta(self, study_name: str, delta) -> List[int]:
        return self._shard(study_name).apply_metadata_delta(study_name, delta)

    def study_transaction(self, study_name: str):
        return self._shard(study_name).study_transaction(study_name)

    # ops ---------------------------------------------------------------------------
    def put_operation(self, op: dict) -> None:
        study_name = op.get("study_name") or op["name"].partition(
            "/operations/")[0]
        self._shard(study_name).put_operation(op)

    def get_operation(self, op_name: str) -> dict:
        shard = self._shard_of_op(op_name)
        if shard is not None:
            return shard.get_operation(op_name)
        for shard in self._shards:  # malformed name: fall back to a scan
            try:
                return shard.get_operation(op_name)
            except NotFoundError:
                continue
        raise NotFoundError(op_name)

    def list_operations(self, study_name, *, client_id=None, only_pending=False):
        return self._shard(study_name).list_operations(
            study_name, client_id=client_id, only_pending=only_pending)

    def close(self) -> None:
        for shard in self._shards:
            shard.close()
