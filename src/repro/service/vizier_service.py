"""The OSS Vizier API servicer (paper §3.2, Figure 2).

Implements the RPC surface with Vertex-Vizier method names:

  CreateStudy / GetStudy / ListStudies / DeleteStudy / SetStudyState
  SuggestTrials -> Operation           (Pythia runs in a server thread)
  BatchSuggestTrials -> [Operation]    (N studies' suggestions, one dispatch)
  GetOperation                         (client polling loop)
  CompleteTrial / AddTrialMeasurement / GetTrial / ListTrials / DeleteTrial
  BatchCompleteTrials                  (N completions, one round trip)
  CheckTrialEarlyStoppingState -> Operation
  StopTrial / ListOptimalTrials / UpdateMetadata / ListAlgorithms

Batched suggestion path: BatchSuggestTrials coalesces the suggestion
operations of many (study, client) pairs arriving in one request into a
single Pythia dispatch — one thread-pool job, one multi-study datastore
prefetch (Datastore.list_trials_multi), one policy construction per study —
instead of one job + per-study query fan-out per call. Fast paths (own
ACTIVE trials, stalled-trial reassignment, idempotent pending ops) are
evaluated per sub-request exactly as in SuggestTrials, so batched and
sequential calls observe identical protocol semantics.

Key semantics reproduced from the paper:
  * client_id trial binding — a SuggestTrials call first returns the caller's
    own ACTIVE trials, so a crashed-and-restarted worker resumes its trial
    (client-side fault tolerance, §5).
  * stalled-trial reassignment — ACTIVE trials bound to a client that has not
    heartbeated within ``reassign_stalled_after`` seconds are re-bound to the
    requesting client (§5 "reassign Trials to other clients to prevent
    stalling").
  * operation persistence + recover_pending_operations() — suggestion work
    interrupted by a server crash restarts on boot (§3.2).
  * Pythia may run in-process or as a separate service (Figure 2) — see
    PythiaConnector implementations.
"""

from __future__ import annotations

import logging
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

from repro.core.metadata import Metadata, MetadataDelta, Namespace
from repro.core.pareto import pareto_frontier_indices
from repro.core.study import (
    Measurement,
    Study,
    StudyState,
    Trial,
    TrialState,
)
from repro.core.study_config import StudyConfig
from repro.pythia.policy import StudyDescriptor, SuggestRequest, EarlyStopRequest
from repro.pythia.registry import make_policy, registered_algorithms
from repro.pythia.supporter import DatastorePolicySupporter, PrefetchedPolicySupporter
from repro.service import chaos
from repro.service import operations as ops_lib
from repro.service._lockwitness import make_lock
from repro.service.datastore import Datastore, KeyAlreadyExistsError, NotFoundError
from repro.service.rpc import Servicer, StatusCode, VizierRpcError

log = logging.getLogger(__name__)

HEARTBEAT_NS = "system.heartbeat"


class PythiaConnector:
    """How the API server reaches the algorithm (same process or remote)."""

    def suggest(self, study: Study, count: int, client_id: str):
        raise NotImplementedError

    def suggest_batch(self, items: "List[tuple]"):
        """items: [(study, count, client_id)] -> per-item (suggestions, delta)
        or the Exception that item raised (per-item fault isolation).

        Default loops over suggest(); InProcessPythia overrides with a
        shared multi-study prefetch so one coalesced dispatch issues O(1)
        datastore queries instead of O(N).
        """
        out = []
        for study, count, client_id in items:
            try:
                out.append(self.suggest(study, count, client_id))
            except Exception as e:  # noqa: BLE001 — isolate per study
                out.append(e)
        return out

    def early_stop(self, study: Study, trial_ids: List[int]):
        raise NotImplementedError


class InProcessPythia(PythiaConnector):
    """Pythia policy in the API-server process (paper: 'can be the same binary')."""

    def __init__(self, datastore: Datastore):
        self._ds = datastore

    def _descriptor(self, study: Study) -> StudyDescriptor:
        return StudyDescriptor(
            config=study.study_config,
            guid=study.name,
            max_trial_id=self._ds.max_trial_id(study.name),
        )

    def suggest(self, study: Study, count: int, client_id: str):
        supporter = DatastorePolicySupporter(self._ds, study.name)
        policy = make_policy(study.study_config.algorithm, supporter, study.study_config)
        request = SuggestRequest(study_descriptor=self._descriptor(study), count=count)
        decision = policy.suggest(request)
        return decision.suggestions, decision.metadata

    def _prefetch_snapshot(self, study_names: List[str]) -> dict:
        """Two multi-study queries (completed + active). A study deleted
        mid-flight must not poison the whole prefetch: fall back to
        per-study reads and let the missing study's own item fail."""
        try:
            completed = self._ds.list_trials_multi(
                study_names, states=[TrialState.COMPLETED])
            active = self._ds.list_trials_multi(
                study_names, states=[TrialState.ACTIVE])
        except NotFoundError:
            completed, active = {}, {}
            for name in study_names:
                try:
                    completed[name] = self._ds.list_trials(
                        name, states=[TrialState.COMPLETED])
                    active[name] = self._ds.list_trials(
                        name, states=[TrialState.ACTIVE])
                except NotFoundError:
                    pass  # absent from the snapshot; its item raises alone
        return {
            name: {
                TrialState.COMPLETED.value: completed[name],
                TrialState.ACTIVE.value: active[name],
            }
            for name in study_names
            if name in completed and name in active
        }

    def suggest_batch(self, items: "List[tuple]"):
        study_names = list({study.name for study, _, _ in items})
        # transfer learning: fold every batched study's prior studies into
        # the same prefetch so the stacked-GP fit reads them from memory (a
        # deleted prior just stays absent; the policy skips it)
        prior_names = []
        for study, _, _ in items:
            for pn in getattr(study.study_config, "prior_study_names", ()):
                if pn not in study_names and pn not in prior_names:
                    prior_names.append(pn)
        # one multi-study query per state the policies read (completed for
        # the regressor fit, active for pending-trial fantasies)
        snapshot = self._prefetch_snapshot(study_names + prior_names)
        out = []
        for study, count, client_id in items:
            try:
                supporter = PrefetchedPolicySupporter(
                    DatastorePolicySupporter(self._ds, study.name), snapshot
                )
                policy = make_policy(
                    study.study_config.algorithm, supporter, study.study_config
                )
                decision = policy.suggest(
                    SuggestRequest(study_descriptor=self._descriptor(study), count=count)
                )
                out.append((decision.suggestions, decision.metadata))
            except Exception as e:  # noqa: BLE001 — isolate per study
                out.append(e)
        return out

    def early_stop(self, study: Study, trial_ids: List[int]):
        supporter = DatastorePolicySupporter(self._ds, study.name)
        policy = make_policy(study.study_config.algorithm, supporter, study.study_config)
        request = EarlyStopRequest(
            study_descriptor=self._descriptor(study), trial_ids=trial_ids
        )
        return policy.early_stop(request).decisions


class RemotePythia(PythiaConnector):
    """Pythia as a separate service reached over RPC (paper Figure 2).

    suggest_batch dispatches the whole coalesced work-list in ONE
    PythiaBatchSuggest frame: the Pythia service loads every study's
    config/trials once (a single GetTrialsMulti(include_studies) frame back
    to the API server) and returns per-item results with isolated errors —
    the same contract as InProcessPythia.suggest_batch, so the coalesced
    operation runner needs no per-backend branching.
    Against an older Pythia binary without the batch method (UNIMPLEMENTED)
    it falls back to the per-study PythiaSuggest loop.
    """

    def __init__(self, rpc_client, *, coalesce: bool = True):
        self._rpc = rpc_client
        self._coalesce = coalesce

    @staticmethod
    def _parse_suggestions(result: dict):
        from repro.core.study import TrialSuggestion

        suggestions = []
        for p in result["suggestions"]:
            t = Trial.from_proto(p)
            suggestions.append(TrialSuggestion(parameters=t.parameters, metadata=t.metadata))
        return suggestions, MetadataDelta.from_proto(result.get("metadata_delta"))

    def suggest(self, study: Study, count: int, client_id: str):
        result = self._rpc.call(
            "PythiaSuggest",
            {"study_name": study.name, "count": count, "client_id": client_id},
            timeout=600.0,
        )
        return self._parse_suggestions(result)

    def suggest_batch(self, items: "List[tuple]"):
        if not items:
            return []
        if not self._coalesce:
            return super().suggest_batch(items)
        requests = [
            {"study_name": study.name, "count": int(count), "client_id": client_id}
            for study, count, client_id in items
        ]
        try:
            result = self._rpc.call(
                "PythiaBatchSuggest", {"requests": requests}, timeout=600.0
            )
        except VizierRpcError as e:
            if e.code != StatusCode.UNIMPLEMENTED:
                raise
            return super().suggest_batch(items)  # pre-batch Pythia binary
        out = []
        for r in result["results"]:
            err = r.get("error")
            if err:
                out.append(VizierRpcError(
                    err.get("code", StatusCode.INTERNAL),
                    err.get("message", "unknown error"),
                ))
            else:
                out.append(self._parse_suggestions(r))
        return out

    def early_stop(self, study: Study, trial_ids: List[int]):
        from repro.pythia.policy import EarlyStopDecision

        result = self._rpc.call(
            "PythiaEarlyStop", {"study_name": study.name, "trial_ids": trial_ids},
            timeout=600.0,
        )
        return [
            EarlyStopDecision(d["trial_id"], d["should_stop"], d.get("reason", ""))
            for d in result["decisions"]
        ]


class VizierService(Servicer):
    #: server-side cap on one WaitOperation park; clients chunk longer waits
    MAX_WAIT_S = 30.0

    def __init__(
        self,
        datastore: Datastore,
        pythia: Optional[PythiaConnector] = None,
        *,
        reassign_stalled_after: Optional[float] = None,
        max_workers: int = 16,
        n_pythia_workers: int = 0,
        n_shards: int = 8,
        lease_timeout: float = 30.0,
    ):
        """``n_pythia_workers`` > 0 switches suggestion execution from the
        direct thread-pool submit to the scale-out tier: ops enqueue on a
        ``n_shards``-way study-sharded work queue and a pool of Pythia
        workers lease per-shard coalesced batches (see ``work_queue``). The
        thread pool remains for early-stopping ops either way."""
        super().__init__()
        self._ds = datastore
        self._pythia = pythia or InProcessPythia(datastore)
        self._reassign_after = reassign_stalled_after
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="pythia")
        self._study_locks: Dict[str, threading.Lock] = {}
        self._locks_guard = make_lock("VizierService._locks_guard")
        # WaitOperation long-poll: op name -> [Event, waiter refcount]
        self._op_waiters: Dict[str, list] = {}
        self._op_waiters_guard = make_lock("VizierService._op_waiters_guard")
        self._queue = None
        self.worker_pool = None
        if n_pythia_workers > 0:
            from repro.service.work_queue import (
                PythiaWorkerPool,
                ShardedWorkQueue,
            )

            self._queue = ShardedWorkQueue(n_shards,
                                           lease_timeout=lease_timeout)
            self.worker_pool = PythiaWorkerPool(
                self._queue,
                self._run_suggest_ops_coalesced,
                self._op_already_done,
                n_workers=n_pythia_workers,
            ).start()
        for method in (
            "CreateStudy", "GetStudy", "ListStudies", "DeleteStudy", "SetStudyState",
            "SuggestTrials", "BatchSuggestTrials", "GetOperation", "WaitOperation",
            "CompleteTrial", "BatchCompleteTrials", "AddTrialMeasurement",
            "GetTrial", "ListTrials", "GetTrialsMulti", "DeleteTrial", "CreateTrial",
            "CheckTrialEarlyStoppingState", "StopTrial", "ListOptimalTrials",
            "UpdateMetadata", "ListAlgorithms", "Ping",
        ):
            self.expose(method, getattr(self, method))

    # -- helpers ---------------------------------------------------------------
    def _study_lock(self, study_name: str) -> threading.Lock:
        with self._locks_guard:
            return self._study_locks.setdefault(
                study_name, make_lock("VizierService._study_lock"))

    def _put_op(self, op: dict) -> None:
        """Single write path for operations: persists, then wakes any
        WaitOperation long-pollers once the op reaches a terminal state."""
        self._ds.put_operation(op)
        if op.get("done"):
            with self._op_waiters_guard:
                entry = self._op_waiters.pop(op["name"], None)
            if entry is not None:
                entry[0].set()

    def _op_already_done(self, op: dict) -> bool:
        """Requeue idempotency: a dead worker may have finished this op."""
        try:
            return bool(self._ds.get_operation(op["name"]).get("done"))
        except NotFoundError:
            return True  # study (and its ops) deleted mid-flight

    def _dispatch_suggest_op(self, op: dict) -> None:
        """Route a runnable suggest op to the worker-pool queue (scale-out)
        or the legacy direct thread-pool dispatch."""
        if self._queue is not None:
            self._queue.enqueue(op)
        else:
            self._pool.submit(self._run_suggest_op, op)

    def _dispatch_suggest_ops(self, ops: List[dict]) -> None:
        if self._queue is not None:
            for op in ops:
                self._queue.enqueue(op)
        else:
            self._pool.submit(self._run_suggest_ops_coalesced, ops)

    def _get_study_or_rpc_error(self, name: str) -> Study:
        try:
            return self._ds.get_study(name)
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, f"study {name!r}") from e

    @staticmethod
    def _parse_trial_name(name: str):
        if "/trials/" not in name:
            raise VizierRpcError(StatusCode.INVALID_ARGUMENT, f"bad trial name {name!r}")
        study_name, trial_id = name.rsplit("/trials/", 1)
        return study_name, int(trial_id)

    def _touch_heartbeat(self, trial: Trial) -> None:
        trial.metadata.abs_ns(Namespace(HEARTBEAT_NS))["t"] = repr(time.time())

    def _heartbeat_of(self, trial: Trial) -> float:
        raw = trial.metadata.abs_ns(Namespace(HEARTBEAT_NS)).get("t")
        if raw is None:
            return trial.creation_time
        try:
            return float(raw if isinstance(raw, str) else raw.decode())
        except ValueError:
            return trial.creation_time

    # -- studies ------------------------------------------------------------------
    def CreateStudy(self, params: dict) -> dict:
        owner = params.get("owner", "default")
        display_name = params.get("display_name") or f"study-{int(time.time()*1e3)}"
        try:
            config = StudyConfig.from_proto(params["study_spec"])
        except (ValueError, KeyError, TypeError) as e:
            # malformed spec (e.g. duplicate metric ids): permanent client
            # error, not a retryable INTERNAL
            raise VizierRpcError(
                StatusCode.INVALID_ARGUMENT,
                f"invalid study_spec: {type(e).__name__}: {e}") from e
        name = f"owners/{owner}/studies/{display_name}"
        study = Study(name=name, display_name=display_name, study_config=config)
        try:
            self._ds.create_study(study)
        except KeyAlreadyExistsError:
            # load-or-create semantics live in the client; Create returns the
            # existing study (idempotent for identical display names).
            study = self._ds.get_study(name)
        return {"study": study.to_proto()}

    def GetStudy(self, params: dict) -> dict:
        return {"study": self._get_study_or_rpc_error(params["name"]).to_proto()}

    def ListStudies(self, params: dict) -> dict:
        prefix = params.get("parent", "")
        return {"studies": [s.to_proto() for s in self._ds.list_studies(prefix)]}

    def DeleteStudy(self, params: dict) -> dict:
        name = params["name"]
        try:
            self._ds.delete_study(name)
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e
        # evict the per-study lock: without this the lock map grows forever
        # under study churn (create/delete workloads leaked one Lock per
        # study for the life of the server)
        with self._locks_guard:
            self._study_locks.pop(name, None)
        return {}

    def SetStudyState(self, params: dict) -> dict:
        # read-modify-write under the study lock: racing a concurrent
        # _apply_delta_locked / UpdateMetadata would resurrect the stale
        # study snapshot and silently drop their writes
        with self._study_lock(params["name"]):
            study = self._get_study_or_rpc_error(params["name"])
            study.state = StudyState(params["state"])
            self._ds.update_study(study)
        return {"study": study.to_proto()}

    # -- suggestion flow -------------------------------------------------------------
    def _prepare_suggest_op(self, study_name: str, client_id: str, count: int):
        """Shared SuggestTrials protocol. Returns (op, needs_computation).

        Fast paths 1-4 return an op that is already done (or already pending
        elsewhere); only path 5 needs a Pythia dispatch. Caller must hold no
        locks; this takes the study lock itself.
        """
        study = self._get_study_or_rpc_error(study_name)

        with self._study_lock(study_name):
            # 1. study no longer active -> empty, done (client loop terminates)
            if study.state != StudyState.ACTIVE:
                op = ops_lib.new_suggest_operation(study_name, client_id, count)
                op = ops_lib.complete_operation(op, {"trials": []})
                self._put_op(op)
                return op, False

            # 2. client already owns ACTIVE trials -> return them immediately
            #    (client-side fault tolerance, paper §5)
            mine = self._ds.list_trials(
                study_name, states=[TrialState.ACTIVE], client_id=client_id
            )
            if mine:
                op = ops_lib.new_suggest_operation(study_name, client_id, count)
                op = ops_lib.complete_operation(
                    op, {"trials": [t.to_proto() for t in mine[:count]]}
                )
                self._put_op(op)
                return op, False

            # 3. reassign stalled trials from dead clients (paper §5)
            if self._reassign_after is not None:
                now = time.time()
                stalled = [
                    t
                    for t in self._ds.list_trials(study_name, states=[TrialState.ACTIVE])
                    if now - self._heartbeat_of(t) > self._reassign_after
                ]
                if stalled:
                    grabbed = []
                    for t in stalled[:count]:
                        t.client_id = client_id
                        self._touch_heartbeat(t)
                        self._ds.update_trial(study_name, t)
                        grabbed.append(t)
                    op = ops_lib.new_suggest_operation(study_name, client_id, count)
                    op = ops_lib.complete_operation(
                        op, {"trials": [t.to_proto() for t in grabbed]}
                    )
                    self._put_op(op)
                    return op, False

            # 4. an identical pending op may already exist (idempotent retry)
            pending = self._ds.list_operations(
                study_name, client_id=client_id, only_pending=True
            )
            for op in pending:
                if op.get("type") == "suggest":
                    return op, False

            # 5. schedule fresh Pythia computation
            op = ops_lib.new_suggest_operation(study_name, client_id, count)
            self._put_op(op)
            return op, True

    def SuggestTrials(self, params: dict) -> dict:
        study_name = params["parent"]
        client_id = params.get("client_id") or "default_client"
        count = int(params.get("suggestion_count", 1))
        op, needs_run = self._prepare_suggest_op(study_name, client_id, count)
        if needs_run:
            self._dispatch_suggest_op(op)
        return {"operation": op}

    def BatchSuggestTrials(self, params: dict) -> dict:
        """N sub-requests -> N operations, at most ONE Pythia dispatch job.

        params: {"requests": [{"parent", "suggestion_count", "client_id"}...]}
        Sub-requests that hit a fast path (own ACTIVE trials, reassignment,
        idempotent retry) complete inline exactly as SuggestTrials would; the
        remainder are coalesced — grouped by study, one policy invocation per
        study with the summed count — into a single pool job. Per-sub-request
        failures (e.g. unknown study) surface as error entries, not a failed
        batch.
        """
        requests = params.get("requests") or []
        operations: List[Optional[dict]] = []
        errors: List[Optional[dict]] = []
        to_run: List[dict] = []
        for r in requests:
            try:
                study_name = r["parent"]
                client_id = r.get("client_id") or "default_client"
                count = int(r.get("suggestion_count", 1))
                op, needs_run = self._prepare_suggest_op(study_name, client_id, count)
            except VizierRpcError as e:
                operations.append(None)
                errors.append({"code": e.code, "message": e.message})
                continue
            except (KeyError, TypeError, ValueError) as e:
                operations.append(None)
                errors.append({
                    "code": StatusCode.INVALID_ARGUMENT,
                    "message": f"malformed sub-request: {type(e).__name__}: {e}",
                })
                continue
            operations.append(op)
            errors.append(None)
            if needs_run:
                to_run.append(op)
        if to_run:
            self._dispatch_suggest_ops(to_run)
        return {"operations": operations, "errors": errors}

    def _apply_delta_locked(self, study_name: str, delta) -> None:
        """Apply policy metadata (algorithm state; paper §6.3). Lock held."""
        if delta is not None and not delta.empty():
            self._ds.apply_metadata_delta(study_name, delta)

    def _create_trials_locked(self, study_name: str, client_id: str,
                              suggestions) -> List[Trial]:
        """Materialize suggestions as ACTIVE trials bound to client. Lock held."""
        trials = []
        for sug in suggestions:
            trial = Trial(
                parameters=sug.parameters,
                metadata=sug.metadata,
                state=TrialState.ACTIVE,
                client_id=client_id,
            )
            self._touch_heartbeat(trial)
            trial = self._ds.create_trial(study_name, trial)
            trials.append(trial)
        return trials

    def _fail_op(self, op: dict, e: Exception) -> None:
        self._put_op(
            ops_lib.fail_operation_from_exception(op, e,
                                                  default_code=StatusCode.INTERNAL)
        )

    def _run_suggest_op(self, op: dict) -> None:
        study_name = op["study_name"]
        client_id = op["client_id"]
        try:
            study = self._ds.get_study(study_name)
            suggestions, delta = self._pythia.suggest(
                study, op["suggestion_count"], client_id
            )
            with self._study_lock(study_name):
                # one durable unit: delta + trials + the done op commit
                # together, so a crash mid-finalize rolls back to a cleanly
                # re-runnable pending op (never trials without their op)
                with self._ds.study_transaction(study_name):
                    self._apply_delta_locked(study_name, delta)
                    trials = self._create_trials_locked(study_name, client_id, suggestions)
                    done = ops_lib.complete_operation(
                        op, {"trials": [t.to_proto() for t in trials]}
                    )
                    self._put_op(done)
        except Exception as e:  # noqa: BLE001 — op must terminate
            log.exception("suggest op %s failed", op["name"])
            self._fail_op(op, e)

    def _run_suggest_ops_coalesced(self, ops: List[dict], op_guard=None) -> None:
        """One job for a whole coalesced dispatch (pool job or worker lease).

        Groups ops by study, asks Pythia for each study's summed count in one
        policy invocation, then splits the suggestion batch across the ops in
        arrival order (each trial bound to its requester's client_id). A
        failed study fails only its own ops.

        ``op_guard`` (worker-pool path): called per op before any state is
        written; returning False means this runner's lease was revoked — the
        op has been requeued to another worker, so a zombie holder must
        neither create trials nor terminate the op. Paired with the
        done-recheck under the study lock, a requeued op is finalized exactly
        once even if the presumed-dead worker is still running.
        """
        by_study: Dict[str, List[dict]] = {}
        for op in ops:
            by_study.setdefault(op["study_name"], []).append(op)

        def fail_group(group, e):
            for op in group:
                if op_guard is not None and not op_guard(op):
                    continue
                self._fail_op(op, e)

        items = []
        for study_name, group in by_study.items():
            try:
                study = self._ds.get_study(study_name)
            except Exception as e:  # noqa: BLE001 — study may be deleted
                fail_group(group, e)
                continue
            total = sum(int(op["suggestion_count"]) for op in group)
            items.append((study, total, group[0]["client_id"]))

        try:
            results = self._pythia.suggest_batch(items)
        except Exception as e:  # noqa: BLE001 — whole dispatch failed
            log.exception("batch suggest dispatch failed")
            for study, _, _ in items:
                fail_group(by_study[study.name], e)
            return

        for (study, _, _), result in zip(items, results):
            group = by_study[study.name]
            if isinstance(result, Exception):
                log.error("batch suggest for %s failed: %s", study.name, result)
                fail_group(group, result)
                continue
            suggestions, delta = result
            shortfalls: List[tuple] = []
            try:
                # injected finalize faults fire before the study lock so a
                # stall here delays, never deadlocks, the finalize path
                chaos.inject("service.finalize", study=study.name)
                with self._study_lock(study.name):
                    if op_guard is not None:
                        # zombie-lease finalize races are settled under the
                        # study lock: drop ops whose lease is gone or that a
                        # successor already finalized
                        group = [op for op in group
                                 if op_guard(op) and not self._op_already_done(op)]
                        if not group:
                            continue
                    # one durable unit per study group: delta + every op's
                    # trials + done markers commit together (see
                    # Datastore.study_transaction)
                    with self._ds.study_transaction(study.name):
                        self._apply_delta_locked(study.name, delta)
                        cursor = 0
                        for op in group:
                            want = int(op["suggestion_count"])
                            take = suggestions[cursor:cursor + want]
                            cursor += len(take)
                            if want and not take:
                                # the policy under-delivered and this op got
                                # nothing: an empty *successful* op would make
                                # the client's suggestion loop terminate, so
                                # fail it (client may retry) instead
                                self._fail_op(op, RuntimeError(
                                    f"policy returned {len(suggestions)} suggestions "
                                    f"for a coalesced request; none left for this op"))
                                continue
                            if len(take) < want:
                                # log outside the study lock (logging does I/O)
                                shortfalls.append((op["name"], len(take), want))
                            trials = self._create_trials_locked(
                                study.name, op["client_id"], take
                            )
                            done = ops_lib.complete_operation(
                                op, {"trials": [t.to_proto() for t in trials]}
                            )
                            self._put_op(done)
            except Exception as e:  # noqa: BLE001 — ops must terminate
                log.exception("batch suggest finalize for %s failed", study.name)
                for op in group:
                    try:
                        if self._ds.get_operation(op["name"]).get("done"):
                            continue
                    except NotFoundError:
                        pass
                    if op_guard is not None and not op_guard(op):
                        continue
                    self._fail_op(op, e)
            for op_name, got, want in shortfalls:
                log.warning("coalesced op %s got %d/%d suggestions",
                            op_name, got, want)

    def GetOperation(self, params: dict) -> dict:
        try:
            return {"operation": self._ds.get_operation(params["name"])}
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e

    def WaitOperation(self, params: dict) -> dict:
        """Long-poll GetOperation: parks the request on a per-op event until
        the op completes or ``timeout_ms`` lapses (capped at MAX_WAIT_S per
        call; clients chunk longer waits), then returns the current op state.
        Completion latency stops being quantized by the client poll/backoff
        ladder — the response leaves the instant the op finishes.
        """
        name = params["name"]
        timeout = min(float(params.get("timeout_ms", 0)) / 1000.0,
                      self.MAX_WAIT_S)
        try:
            op = self._ds.get_operation(name)
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e
        if op.get("done") or timeout <= 0:
            return {"operation": op}
        with self._op_waiters_guard:
            entry = self._op_waiters.setdefault(name, [threading.Event(), 0])
            entry[1] += 1
            event = entry[0]
        try:
            event.wait(timeout)
        finally:
            with self._op_waiters_guard:
                cur = self._op_waiters.get(name)
                if cur is not None and cur[0] is event:
                    cur[1] -= 1
                    if cur[1] <= 0:  # last waiter out evicts the entry
                        del self._op_waiters[name]
        try:
            return {"operation": self._ds.get_operation(name)}
        except NotFoundError as e:  # op's study deleted while parked
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e

    def recover_pending_operations(self) -> int:
        """Re-launches computations for not-done ops (crash recovery, §3.2).

        With the worker pool enabled, recovered suggest ops re-enter the
        sharded queue like fresh ones — same-study ops land on the same
        shard and coalesce into one lease."""
        count = 0
        for study in self._ds.list_studies():
            for op in self._ds.list_operations(study.name, only_pending=True):
                if op.get("type") == "suggest":
                    self._dispatch_suggest_op(op)
                elif op.get("type") == "early_stopping":
                    self._pool.submit(self._run_early_stop_op, op)
                count += 1
        return count

    # -- trial lifecycle -----------------------------------------------------------
    def CreateTrial(self, params: dict) -> dict:
        """Registers a user-provided trial (e.g. known baselines / transfer)."""
        study_name = params["parent"]
        self._get_study_or_rpc_error(study_name)
        trial = Trial.from_proto(params["trial"])
        trial.id = 0  # service assigns ids
        trial = self._ds.create_trial(study_name, trial)
        return {"trial": trial.to_proto()}

    def GetTrial(self, params: dict) -> dict:
        study_name, trial_id = self._parse_trial_name(params["name"])
        try:
            return {"trial": self._ds.get_trial(study_name, trial_id).to_proto()}
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e

    def ListTrials(self, params: dict) -> dict:
        study_name = params["parent"]
        states = [TrialState(s) for s in params.get("states", [])] or None
        try:
            trials = self._ds.list_trials(
                study_name,
                states=states,
                client_id=params.get("client_id"),
                min_trial_id=params.get("min_trial_id"),
            )
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e
        return {"trials": [t.to_proto() for t in trials]}

    def GetTrialsMulti(self, params: dict) -> dict:
        """Many studies' trials in ONE frame (coalesced Pythia prefetch).

        params: {"parents": [study names], "states": [state values]?,
                 "allow_missing": bool?, "include_studies": bool?,
                 "include_priors": bool?}. Strict
        by default (any unknown study is NOT_FOUND, matching ListTrials);
        with allow_missing the unknown names are reported in "missing"
        instead so one deleted study cannot poison a whole batch's prefetch.
        include_studies adds a "studies" map so the coalesced Pythia
        dispatch gets configs + trials for N studies in ONE frame.
        include_priors (requires include_studies) additionally expands each
        requested study's ``prior_study_names`` ONE level deep: the prior
        studies' configs + trials join the same response maps (deleted
        priors land in "missing", never an error), so a transfer-learning
        suggest costs zero extra frames.
        """
        parents = list(params.get("parents") or [])
        states = [TrialState(s) for s in params.get("states", [])] or None
        missing: List[str] = []
        try:
            # raw protos end to end: no Trial materialization server-side
            by_study = self._ds.list_trials_multi_raw(parents, states=states)
        except NotFoundError as e:
            if not params.get("allow_missing"):
                raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e
            by_study = {}
            for name in parents:
                try:
                    by_study[name] = [
                        t.to_proto()
                        for t in self._ds.list_trials(name, states=states)
                    ]
                except NotFoundError:
                    missing.append(name)
        result: dict = {"trials_by_study": by_study, "missing": missing}
        if params.get("include_studies"):
            studies = {}
            for name in list(by_study):
                try:
                    studies[name] = self._ds.get_study(name).to_proto()
                except NotFoundError:  # deleted between the two reads
                    del by_study[name]
                    missing.append(name)
            if params.get("include_priors"):
                # one-level transfer expansion (priors' own priors are NOT
                # chased): a deleted prior is reported, never a failure
                prior_names: List[str] = []
                for sproto in studies.values():
                    spec = sproto.get("study_spec") or {}
                    for pn in spec.get("prior_study_names", ()):
                        if pn not in by_study and pn not in prior_names \
                                and pn not in missing:
                            prior_names.append(pn)
                for pn in prior_names:
                    try:
                        study_proto = self._ds.get_study(pn).to_proto()
                        trials = self._ds.list_trials_multi_raw(
                            [pn], states=states)[pn]
                    except NotFoundError:
                        missing.append(pn)
                        continue
                    studies[pn] = study_proto
                    by_study[pn] = trials
            result["studies"] = studies
        return result

    def AddTrialMeasurement(self, params: dict) -> dict:
        """Intermediate measurement — also acts as the client heartbeat."""
        study_name, trial_id = self._parse_trial_name(params["trial_name"])
        measurement = Measurement.from_proto(params["measurement"])
        with self._study_lock(study_name):
            trial = self._ds.get_trial(study_name, trial_id)
            if trial.state.is_terminal:
                raise VizierRpcError(
                    StatusCode.FAILED_PRECONDITION, f"trial {trial_id} already terminal"
                )
            trial.add_measurement(measurement)
            self._touch_heartbeat(trial)
            self._ds.update_trial(study_name, trial)
        return {"trial": trial.to_proto()}

    def CompleteTrial(self, params: dict) -> dict:
        study_name, trial_id = self._parse_trial_name(params["name"])
        with self._study_lock(study_name):
            trial = self._complete_trial_locked(study_name, trial_id, params)
        return {"trial": trial.to_proto()}

    def _complete_trial_locked(self, study_name: str, trial_id: int,
                               params: dict) -> Trial:
        trial = self._ds.get_trial(study_name, trial_id)
        if trial.state.is_terminal:
            raise VizierRpcError(
                StatusCode.FAILED_PRECONDITION, f"trial {trial_id} already terminal"
            )
        if params.get("trial_infeasible"):
            trial.complete(
                infeasibility_reason=params.get("infeasible_reason", "infeasible")
            )
        else:
            fm = Measurement.from_proto(params.get("final_measurement"))
            if fm is None:
                # fall back to the last intermediate measurement
                if not trial.measurements:
                    raise VizierRpcError(
                        StatusCode.INVALID_ARGUMENT,
                        "no final_measurement and no intermediate measurements",
                    )
                fm = trial.measurements[-1]
            trial.complete(fm)
        self._ds.update_trial(study_name, trial)
        return trial

    def BatchCompleteTrials(self, params: dict) -> dict:
        """N CompleteTrial sub-requests in one round trip.

        params: {"requests": [CompleteTrial params...]}. Returns parallel
        "trials"/"errors" lists — a failed completion (unknown trial, already
        terminal) yields an error entry without failing its siblings.
        """
        trials: List[Optional[dict]] = []
        errors: List[Optional[dict]] = []
        for r in params.get("requests") or []:
            try:
                study_name, trial_id = self._parse_trial_name(r["name"])
                with self._study_lock(study_name):
                    trial = self._complete_trial_locked(study_name, trial_id, r)
                trials.append(trial.to_proto())
                errors.append(None)
            except VizierRpcError as e:
                trials.append(None)
                errors.append({"code": e.code, "message": e.message})
            except NotFoundError as e:
                trials.append(None)
                errors.append({"code": StatusCode.NOT_FOUND, "message": str(e)})
            except (KeyError, TypeError, ValueError) as e:
                trials.append(None)
                errors.append({
                    "code": StatusCode.INVALID_ARGUMENT,
                    "message": f"malformed sub-request: {type(e).__name__}: {e}",
                })
        return {"trials": trials, "errors": errors}

    def DeleteTrial(self, params: dict) -> dict:
        study_name, trial_id = self._parse_trial_name(params["name"])
        try:
            self._ds.delete_trial(study_name, trial_id)
        except NotFoundError as e:
            raise VizierRpcError(StatusCode.NOT_FOUND, str(e)) from e
        return {}

    def StopTrial(self, params: dict) -> dict:
        study_name, trial_id = self._parse_trial_name(params["name"])
        with self._study_lock(study_name):
            trial = self._ds.get_trial(study_name, trial_id)
            if not trial.state.is_terminal:
                trial.state = TrialState.STOPPING
                self._ds.update_trial(study_name, trial)
        return {"trial": trial.to_proto()}

    # -- early stopping ----------------------------------------------------------------
    def CheckTrialEarlyStoppingState(self, params: dict) -> dict:
        study_name, trial_id = self._parse_trial_name(params["trial_name"])
        self._get_study_or_rpc_error(study_name)
        op = ops_lib.new_early_stopping_operation(study_name, trial_id)
        self._put_op(op)
        self._pool.submit(self._run_early_stop_op, op)
        return {"operation": op}

    def _run_early_stop_op(self, op: dict) -> None:
        try:
            study = self._ds.get_study(op["study_name"])
            decisions = self._pythia.early_stop(study, [op["trial_id"]])
            should_stop = any(d.should_stop for d in decisions)
            if should_stop:
                with self._study_lock(op["study_name"]):
                    trial = self._ds.get_trial(op["study_name"], op["trial_id"])
                    if not trial.state.is_terminal:
                        trial.state = TrialState.STOPPING
                        self._ds.update_trial(op["study_name"], trial)
            self._put_op(
                ops_lib.complete_operation(op, {"should_stop": bool(should_stop)})
            )
        except Exception as e:  # noqa: BLE001
            log.exception("early-stop op %s failed", op["name"])
            # _fail_op maps the carried code (e.g. PolicyConstructionError ->
            # INVALID_ARGUMENT); hard-coding INTERNAL here made permanent
            # policy-construction failures look retryable
            self._fail_op(op, e)

    # -- optimal trials / metadata ---------------------------------------------------
    def ListOptimalTrials(self, params: dict) -> dict:
        study_name = params["parent"]
        study = self._get_study_or_rpc_error(study_name)
        config: StudyConfig = study.study_config
        completed = self._ds.list_trials(study_name, states=[TrialState.COMPLETED])
        ys, keep = [], []
        for t in completed:
            obj = config.objective_values(t)
            if obj is not None:
                ys.append(obj)
                keep.append(t)
        if not ys:
            return {"optimal_trials": []}
        idx = pareto_frontier_indices(ys)
        return {"optimal_trials": [keep[i].to_proto() for i in idx]}

    def UpdateMetadata(self, params: dict) -> dict:
        study_name = params["name"]
        delta = MetadataDelta.from_proto(params["delta"])
        # the study lock orders this against SetStudyState's read-modify-
        # write (backend atomicity alone can't stop a stale study snapshot
        # from overwriting the delta); per-trial entries naming deleted
        # trials are skipped instead of failing a half-applied delta, and
        # the skipped ids are reported so callers can detect stale targets
        with self._study_lock(study_name):
            self._get_study_or_rpc_error(study_name)
            skipped = self._ds.apply_metadata_delta(study_name, delta)
        return {"skipped_trials": skipped}

    def ListAlgorithms(self, params: dict) -> dict:
        return {"algorithms": registered_algorithms()}

    def Ping(self, params: dict) -> dict:
        return {"time": time.time()}

    def shutdown(self) -> None:
        if self.worker_pool is not None:
            self.worker_pool.shutdown()
        self._pool.shutdown(wait=False, cancel_futures=True)
