"""Deterministic chaos injection for the service tier (paper §3.2, §5).

The paper's reliability claims — worker death, client disconnects, server
restarts — are pinned by hand-scripted kill tests elsewhere; this module
turns them into *seeded, declarative* fault schedules so a failure found at
seed N replays exactly at seed N. It is a harness, not a production feature:
every hook is a no-op unless an injector is installed (``CHAOS_SEED`` in the
environment, or :func:`scenario` in a test), and the archlint
``chaos-ungated-hook`` rule pins the early-return guard that keeps the hooks
dead code in normal operation.

Injection sites (where production code calls :func:`inject`):

========================  ====================================================
site                      seam
========================  ====================================================
``transport.send``        before a frame (or pipelined batch) is written
``transport.recv``        before each response frame is read (ctx: ``index``)
``datastore.<method>``    every public Datastore call (via ``wrap_datastore``)
``queue.lease``           after a shard lease is granted (ctx: ``lease``)
``queue.ack``             before a worker acks its lease (ctx: ``kill``)
``worker.batch``          before a worker dispatches a leased batch
``service.finalize``      before a coalesced batch takes the study lock
========================  ====================================================

Fault kinds:

``delay``/``stall``  sleep ``delay_s`` (a slow link / slow disk)
``sever``            raise ConnectionError — at ``transport.send`` the server
                     never sees the request
``drop``             raise ConnectionError — at ``transport.recv`` the server
                     *did* apply the request but the response is lost (the
                     non-idempotent-resend hazard)
``error``            raise :class:`ChaosError` carrying a status ``code``
                     (duck-typed like VizierRpcError, so error discipline
                     maps it end to end). Use at datastore/queue/service
                     seams — the transport seams promise VizierRpcError to
                     their callers, so inject ``sever``/``drop`` there
                     instead
``expire_lease``     zero the granted lease's deadline: the next queue scan
                     reclaims and requeues it under the current holder
``kill_worker``      invoke the seam's ``kill`` callback — the worker thread
                     dies as if crashed (no ack, no reclaim of its own)
``corrupt``          scramble every ``repro.gp_bandit`` state value in the
                     metadata/delta about to be written (the policy must
                     treat it as a cold start, never fail the op)

Reproducibility: each fault gets its own ``random.Random`` stream derived
from ``(seed, fault index)`` and its own matched-event counter, so firing
decisions depend only on the per-site event order — not on wall-clock time
or interleaving with other sites.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import random
import threading
import time
from typing import Any, Dict, List, Optional

from repro.service._lockwitness import make_lock

_UNAVAILABLE = 14  # StatusCode.UNAVAILABLE (duck-typed; no rpc import cycle)

# value written over repro.gp_bandit state by the ``corrupt`` kind — not
# valid msgpack/JSON, so every schema-versioned loader rejects it
_CORRUPT_BLOB = b"\x00chaos-corrupted\x00"
_STATE_NS_FRAGMENT = "gp_bandit"


class ChaosError(Exception):
    """An injected failure. Carries ``code``/``message`` like VizierRpcError
    so ``Servicer.dispatch`` and ``fail_operation_from_exception`` surface a
    real status code, per the error-discipline invariant."""

    def __init__(self, code: int, message: str):
        super().__init__(f"[code={code}] {message}")
        self.code = code
        self.message = message


@dataclasses.dataclass
class Fault:
    """One declarative fault. ``site`` is exact or a ``prefix.*`` glob."""

    site: str
    kind: str
    prob: float = 1.0      # per-matching-event firing probability
    after: int = 0         # skip the first N matching events
    times: int = 1         # fire at most this many times
    delay_s: float = 0.05  # delay/stall sleep
    code: int = _UNAVAILABLE

    def matches(self, site: str) -> bool:
        if self.site.endswith(".*"):
            return site.startswith(self.site[:-1]) or site == self.site[:-2]
        return site == self.site


class FaultInjector:
    """Seeded schedule evaluator. ``fire`` is called from every hook site;
    counter bookkeeping happens under a lock, fault *actions* (sleeps,
    raises, mutations) strictly after it is released."""

    def __init__(self, seed: int, faults: List[Fault]):
        self.seed = int(seed)
        self.faults = list(faults)
        self._lock = make_lock("FaultInjector._lock")
        self._seen = [0] * len(self.faults)
        self._fired = [0] * len(self.faults)
        # independent stream per fault: decisions for fault i are a pure
        # function of (seed, i, per-fault event index)
        self._rngs = [random.Random((self.seed << 8) ^ i)
                      for i in range(len(self.faults))]
        self.events: List[tuple] = []  # (site, kind, event index), bounded

    def fired_count(self, site_prefix: str = "") -> int:
        with self._lock:
            return sum(
                fired for fault, fired in zip(self.faults, self._fired)
                if fault.site.startswith(site_prefix))

    def fire(self, site: str, ctx: Dict[str, Any]) -> None:
        actions: List[Fault] = []
        with self._lock:
            for i, fault in enumerate(self.faults):
                if not fault.matches(site):
                    continue
                n = self._seen[i]
                self._seen[i] += 1
                if n < fault.after or self._fired[i] >= fault.times:
                    continue
                if fault.prob < 1.0 and self._rngs[i].random() > fault.prob:
                    continue
                self._fired[i] += 1
                if len(self.events) < 10_000:
                    self.events.append((site, fault.kind, n))
                actions.append(fault)
        # non-raising effects first, then the first raising fault wins
        raising: Optional[Fault] = None
        for fault in actions:
            kind = fault.kind
            if kind in ("delay", "stall"):
                time.sleep(fault.delay_s)
            elif kind == "expire_lease":
                lease = ctx.get("lease")
                if lease is not None:
                    lease.deadline = time.monotonic() - 1.0
            elif kind == "kill_worker":
                kill = ctx.get("kill")
                if kill is not None:
                    kill()
            elif kind == "corrupt":
                _corrupt_state(ctx)
            elif raising is None:
                raising = fault
        if raising is not None:
            if raising.kind in ("sever", "drop"):
                raise ConnectionError(
                    f"chaos: {raising.kind} at {site} (seed {self.seed})")
            raise ChaosError(
                raising.code, f"chaos: injected {raising.kind} at {site} "
                              f"(seed {self.seed})")


def _corrupt_state(ctx: Dict[str, Any]) -> None:
    """Overwrite repro.gp_bandit state values in a Metadata/MetadataDelta
    about to be persisted. Reaches into the metadata store directly: the
    corruption must bypass every API-level validation, exactly like a torn
    write on disk would."""
    stores = []
    delta = ctx.get("delta")
    if delta is not None:
        stores.append(delta.on_study._store)
        stores.extend(md._store for md in delta.on_trials.values())
    metadata = ctx.get("metadata")
    if metadata is not None:
        stores.append(metadata._store)
    for store in stores:
        for ns_key, bucket in store.items():
            if _STATE_NS_FRAGMENT in ns_key:
                for key in bucket:
                    bucket[key] = _CORRUPT_BLOB


# ---------------------------------------------------------------------------
# Module-level installation (the hooks production code calls)
# ---------------------------------------------------------------------------

_injector: Optional[FaultInjector] = None
_install_lock = threading.Lock()


def active() -> bool:
    return _injector is not None


def current() -> Optional[FaultInjector]:
    return _injector


def inject(site: str, **ctx: Any) -> None:
    """The hook. MUST stay a no-op when no injector is installed — the
    archlint ``chaos-ungated-hook`` rule pins this guard."""
    if _injector is None:
        return
    _injector.fire(site, ctx)


def install(seed: int, faults: List[Fault]) -> FaultInjector:
    global _injector
    with _install_lock:
        inj = FaultInjector(seed, faults)
        _injector = inj
        return inj


def uninstall() -> None:
    global _injector
    with _install_lock:
        _injector = None


@contextlib.contextmanager
def scenario(seed: int, faults: List[Fault]):
    """Install a schedule for the duration of a with-block (test harness)."""
    inj = install(seed, faults)
    try:
        yield inj
    finally:
        uninstall()


#: schedule used when only CHAOS_SEED is set: a mild mixed storm across
#: every seam, probabilistic so different seeds exercise different traces
DEFAULT_SCHEDULE = [
    Fault(site="transport.send", kind="sever", prob=0.05, times=10),
    Fault(site="transport.recv", kind="drop", prob=0.05, times=10),
    Fault(site="datastore.*", kind="stall", prob=0.02, times=20,
          delay_s=0.02),
    Fault(site="queue.lease", kind="expire_lease", prob=0.1, times=5),
    Fault(site="service.finalize", kind="delay", prob=0.1, times=5,
          delay_s=0.05),
]


def install_from_env() -> Optional[FaultInjector]:
    """Install from ``CHAOS_SEED`` (+ optional ``CHAOS_SCHEDULE`` JSON list
    of Fault kwargs). No-op when unset or when an injector already exists
    (a scenario() in a test wins over the env)."""
    seed_raw = os.environ.get("CHAOS_SEED")
    if not seed_raw or active():
        return _injector
    raw = os.environ.get("CHAOS_SCHEDULE")
    faults = ([Fault(**spec) for spec in json.loads(raw)]
              if raw else list(DEFAULT_SCHEDULE))
    return install(int(seed_raw), faults)


# ---------------------------------------------------------------------------
# Datastore seam
# ---------------------------------------------------------------------------


class ChaosDatastore:
    """Fault-injecting Datastore proxy.

    Installed only while chaos is active (see :func:`wrap_datastore`), so
    the production datastores carry no chaos code at all. Every public
    method call fires ``datastore.<method>`` before delegating; the
    metadata-writing methods also expose their payload so the ``corrupt``
    kind can scramble ``repro.gp_bandit`` state in flight.
    """

    def __init__(self, inner: Any):
        self._inner = inner

    @property
    def wrapped(self) -> Any:
        return self._inner

    def __getattr__(self, name: str) -> Any:
        attr = getattr(self._inner, name)
        if name.startswith("_") or not callable(attr):
            return attr

        def hooked(*args: Any, **kwargs: Any) -> Any:
            ctx: Dict[str, Any] = {"method": name}
            if name == "apply_metadata_delta" and len(args) >= 2:
                ctx["delta"] = args[1]
            elif name == "update_study_metadata" and len(args) >= 2:
                ctx["metadata"] = args[1]
            inject(f"datastore.{name}", **ctx)
            return attr(*args, **kwargs)

        hooked.__name__ = name
        return hooked


def wrap_datastore(ds: Any) -> Any:
    """Return ``ds`` untouched when chaos is off; the injecting proxy when
    on. Servers call this once at construction."""
    if not active():
        return ds
    return ChaosDatastore(ds)
