"""VizierClient — the user API (paper §5, Code Block 1).

    client = VizierClient.load_or_create_study(
        'cifar10', config, client_id=sys.argv[1], target=address)
    while suggestions := client.get_suggestions(count=1):
        for trial in suggestions:
            metrics = evaluate(trial.parameters)
            client.complete_trial(metrics, trial_id=trial.id)

The client hides the SuggestTrials -> GetOperation polling loop, retries
transport failures, and (by re-using its client_id) resumes its own ACTIVE
trials after a crash.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Union

from repro.core.metadata import Metadata
from repro.core.study import Measurement, Study, StudyState, Trial, TrialState
from repro.core.study_config import StudyConfig
from repro.service.rpc import RpcClient, StatusCode, VizierRpcError


class OperationFailedError(Exception):
    pass


class VizierClient:
    def __init__(
        self,
        target,
        study_name: str,
        client_id: str,
        *,
        poll_interval: float = 0.02,
        poll_backoff: float = 1.3,
        max_poll_interval: float = 2.0,
    ):
        self._rpc = RpcClient(target)
        self._study_name = study_name
        self._client_id = client_id
        self._poll = (poll_interval, poll_backoff, max_poll_interval)

    # -- construction -------------------------------------------------------------
    @classmethod
    def load_or_create_study(
        cls,
        display_name: str,
        study_config: Optional[StudyConfig] = None,
        *,
        client_id: str,
        target,
        owner: str = "default",
        **kwargs,
    ) -> "VizierClient":
        rpc = RpcClient(target)
        name = f"owners/{owner}/studies/{display_name}"
        try:
            rpc.call("GetStudy", {"name": name})
        except VizierRpcError as e:
            if e.code != StatusCode.NOT_FOUND:
                raise
            if study_config is None:
                raise ValueError(
                    f"study {name!r} does not exist and no study_config given"
                ) from e
            rpc.call(
                "CreateStudy",
                {
                    "owner": owner,
                    "display_name": display_name,
                    "study_spec": study_config.to_proto(),
                },
            )
        rpc.close()
        return cls(target, name, client_id, **kwargs)

    @property
    def study_name(self) -> str:
        return self._study_name

    @property
    def client_id(self) -> str:
        return self._client_id

    # -- suggestion loop -------------------------------------------------------------
    def get_suggestions(self, count: int = 1, *, timeout: float = 600.0) -> List[Trial]:
        """SuggestTrials + GetOperation polling until the batch is ready."""
        result = self._rpc.call(
            "SuggestTrials",
            {
                "parent": self._study_name,
                "suggestion_count": count,
                "client_id": self._client_id,
            },
        )
        op = result["operation"]
        op = self._await_operation(op, timeout=timeout)
        return [Trial.from_proto(p) for p in (op.get("result") or {}).get("trials", [])]

    def _await_operation(self, op: dict, *, timeout: float) -> dict:
        interval, backoff, max_interval = self._poll
        deadline = time.monotonic() + timeout
        while not op.get("done"):
            if time.monotonic() > deadline:
                raise OperationFailedError(f"operation {op['name']} timed out")
            time.sleep(interval)
            interval = min(interval * backoff, max_interval)
            op = self._rpc.call("GetOperation", {"name": op["name"]})["operation"]
        if op.get("error"):
            raise OperationFailedError(
                f"operation {op['name']}: {op['error'].get('message')}"
            )
        return op

    # -- reporting ---------------------------------------------------------------------
    def _trial_name(self, trial_id: int) -> str:
        return f"{self._study_name}/trials/{trial_id}"

    def report_intermediate_objective_value(
        self,
        metrics: Dict[str, float],
        *,
        trial_id: int,
        step: int,
        elapsed_secs: float = 0.0,
    ) -> Trial:
        m = Measurement(metrics=metrics, steps=step, elapsed_secs=elapsed_secs)
        result = self._rpc.call(
            "AddTrialMeasurement",
            {"trial_name": self._trial_name(trial_id), "measurement": m.to_proto()},
        )
        return Trial.from_proto(result["trial"])

    def complete_trial(
        self,
        metrics: Union[Dict[str, float], Measurement, None] = None,
        *,
        trial_id: int,
        infeasibility_reason: Optional[str] = None,
        elapsed_secs: float = 0.0,
    ) -> Trial:
        params: dict = {"name": self._trial_name(trial_id)}
        if infeasibility_reason is not None:
            params["trial_infeasible"] = True
            params["infeasible_reason"] = infeasibility_reason
        elif metrics is not None:
            m = (
                metrics
                if isinstance(metrics, Measurement)
                else Measurement(metrics=metrics, elapsed_secs=elapsed_secs)
            )
            params["final_measurement"] = m.to_proto()
        result = self._rpc.call("CompleteTrial", params)
        return Trial.from_proto(result["trial"])

    # -- early stopping -------------------------------------------------------------------
    def should_trial_stop(self, trial_id: int, *, timeout: float = 120.0) -> bool:
        result = self._rpc.call(
            "CheckTrialEarlyStoppingState", {"trial_name": self._trial_name(trial_id)}
        )
        op = self._await_operation(result["operation"], timeout=timeout)
        return bool((op.get("result") or {}).get("should_stop", False))

    # -- reads -------------------------------------------------------------------------------
    def get_study_config(self) -> StudyConfig:
        result = self._rpc.call("GetStudy", {"name": self._study_name})
        return StudyConfig.from_proto(result["study"]["study_spec"])

    def get_trial(self, trial_id: int) -> Trial:
        result = self._rpc.call("GetTrial", {"name": self._trial_name(trial_id)})
        return Trial.from_proto(result["trial"])

    def list_trials(self, states: Optional[List[TrialState]] = None) -> List[Trial]:
        params: dict = {"parent": self._study_name}
        if states:
            params["states"] = [s.value for s in states]
        result = self._rpc.call("ListTrials", params)
        return [Trial.from_proto(p) for p in result["trials"]]

    def list_optimal_trials(self) -> List[Trial]:
        result = self._rpc.call("ListOptimalTrials", {"parent": self._study_name})
        return [Trial.from_proto(p) for p in result["optimal_trials"]]

    def add_trial(self, trial: Trial) -> Trial:
        """Registers a pre-evaluated trial (baseline / transfer learning)."""
        result = self._rpc.call(
            "CreateTrial", {"parent": self._study_name, "trial": trial.to_proto()}
        )
        return Trial.from_proto(result["trial"])

    def set_study_state(self, state: StudyState) -> None:
        self._rpc.call("SetStudyState", {"name": self._study_name, "state": state.value})

    def delete_study(self) -> None:
        self._rpc.call("DeleteStudy", {"name": self._study_name})

    def close(self) -> None:
        self._rpc.close()
