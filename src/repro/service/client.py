"""VizierClient — the user API (paper §5, Code Block 1).

    client = VizierClient.load_or_create_study(
        'cifar10', config, client_id=sys.argv[1], target=address)
    while suggestions := client.get_suggestions(count=1):
        for trial in suggestions:
            metrics = evaluate(trial.parameters)
            client.complete_trial(metrics, trial_id=trial.id)

The client hides the SuggestTrials -> WaitOperation long-poll loop (degrading
to GetOperation polling on servers without WaitOperation), retries transport
failures, and (by re-using its client_id) resumes its own ACTIVE trials after
a crash.

Batched suggestions: ``VizierBatchClient`` fans many (study, client) pairs'
suggestion requests into one BatchSuggestTrials RPC (one server-side Pythia
dispatch), parks a WaitOperation long-poll on the first pending op, and
sweeps the rest with pipelined GetOperation frames — the high-throughput
path for schedulers driving many studies.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Union

from repro.core.metadata import Metadata, MetadataDelta
from repro.core.study import Measurement, Study, StudyState, Trial, TrialState
from repro.core.study_config import StudyConfig
from repro.service.rpc import RpcClient, StatusCode, VizierRpcError


class OperationFailedError(Exception):
    """A long-running operation failed or timed out.

    Carries the server's structured error, not just its message:
    ``code`` is the RPC StatusCode (DEADLINE_EXCEEDED for client-side
    timeouts), ``operation_name`` the op that failed — so schedulers can
    distinguish a retryable UNAVAILABLE from a permanent INVALID_ARGUMENT
    without parsing strings.
    """

    def __init__(self, message: str, *, code: Optional[int] = None,
                 operation_name: Optional[str] = None):
        super().__init__(message)
        self.code = code if code is not None else StatusCode.INTERNAL
        self.operation_name = operation_name


#: one WaitOperation park per round trip; longer client deadlines chunk
_WAIT_CHUNK_S = 10.0
#: transport deadline slack over the server-side wait park
_WAIT_RPC_SLACK_S = 5.0


class VizierClient:
    def __init__(
        self,
        target,
        study_name: str,
        client_id: str,
        *,
        poll_interval: float = 0.02,
        poll_backoff: float = 1.3,
        max_poll_interval: float = 2.0,
        long_poll: bool = True,
    ):
        """``long_poll=True`` awaits operations via the WaitOperation RPC
        (server parks the request until the op completes — latency is no
        longer quantized by the poll/backoff ladder), degrading permanently
        to the classic GetOperation polling loop if the server predates
        WaitOperation (UNIMPLEMENTED)."""
        self._rpc = RpcClient(target)
        self._study_name = study_name
        self._client_id = client_id
        self._poll = (poll_interval, poll_backoff, max_poll_interval)
        # None = probe on first use; False is sticky after UNIMPLEMENTED
        self._long_poll: Optional[bool] = None if long_poll else False

    # -- construction -------------------------------------------------------------
    @classmethod
    def load_or_create_study(
        cls,
        display_name: str,
        study_config: Optional[StudyConfig] = None,
        *,
        client_id: str,
        target,
        owner: str = "default",
        prior_studies: Optional[List[str]] = None,
        **kwargs,
    ) -> "VizierClient":
        """``prior_studies`` (transfer learning): resource names of earlier
        studies — e.g. ``other_client.study_name`` — whose completed trials
        warm the GP-bandit as a stacked residual prior. Earlier names sit
        deeper in the stack. Only applies when the study is created here; a
        prior study deleted later silently degrades to a cold fit."""
        rpc = RpcClient(target)
        name = f"owners/{owner}/studies/{display_name}"
        try:
            rpc.call("GetStudy", {"name": name})
        except VizierRpcError as e:
            if e.code != StatusCode.NOT_FOUND:
                raise
            if study_config is None:
                raise ValueError(
                    f"study {name!r} does not exist and no study_config given"
                ) from e
            if prior_studies is not None:
                study_config.prior_studies = list(prior_studies)
            rpc.call(
                "CreateStudy",
                {
                    "owner": owner,
                    "display_name": display_name,
                    "study_spec": study_config.to_proto(),
                },
            )
        rpc.close()
        return cls(target, name, client_id, **kwargs)

    @property
    def study_name(self) -> str:
        return self._study_name

    @property
    def client_id(self) -> str:
        return self._client_id

    # -- suggestion loop -------------------------------------------------------------
    def get_suggestions(self, count: int = 1, *, timeout: float = 600.0) -> List[Trial]:
        """SuggestTrials + WaitOperation long-poll until the batch is ready."""
        result = self._rpc.call(
            "SuggestTrials",
            {
                "parent": self._study_name,
                "suggestion_count": count,
                "client_id": self._client_id,
            },
        )
        op = result["operation"]
        op = self._await_operation(op, timeout=timeout)
        return [Trial.from_proto(p) for p in (op.get("result") or {}).get("trials", [])]

    def _await_operation(self, op: dict, *, timeout: float) -> dict:
        deadline = time.monotonic() + timeout
        op = self._wait_until_done(op, deadline)
        if not op.get("done"):
            # the op is NOT abandoned server-side: it stays pending and a
            # later GetOperation (or recovery) still finds/completes it
            raise OperationFailedError(
                f"operation {op['name']} timed out after {timeout:.3f}s",
                code=StatusCode.DEADLINE_EXCEEDED,
                operation_name=op["name"],
            )
        if op.get("error"):
            err = op["error"]
            raise OperationFailedError(
                f"operation {op['name']}: {err.get('message')}",
                code=err.get("code"),
                operation_name=op["name"],
            )
        return op

    def _wait_until_done(self, op: dict, deadline: float) -> dict:
        """Blocks until the op is done or the deadline lapses (returns the
        last-seen op either way; the caller decides whether to raise)."""
        while not op.get("done") and self._long_poll is not False:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return op
            chunk = min(remaining, _WAIT_CHUNK_S)
            try:
                op = self._rpc.call(
                    "WaitOperation",
                    {"name": op["name"], "timeout_ms": int(chunk * 1000)},
                    timeout=chunk + _WAIT_RPC_SLACK_S,
                )["operation"]
                self._long_poll = True
            except VizierRpcError as e:
                if e.code != StatusCode.UNIMPLEMENTED:
                    raise
                self._long_poll = False  # old server: degrade permanently
        interval, backoff, max_interval = self._poll
        while not op.get("done"):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return op
            time.sleep(min(interval, remaining))
            interval = min(interval * backoff, max_interval)
            op = self._rpc.call("GetOperation", {"name": op["name"]})["operation"]
        return op

    # -- reporting ---------------------------------------------------------------------
    def _trial_name(self, trial_id: int) -> str:
        return f"{self._study_name}/trials/{trial_id}"

    def report_intermediate_objective_value(
        self,
        metrics: Dict[str, float],
        *,
        trial_id: int,
        step: int,
        elapsed_secs: float = 0.0,
    ) -> Trial:
        m = Measurement(metrics=metrics, steps=step, elapsed_secs=elapsed_secs)
        result = self._rpc.call(
            "AddTrialMeasurement",
            {"trial_name": self._trial_name(trial_id), "measurement": m.to_proto()},
        )
        return Trial.from_proto(result["trial"])

    def complete_trial(
        self,
        metrics: Union[Dict[str, float], Measurement, None] = None,
        *,
        trial_id: int,
        infeasibility_reason: Optional[str] = None,
        elapsed_secs: float = 0.0,
    ) -> Trial:
        params: dict = {"name": self._trial_name(trial_id)}
        if infeasibility_reason is not None:
            params["trial_infeasible"] = True
            params["infeasible_reason"] = infeasibility_reason
        elif metrics is not None:
            m = (
                metrics
                if isinstance(metrics, Measurement)
                else Measurement(metrics=metrics, elapsed_secs=elapsed_secs)
            )
            params["final_measurement"] = m.to_proto()
        result = self._rpc.call("CompleteTrial", params)
        return Trial.from_proto(result["trial"])

    # -- early stopping -------------------------------------------------------------------
    def should_trial_stop(self, trial_id: int, *, timeout: float = 120.0) -> bool:
        result = self._rpc.call(
            "CheckTrialEarlyStoppingState", {"trial_name": self._trial_name(trial_id)}
        )
        op = self._await_operation(result["operation"], timeout=timeout)
        return bool((op.get("result") or {}).get("should_stop", False))

    # -- metadata ----------------------------------------------------------------------------
    def update_metadata(self, delta: "MetadataDelta") -> List[int]:
        """Pushes a MetadataDelta (study and/or per-trial) to the service.

        Returns the trial ids whose per-trial updates were skipped because
        the trial no longer exists (the study-level half still applies).
        Namespaces starting with ``repro.`` are reserved for algorithm state
        (e.g. the GP-bandit's warm-start checkpoint); writing them from user
        code risks corrupting policy state — which the policies tolerate (a
        bad blob degrades to a cold fit) but callers should not rely on.
        """
        result = self._rpc.call(
            "UpdateMetadata",
            {"name": self._study_name, "delta": delta.to_proto()},
        )
        return [int(t) for t in result.get("skipped_trials") or []]

    def get_study_metadata(self) -> Metadata:
        """The study-level metadata, including persisted algorithm state."""
        return self.get_study_config().metadata

    # -- reads -------------------------------------------------------------------------------
    def get_study_config(self) -> StudyConfig:
        result = self._rpc.call("GetStudy", {"name": self._study_name})
        return StudyConfig.from_proto(result["study"]["study_spec"])

    def get_trial(self, trial_id: int) -> Trial:
        result = self._rpc.call("GetTrial", {"name": self._trial_name(trial_id)})
        return Trial.from_proto(result["trial"])

    def list_trials(self, states: Optional[List[TrialState]] = None) -> List[Trial]:
        params: dict = {"parent": self._study_name}
        if states:
            params["states"] = [s.value for s in states]
        result = self._rpc.call("ListTrials", params)
        return [Trial.from_proto(p) for p in result["trials"]]

    def list_optimal_trials(self) -> List[Trial]:
        result = self._rpc.call("ListOptimalTrials", {"parent": self._study_name})
        return [Trial.from_proto(p) for p in result["optimal_trials"]]

    def pareto_frontier(self) -> "tuple[List[Trial], List[List[float]]]":
        """(frontier trials, their larger-is-better objective vectors).

        The trial set is the server's ``ListOptimalTrials`` answer (for a
        single-objective study that is the single best trial); the vectors
        come from the study config's own scoring, so MINIMIZE metrics arrive
        sign-flipped exactly as the optimizer saw them. Trials the config
        cannot score (shouldn't happen for server-returned optima) are
        dropped from both lists in lockstep.
        """
        config = self.get_study_config()
        trials, vectors = [], []
        for t in self.list_optimal_trials():
            obj = config.objective_values(t)
            if obj is None:
                continue
            trials.append(t)
            vectors.append(obj)
        return trials, vectors

    def hypervolume(self, reference_point: Optional[List[float]] = None,
                    ) -> float:
        """Hypervolume dominated by the study's Pareto frontier.

        ``reference_point`` is in the larger-is-better convention (one value
        per metric, in config order); omitted, it anchors below the observed
        objectives via ``default_reference_point`` — fine for tracking
        progress within one study, but comparisons ACROSS studies or
        algorithms must pass the same explicit point.
        """
        from repro.core.pareto import default_reference_point, hypervolume

        _trials, vectors = self.pareto_frontier()
        if not vectors:
            return 0.0
        if reference_point is None:
            reference_point = default_reference_point(vectors)
        return hypervolume(vectors, reference_point)

    def add_trial(self, trial: Trial) -> Trial:
        """Registers a pre-evaluated trial (baseline / transfer learning)."""
        result = self._rpc.call(
            "CreateTrial", {"parent": self._study_name, "trial": trial.to_proto()}
        )
        return Trial.from_proto(result["trial"])

    def set_study_state(self, state: StudyState) -> None:
        self._rpc.call("SetStudyState", {"name": self._study_name, "state": state.value})

    def delete_study(self) -> None:
        self._rpc.call("DeleteStudy", {"name": self._study_name})

    def close(self) -> None:
        self._rpc.close()


class BatchSuggestionError(Exception):
    """A sub-request of a batched call failed.

    .errors  — per-item error dicts (None where the item succeeded)
    .results — per-item successful payloads (None where the item failed);
               for get_suggestions these are the Trial lists of the
               sub-requests that DID succeed, so callers don't orphan
               work the server already scheduled.
    """

    def __init__(self, message: str, errors, results=None):
        super().__init__(message)
        self.errors = errors
        self.results = results


class VizierBatchClient:
    """Fan-in client: one RPC round trip for N studies' suggestions.

        batch = VizierBatchClient(target)
        results = batch.get_suggestions([
            {"study_name": s1, "client_id": "w0", "count": 2},
            {"study_name": s2, "client_id": "w1"},
        ])
        # results[i] is the list of Trials for request i

    Unlike VizierClient, this is not bound to one study — it is meant for
    schedulers/launchers that coordinate many studies (or many workers'
    client_ids) and want the server to coalesce the Pythia work.
    """

    def __init__(
        self,
        target,
        *,
        poll_interval: float = 0.02,
        poll_backoff: float = 1.3,
        max_poll_interval: float = 2.0,
        long_poll: bool = True,
    ):
        self._rpc = RpcClient(target)
        self._poll = (poll_interval, poll_backoff, max_poll_interval)
        self._long_poll: Optional[bool] = None if long_poll else False

    def get_suggestions(
        self, requests: List[Dict], *, timeout: float = 600.0
    ) -> List[List[Trial]]:
        """requests: [{"study_name", "client_id", "count"?}] -> trials per item."""
        wire = [
            {
                "parent": r["study_name"],
                "suggestion_count": int(r.get("count", 1)),
                "client_id": r.get("client_id") or "default_client",
            }
            for r in requests
        ]
        if not wire:
            return []
        result = self._rpc.call("BatchSuggestTrials", {"requests": wire})
        errors = result.get("errors") or [None] * len(wire)
        ops = {
            i: op for i, op in enumerate(result["operations"]) if op is not None
        }
        # poll even when some sub-requests errored: the valid ones were
        # already dispatched server-side and must not be orphaned
        done = self._poll_operations(ops, timeout)
        trials_by_index = {
            i: [
                Trial.from_proto(p)
                for p in (op.get("result") or {}).get("trials", [])
            ]
            for i, op in done.items()
            if not op.get("error")
        }
        op_failures = {i: op["error"] for i, op in done.items() if op.get("error")}
        if any(errors):
            raise BatchSuggestionError(
                "batched suggestion had failures",
                errors,
                results=[trials_by_index.get(i) for i in range(len(wire))],
            )
        if op_failures:
            first_i = min(op_failures)
            raise OperationFailedError(
                f"batched suggestion failures: {op_failures}",
                code=op_failures[first_i].get("code"),
                operation_name=done[first_i]["name"],
            )
        return [trials_by_index[i] for i in range(len(wire))]

    def _poll_operations(self, ops: Dict[int, dict], timeout: float) -> Dict[int, dict]:
        """Awaits all pending operations: long-poll + pipelined sweep.

        Parks one WaitOperation on the lowest-indexed pending op — siblings
        of a coalesced dispatch complete together, so one long-poll amortizes
        the whole batch — then sweeps the rest with pipelined GetOperation
        frames. Falls back to the classic sleep/poll ladder on servers
        without WaitOperation.
        """
        done: Dict[int, dict] = {}
        interval, backoff, max_interval = self._poll
        deadline = time.monotonic() + timeout
        while True:
            for i, op in list(ops.items()):
                if op.get("done"):
                    done[i] = ops.pop(i)
            if not ops:
                return done
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                first = min(ops)
                raise OperationFailedError(
                    f"{len(ops)} batched suggestion operations timed out",
                    code=StatusCode.DEADLINE_EXCEEDED,
                    operation_name=ops[first]["name"],
                )
            idx = sorted(ops)
            if self._long_poll is not False:
                chunk = min(remaining, _WAIT_CHUNK_S)
                try:
                    ops[idx[0]] = self._rpc.call(
                        "WaitOperation",
                        {"name": ops[idx[0]]["name"], "timeout_ms": int(chunk * 1000)},
                        timeout=chunk + _WAIT_RPC_SLACK_S,
                    )["operation"]
                    self._long_poll = True
                except VizierRpcError as e:
                    if e.code != StatusCode.UNIMPLEMENTED:
                        raise
                    self._long_poll = False
                rest = idx[1:] if self._long_poll else idx
            else:
                time.sleep(min(interval, remaining))
                interval = min(interval * backoff, max_interval)
                rest = idx
            if rest:
                # pipelined poll: N GetOperation frames, one network round trip
                polled = self._rpc.call_many(
                    "GetOperation", [{"name": ops[i]["name"]} for i in rest]
                )
                for i, r in zip(rest, polled):
                    ops[i] = r["operation"]

    def complete_trials(
        self, completions: List[Dict]
    ) -> List[Optional[Trial]]:
        """completions: [{"trial_name", "metrics"?, "infeasibility_reason"?}].

        Returns the completed Trial per item (None where that item failed;
        failures raise BatchSuggestionError with per-item errors attached
        only if *all* items failed — partial failure is surfaced in-band so
        a scheduler can retry just the failed completions).
        """
        wire = []
        for c in completions:
            p: dict = {"name": c["trial_name"]}
            if c.get("infeasibility_reason") is not None:
                p["trial_infeasible"] = True
                p["infeasible_reason"] = c["infeasibility_reason"]
            elif c.get("metrics") is not None:
                m = c["metrics"]
                m = m if isinstance(m, Measurement) else Measurement(metrics=m)
                p["final_measurement"] = m.to_proto()
            wire.append(p)
        if not wire:
            return []
        result = self._rpc.call("BatchCompleteTrials", {"requests": wire})
        trials = [
            Trial.from_proto(p) if p is not None else None
            for p in result["trials"]
        ]
        errors = result.get("errors") or []
        if trials and all(t is None for t in trials):
            raise BatchSuggestionError("all batched completions failed", errors)
        return trials

    def close(self) -> None:
        self._rpc.close()
