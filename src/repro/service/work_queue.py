"""Study-sharded suggestion work queue + Pythia worker pool (scale-out tier).

The Figure-2 topology stops being one API server driving one Pythia dispatch
thread: suggest operations are enqueued on ``hash(study_name) % n_shards``
shards, and a pool of Pythia workers each lease one shard's whole backlog as a
coalesced batch, run it through the existing coalesced-dispatch path, and ack
on completion. The invariants:

* **Shard keying** — a study maps to exactly one shard (stable CRC32 of the
  study name, see ``operations.shard_of``), and a shard is leased by at most
  one worker at a time, so one study's policy state is never computed by two
  workers concurrently.
* **Lease / ack / requeue** — ``lease`` hands a worker every op currently
  queued on one free shard and stamps the lease with the shard's generation
  counter. ``ack`` retires the lease only if the generation still matches. A
  worker that dies mid-lease (killed, or its lease outlives
  ``lease_timeout``) has its in-flight ops requeued at the *front* of their
  shard; the generation bump makes the dead worker's late ack — and, via
  ``lease_valid`` guards in the finalize path, its late op completions — a
  no-op, so a re-run never races a zombie.
* **Idempotent re-run** — requeued ops that the dead worker *did* finish are
  filtered out by the runner's done-check before (and again under the study
  lock during) finalization, so a kill between "op completed" and "ack" never
  produces duplicate trials.

``PythiaWorkerPool`` runs the workers as daemon threads inside the API-server
process; ``stop_worker``/``restart_worker`` give the fault-injection harness
worker-granular kills (extending the PR-2 ``stop_pythia``/``restart_pythia``
process-granular harness).
"""

from __future__ import annotations

import logging
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.service import chaos
from repro.service import operations as ops_lib
from repro.service._lockwitness import make_condition

log = logging.getLogger(__name__)


class Lease:
    """One worker's claim on one shard's batch of suggest ops."""

    __slots__ = ("shard_id", "generation", "worker_id", "ops", "deadline")

    def __init__(self, shard_id: int, generation: int, worker_id: int,
                 ops: List[dict], deadline: float):
        self.shard_id = shard_id
        self.generation = generation
        self.worker_id = worker_id
        self.ops = ops
        self.deadline = deadline

    def __repr__(self) -> str:  # debugging/fault-test output
        return (f"Lease(shard={self.shard_id}, gen={self.generation}, "
                f"worker={self.worker_id}, ops={len(self.ops)})")


class _Shard:
    __slots__ = ("queued", "lease", "generation")

    def __init__(self):
        self.queued: deque = deque()
        self.lease: Optional[Lease] = None
        self.generation = 0


class ShardedWorkQueue:
    """In-process sharded op queue with exclusive shard leases.

    All state transitions happen under one condition variable; ``lease``
    blocks until some shard has queued work and no active lease. Expired
    leases are reclaimed lazily on the next ``lease``/``enqueue`` scan — no
    background reaper thread.
    """

    def __init__(self, n_shards: int = 8, *, lease_timeout: float = 30.0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.lease_timeout = lease_timeout
        self._shards = [_Shard() for _ in range(n_shards)]
        self._cv = make_condition("ShardedWorkQueue._cv")
        self._closed = False

    # -- producers -----------------------------------------------------------
    def shard_of(self, study_name: str) -> int:
        return ops_lib.shard_of(study_name, self.n_shards)

    def enqueue(self, op: dict) -> int:
        """Queue a suggest op on its study's shard; returns the shard id."""
        sid = self.shard_of(op["study_name"])
        with self._cv:
            self._shards[sid].queued.append(op)
            self._cv.notify_all()
        return sid

    # -- workers -------------------------------------------------------------
    def _reclaim_expired_locked(self, now: float) -> List[Tuple[str, int]]:
        """Requeue expired leases; returns (lease repr, op count) for each so
        the caller can log after releasing the CV (logging does I/O)."""
        reclaimed: List[Tuple[str, int]] = []
        for shard in self._shards:
            lease = shard.lease
            if lease is not None and now > lease.deadline:
                reclaimed.append((repr(lease), len(lease.ops)))
                self._requeue_locked(lease)
        return reclaimed

    def _requeue_locked(self, lease: Lease) -> None:
        shard = self._shards[lease.shard_id]
        if shard.lease is not lease:
            return  # already reclaimed / acked
        # front of the queue, original order: re-runs keep arrival fairness
        for op in reversed(lease.ops):
            shard.queued.appendleft(ops_lib.note_requeued(op))
        shard.lease = None
        shard.generation += 1  # invalidates the dead holder's lease
        self._cv.notify_all()

    def lease(self, worker_id: int, timeout: Optional[float] = None
              ) -> Optional[Lease]:
        """Claim one free shard's whole backlog; None on timeout/close."""
        deadline = None if timeout is None else time.monotonic() + timeout
        granted: Optional[Lease] = None
        while granted is None:
            # the wait loop re-acquires the CV each iteration so reclaim
            # warnings flush outside the critical section
            reclaimed: List[Tuple[str, int]] = []
            try:
                with self._cv:
                    if self._closed:
                        return None
                    now = time.monotonic()
                    reclaimed = self._reclaim_expired_locked(now)
                    for sid, shard in enumerate(self._shards):
                        if shard.queued and shard.lease is None:
                            ops = list(shard.queued)
                            shard.queued.clear()
                            shard.generation += 1
                            granted = Lease(sid, shard.generation, worker_id,
                                            ops, now + self.lease_timeout)
                            shard.lease = granted
                            break
                    if granted is None:
                        if deadline is not None:
                            remaining = deadline - time.monotonic()
                            if remaining <= 0:
                                return None
                            self._cv.wait(remaining)
                        else:
                            self._cv.wait()
            finally:
                # the with-block has exited (CV released) before this runs
                for desc, n_ops in reclaimed:
                    log.warning("lease %s expired; requeueing %d ops",
                                desc, n_ops)
        # strictly outside the CV: an injected stall or early expiry on this
        # grant must never block the other shards' lease traffic
        chaos.inject("queue.lease", lease=granted)
        return granted

    def lease_valid(self, lease: Lease) -> bool:
        """True while the lease still owns its shard (generation match)."""
        with self._cv:
            shard = self._shards[lease.shard_id]
            return shard.lease is lease and shard.generation == lease.generation

    def ack(self, lease: Lease) -> bool:
        """Retire a completed lease. False (no-op) if it was reclaimed."""
        with self._cv:
            shard = self._shards[lease.shard_id]
            if shard.lease is not lease or shard.generation != lease.generation:
                return False  # stale: ops were requeued to another worker
            shard.lease = None
            self._cv.notify_all()
            return True

    def release(self, lease: Lease) -> bool:
        """Hand a lease back *without* acking (the batch runner failed).

        The ops requeue at the front exactly like a crash reclaim — a worker
        whose runner raised must not ack work it may not have finished, or a
        still-pending op would be retired on a live server and stay pending
        forever (a lost acked op). False if the lease was already reclaimed.
        """
        with self._cv:
            shard = self._shards[lease.shard_id]
            if shard.lease is not lease or shard.generation != lease.generation:
                return False
            self._requeue_locked(lease)
            return True

    def reclaim_worker(self, worker_id: int) -> int:
        """Requeue every in-flight op of a dead worker's active leases."""
        requeued = 0
        with self._cv:
            for shard in self._shards:
                lease = shard.lease
                if lease is not None and lease.worker_id == worker_id:
                    requeued += len(lease.ops)
                    self._requeue_locked(lease)
        return requeued

    # -- introspection -------------------------------------------------------
    def pending_count(self) -> int:
        with self._cv:
            return sum(len(s.queued) for s in self._shards) + sum(
                len(s.lease.ops) for s in self._shards if s.lease is not None)

    def active_leases(self) -> List[Lease]:
        with self._cv:
            return [s.lease for s in self._shards if s.lease is not None]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


# RunBatch(ops, op_guard) runs a coalesced batch; op_guard(op) -> False means
# "your lease is gone, do not finalize this op" (see VizierService).
RunBatch = Callable[[List[dict], Callable[[dict], bool]], None]
AlreadyDone = Callable[[dict], bool]


class PythiaWorkerPool:
    """N worker threads pulling coalesced batches off a ShardedWorkQueue.

    ``stop_worker`` simulates a worker crash: the thread is flagged dead,
    joined briefly (it may be stuck mid-dispatch — a real crash would be),
    and its leases are reclaimed so surviving workers re-run the in-flight
    ops. The zombie thread's eventual finalize attempts are rejected by the
    lease-validity guard.
    """

    _POLL = 0.05  # lease-wait slice; bounds worker shutdown latency

    def __init__(self, queue: ShardedWorkQueue, run_batch: RunBatch,
                 already_done: AlreadyDone, *, n_workers: int = 2):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self._queue = queue
        self._run_batch = run_batch
        self._already_done = already_done
        self.n_workers = n_workers
        self._threads: Dict[int, threading.Thread] = {}
        self._killed: Dict[int, threading.Event] = {}
        self._shutdown = threading.Event()

    def start(self) -> "PythiaWorkerPool":
        for wid in range(self.n_workers):
            self._spawn(wid)
        return self

    def _spawn(self, wid: int) -> None:
        self._killed[wid] = threading.Event()
        t = threading.Thread(target=self._loop, args=(wid,),
                             name=f"pythia-worker-{wid}", daemon=True)
        self._threads[wid] = t
        t.start()

    def _loop(self, wid: int) -> None:
        killed = self._killed[wid]
        while not (self._shutdown.is_set() or killed.is_set()):
            try:
                lease = self._queue.lease(wid, timeout=self._POLL)
            except Exception:  # noqa: BLE001 — injected lease fault: the
                log.exception("worker %d lease raised", wid)
                continue      # grant reclaims via its own timeout
            if lease is None:
                continue
            failed = False
            try:
                # a mid-batch worker kill lands here: killed.set() via the
                # seam's kill callback, checked before dispatch and by the
                # op_guard below
                chaos.inject("worker.batch", worker=wid, lease=lease,
                             kill=killed.set)
                # idempotent re-run: skip ops a dead predecessor finished
                ops = [op for op in lease.ops if not self._already_done(op)]
                if ops and not killed.is_set():
                    self._run_batch(
                        ops,
                        lambda op: (not killed.is_set()
                                    and self._queue.lease_valid(lease)),
                    )
                chaos.inject("queue.ack", lease=lease, kill=killed.set)
            except Exception:  # noqa: BLE001 — the runner fails ops itself
                log.exception("worker %d batch run raised", wid)
                failed = True
            if killed.is_set():
                return  # crashed before ack: reclaim/lease-expiry requeues
            if failed:
                # crash-equivalent: the runner may have died before failing
                # every op — hand the batch back instead of acking it away
                self._queue.release(lease)
                continue
            self._queue.ack(lease)

    # -- fault injection / lifecycle ----------------------------------------
    def alive_workers(self) -> List[int]:
        return sorted(w for w, t in self._threads.items() if t.is_alive())

    def worker_holding(self, study_name: str) -> Optional[int]:
        """Which worker's lease covers this study's shard right now."""
        sid = self._queue.shard_of(study_name)
        for lease in self._queue.active_leases():
            if lease.shard_id == sid:
                return lease.worker_id
        return None

    def stop_worker(self, worker_id: int, *, join_timeout: float = 1.0) -> int:
        """Kill one worker mid-whatever; returns how many ops were requeued."""
        killed = self._killed.get(worker_id)
        if killed is None:
            raise KeyError(f"no worker {worker_id}")
        killed.set()
        t = self._threads[worker_id]
        t.join(timeout=join_timeout)  # may still be stuck in a dispatch
        return self._queue.reclaim_worker(worker_id)

    def restart_worker(self, worker_id: int) -> None:
        old = self._threads.get(worker_id)
        if old is not None and old.is_alive() and not self._killed[worker_id].is_set():
            raise RuntimeError(f"worker {worker_id} is still alive")
        self._spawn(worker_id)

    def shutdown(self, *, join_timeout: float = 1.0) -> None:
        self._shutdown.set()
        self._queue.close()
        for t in self._threads.values():
            t.join(timeout=join_timeout)
