"""Standalone Pythia service (paper Figure 2: "Pythia may run as a separate
service from the API service").

Hosts the algorithm registry behind two RPC methods; reads trials through a
RemotePolicySupporter that RPCs *back* to the API server, so the algorithm
binary needs no datastore of its own and can be written in any language that
speaks the wire format.
"""

from __future__ import annotations

import logging
from typing import Optional

from repro.core.metadata import MetadataDelta
from repro.core.study_config import StudyConfig
from repro.core.study import Trial, TrialState
from repro.pythia.policy import EarlyStopRequest, StudyDescriptor, SuggestRequest
from repro.pythia.registry import make_policy
from repro.pythia.supporter import RemotePolicySupporter
from repro.service.rpc import RpcClient, RpcServer, Servicer

log = logging.getLogger(__name__)


class PythiaServicer(Servicer):
    def __init__(self, api_server_target):
        """api_server_target: address string or in-process VizierService."""
        super().__init__()
        self._api_target = api_server_target
        self.expose("PythiaSuggest", self.PythiaSuggest)
        self.expose("PythiaEarlyStop", self.PythiaEarlyStop)

    def _rpc(self) -> RpcClient:
        return RpcClient(self._api_target)

    def _load(self, rpc: RpcClient, study_name: str):
        study_proto = rpc.call("GetStudy", {"name": study_name})["study"]
        config = StudyConfig.from_proto(study_proto["study_spec"])
        trials = rpc.call("ListTrials", {"parent": study_name})["trials"]
        max_id = max((int(t["id"]) for t in trials), default=0)
        return config, StudyDescriptor(config=config, guid=study_name, max_trial_id=max_id)

    def PythiaSuggest(self, params: dict) -> dict:
        rpc = self._rpc()
        try:
            config, descriptor = self._load(rpc, params["study_name"])
            supporter = RemotePolicySupporter(rpc, params["study_name"])
            policy = make_policy(config.algorithm, supporter, config)
            decision = policy.suggest(
                SuggestRequest(study_descriptor=descriptor, count=int(params["count"]))
            )
            suggestions = []
            for s in decision.suggestions:
                t = Trial(parameters=s.parameters, metadata=s.metadata,
                          state=TrialState.REQUESTED)
                suggestions.append(t.to_proto())
            return {
                "suggestions": suggestions,
                "metadata_delta": decision.metadata.to_proto(),
            }
        finally:
            rpc.close()

    def PythiaEarlyStop(self, params: dict) -> dict:
        rpc = self._rpc()
        try:
            config, descriptor = self._load(rpc, params["study_name"])
            supporter = RemotePolicySupporter(rpc, params["study_name"])
            policy = make_policy(config.algorithm, supporter, config)
            decisions = policy.early_stop(
                EarlyStopRequest(
                    study_descriptor=descriptor,
                    trial_ids=[int(t) for t in params["trial_ids"]],
                )
            ).decisions
            return {
                "decisions": [
                    {"trial_id": d.trial_id, "should_stop": d.should_stop,
                     "reason": d.reason}
                    for d in decisions
                ]
            }
        finally:
            rpc.close()


def start_pythia_server(api_server_address: str, host: str = "127.0.0.1",
                        port: int = 0) -> RpcServer:
    servicer = PythiaServicer(api_server_address)
    return RpcServer(servicer, host=host, port=port).start()
