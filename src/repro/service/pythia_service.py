"""Standalone Pythia service (paper Figure 2: "Pythia may run as a separate
service from the API service").

Hosts the algorithm registry behind three RPC methods; reads trials through a
RemotePolicySupporter that RPCs *back* to the API server, so the algorithm
binary needs no datastore of its own and can be written in any language that
speaks the wire format.

Coalesced dispatch: PythiaBatchSuggest takes a whole BatchSuggestTrials
work-list in one frame. The servicer loads every batched study's
config/descriptor/trials exactly once — ONE GetTrialsMulti frame back to the
API server (include_studies folds the config fetch in) — then runs each
policy against the prefetched raw-proto snapshot, so policies never re-RPC
for trials the service already holds, and SendMetadata writes are folded
into the response instead of costing a frame per policy. Per-item failures
(deleted study, policy bug) come back as error entries, never as a failed
batch: the same isolation contract as the API server's in-process coalesced
path. The per-study PythiaSuggest method is kept as a back-compat shim for
non-batch callers; with single_fetch=True (default) it rides the same
one-frame loader (previously it listed trials once for max_trial_id and the
policy supporter re-fetched them over the wire).

The service is driven concurrently by the API server's Pythia worker pool
(one coalesced PythiaBatchSuggest in flight per worker); calls back to the
API server ride a shared thread-affine connection pool — each handler thread
reuses its own persistent connection instead of dialing per request.
"""

from __future__ import annotations

import logging
from typing import Dict, List, Tuple, Union

from repro.core.metadata import Metadata, MetadataDelta
from repro.core.study_config import StudyConfig
from repro.core.study import Trial, TrialState
from repro.pythia.policy import EarlyStopRequest, StudyDescriptor, SuggestRequest
from repro.pythia.registry import make_policy
from repro.pythia.supporter import RemotePolicySupporter
from repro.service.rpc import (
    PooledRpcClient,
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)

log = logging.getLogger(__name__)

# name -> (config, descriptor, raw trial protos) | the error that study hit.
# Trial protos stay raw until a policy actually reads them (the supporter
# materializes lazily) — random-search-style policies pay nothing.
_LoadedStudy = Union[Tuple[StudyConfig, StudyDescriptor, List[dict]], VizierRpcError]


class PythiaServicer(Servicer):
    def __init__(self, api_server_target, *, single_fetch: bool = True):
        """api_server_target: address string or in-process VizierService.

        single_fetch=False restores the pre-batch wire pattern (one
        ListTrials just to compute max_trial_id, policies re-fetching the
        same trials per state) — the per-study-RPC baseline the throughput
        benchmark quantifies the coalesced dispatch against.
        """
        super().__init__()
        self._api_target = api_server_target
        self._single_fetch = single_fetch
        # one pooled client for the life of the servicer: each handler
        # thread keeps its own persistent connection to the API server
        # (dialing a fresh socket per request was measurable churn once the
        # worker pool started driving N concurrent batch dispatches)
        self._api_rpc = PooledRpcClient(api_server_target)
        self.expose("PythiaSuggest", self.PythiaSuggest)
        self.expose("PythiaBatchSuggest", self.PythiaBatchSuggest)
        self.expose("PythiaEarlyStop", self.PythiaEarlyStop)

    def _rpc(self) -> PooledRpcClient:
        return self._api_rpc

    def close(self) -> None:
        self._api_rpc.close()

    def _load_many(self, rpc: RpcClient, study_names: List[str]
                   ) -> "Tuple[Dict[str, _LoadedStudy], dict]":
        """Configs + descriptors + trials for N studies, isolated per study.

        Exactly ONE GetTrialsMulti frame back to the API server regardless
        of N: include_studies folds the config fetch in, include_priors
        folds every study's transfer-learning prior studies in, and
        max_trial_id comes from the fetched list itself — no separate
        GetStudy round, no ListTrials just to compute the id watermark.

        Returns (per-study work-list entries, supporter context): the
        context dict carries the full raw-trial ``snapshot`` (batched
        studies AND their priors), the parsed ``configs`` for everything the
        frame returned, and the server-reported ``missing`` names — all of
        which RemotePolicySupporter serves locally so policies (including
        the stacked-GP transfer reads) never re-RPC.
        """
        out: Dict[str, _LoadedStudy] = {}
        fetched = rpc.call("GetTrialsMulti", {
            "parents": study_names, "allow_missing": True,
            "include_studies": True, "include_priors": True,
        })
        by_study = fetched["trials_by_study"]
        study_protos = fetched["studies"]
        configs: Dict[str, StudyConfig] = {}
        for name, proto in study_protos.items():
            try:
                configs[name] = StudyConfig.from_proto(proto["study_spec"])
            except Exception:  # noqa: BLE001 — a bad prior config is skipped
                log.exception("unparsable study_spec for %s", name)
        for name in study_names:
            if name not in configs:
                out[name] = VizierRpcError(
                    StatusCode.NOT_FOUND, f"study {name!r}")
                continue
            raw_trials = by_study.get(name, [])
            max_id = max((int(t["id"]) for t in raw_trials), default=0)
            descriptor = StudyDescriptor(
                config=configs[name], guid=name, max_trial_id=max_id)
            out[name] = (configs[name], descriptor, raw_trials)
        context = {
            "snapshot": dict(by_study),
            "configs": configs,
            "missing": list(fetched.get("missing", ())),
        }
        return out, context

    def _load(self, rpc: RpcClient, study_name: str):
        loaded_map, context = self._load_many(rpc, [study_name])
        loaded = loaded_map[study_name]
        if isinstance(loaded, VizierRpcError):
            raise loaded
        return loaded, context

    def _suggest_one(self, rpc: RpcClient, loaded, count: int,
                     context: dict, *,
                     buffer_metadata: bool = True) -> dict:
        config, descriptor, _ = loaded
        supporter = RemotePolicySupporter(rpc, descriptor.guid,
                                          prefetched=context.get("snapshot") or {},
                                          buffer_metadata=buffer_metadata,
                                          configs=context.get("configs"),
                                          known_missing=context.get("missing", ()))
        policy = make_policy(config.algorithm, supporter, config)
        # persisted algorithm state reaches the policy through the config's
        # metadata (request.study_metadata), which rode the single
        # GetTrialsMulti(include_studies) frame — zero extra RPCs
        decision = policy.suggest(
            SuggestRequest(study_descriptor=descriptor, count=count)
        )
        suggestions = []
        for s in decision.suggestions:
            t = Trial(parameters=s.parameters, metadata=s.metadata,
                      state=TrialState.REQUESTED)
            suggestions.append(t.to_proto())
        # SendMetadata writes were buffered instead of RPC'd; fold any the
        # policy did not also return into the wire delta so the API server
        # persists everything when it finalizes the operation.
        delta = decision.metadata
        extras = [d for d in supporter.buffered_deltas if d is not delta]
        if extras:
            merged = MetadataDelta()
            for d in extras + [delta]:
                merged.on_study.attach(d.on_study)
                for tid, md in d.on_trials.items():
                    merged.on_trials.setdefault(tid, Metadata()).attach(md)
            delta = merged
        return {
            "suggestions": suggestions,
            "metadata_delta": delta.to_proto(),
        }

    def _load_legacy(self, rpc: RpcClient, study_name: str):
        """Pre-batch loader: a full ListTrials only to compute max_trial_id
        (the double-fetch PythiaBatchSuggest eliminates)."""
        study_proto = rpc.call("GetStudy", {"name": study_name})["study"]
        config = StudyConfig.from_proto(study_proto["study_spec"])
        trials = rpc.call("ListTrials", {"parent": study_name})["trials"]
        max_id = max((int(t["id"]) for t in trials), default=0)
        return config, StudyDescriptor(config=config, guid=study_name,
                                       max_trial_id=max_id), None

    def PythiaSuggest(self, params: dict) -> dict:
        rpc = self._rpc()
        name = params["study_name"]
        if self._single_fetch:
            loaded, context = self._load(rpc, name)
        else:
            loaded = self._load_legacy(rpc, name)
            context = {}  # policy re-RPCs per state, as before
        return self._suggest_one(rpc, loaded, int(params["count"]),
                                 context,
                                 buffer_metadata=self._single_fetch)

    def PythiaBatchSuggest(self, params: dict) -> dict:
        """N sub-requests -> N parallel result entries, one shared prefetch.

        params: {"requests": [{"study_name", "count", "client_id"?}...]}
        Result: {"results": [{"suggestions", "metadata_delta"} |
                             {"error": {"code", "message"}}]}

        Same-study sub-requests are coalesced exactly like the API server's
        _run_suggest_ops_coalesced: ONE policy invocation with the summed
        count, suggestions split across the sub-requests in arrival order
        (so two clients batched onto one study never receive the duplicate
        points a deterministic policy would produce if invoked twice on the
        same snapshot). The study's metadata delta rides the group's first
        result entry. A failed study fails only its own entries.
        """
        requests = params.get("requests") or []
        rpc = self._rpc()
        # group by study preserving arrival order: name -> [(index, count)]
        groups: Dict[str, list] = {}
        results: list = [None] * len(requests)
        for i, r in enumerate(requests):
            name = r.get("study_name")
            if not name:
                results[i] = {"error": {
                    "code": StatusCode.INVALID_ARGUMENT,
                    "message": "sub-request missing study_name",
                }}
                continue
            groups.setdefault(name, []).append((i, int(r.get("count", 1))))
        if groups:
            loaded, context = self._load_many(rpc, list(groups))
        else:
            loaded, context = {}, {}
        for name, members in groups.items():
            entry = loaded[name]
            if isinstance(entry, VizierRpcError):
                for i, _ in members:
                    results[i] = {"error": {
                        "code": entry.code, "message": entry.message,
                    }}
                continue
            total = sum(count for _, count in members)
            try:
                one = self._suggest_one(rpc, entry, total, context)
            except Exception as e:  # noqa: BLE001 — isolate per study
                log.exception("batched suggest for %s failed", name)
                # preserve a carried status code (PolicyConstructionError
                # carries INVALID_ARGUMENT): collapsing everything to
                # INTERNAL here made permanent config errors retryable in
                # the remote topology while the local path failed them fast
                code = getattr(e, "code", None)
                if not isinstance(code, int):
                    code = StatusCode.INTERNAL
                for i, _ in members:
                    results[i] = {"error": {
                        "code": code,
                        "message": f"{type(e).__name__}: {e}",
                    }}
                continue
            suggestions = one["suggestions"]
            cursor = 0
            for k, (i, want) in enumerate(members):
                take = suggestions[cursor:cursor + want]
                cursor += len(take)
                if want and not take:
                    results[i] = {"error": {
                        "code": StatusCode.INTERNAL,
                        "message": (
                            f"policy returned {len(suggestions)} "
                            f"suggestions for a coalesced request of "
                            f"{total}; none left for this sub-request"),
                    }}
                    continue
                if len(take) < want:
                    log.warning("coalesced sub-request %d got %d/%d "
                                "suggestions", i, len(take), want)
                results[i] = {
                    "suggestions": take,
                    # the study's delta is applied once, via the first entry
                    "metadata_delta": one["metadata_delta"] if k == 0
                    else MetadataDelta().to_proto(),
                }
        return {"results": results}

    def PythiaEarlyStop(self, params: dict) -> dict:
        rpc = self._rpc()
        name = params["study_name"]
        (config, descriptor, _trials), context = self._load(rpc, name)
        supporter = RemotePolicySupporter(
            rpc, name,
            prefetched=context.get("snapshot") or {},
            configs=context.get("configs"),
            known_missing=context.get("missing", ()))
        policy = make_policy(config.algorithm, supporter, config)
        decisions = policy.early_stop(
            EarlyStopRequest(
                study_descriptor=descriptor,
                trial_ids=[int(t) for t in params["trial_ids"]],
            )
        ).decisions
        return {
            "decisions": [
                {"trial_id": d.trial_id, "should_stop": d.should_stop,
                 "reason": d.reason}
                for d in decisions
            ]
        }


def start_pythia_server(api_server_address: str, host: str = "127.0.0.1",
                        port: int = 0) -> RpcServer:
    servicer = PythiaServicer(api_server_address)
    return RpcServer(servicer, host=host, port=port).start()
