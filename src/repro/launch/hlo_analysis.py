"""HLO post-compile analysis: collective-byte accounting + roofline terms.

collective_bytes is not in cost_analysis(); we parse the optimized HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, converting each to *wire bytes per device*
with a ring model:

    all-gather:          result_bytes * (g-1)/g
    all-reduce:      2 * result_bytes * (g-1)/g
    reduce-scatter:      result_bytes * (g-1)        (input = g * result)
    all-to-all:          result_bytes * (g-1)/g
    collective-permute:  result_bytes

Caveat (recorded in EXPERIMENTS.md): the CPU backend sometimes upcasts bf16
collectives to f32 (convert-then-gather instead of gather-then-convert), so
wire bytes here are an upper bound vs the TPU bf16 schedule.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveStats:
    op: str
    dtype: str
    numel: int
    bytes: int
    group_size: int
    wire_bytes: float  # per participating device


def _numel(dims: str) -> int:
    if not dims.strip():
        return 1
    return int(np.prod([int(d) for d in dims.split(",") if d]))


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return total_devices


def _wire_bytes(op: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return nbytes * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(nbytes) * (g - 1)
    if op == "all-to-all":
        return nbytes * (g - 1) / g
    return float(nbytes)  # collective-permute


def parse_collectives(hlo_text: str, total_devices: int) -> List[CollectiveStats]:
    out: List[CollectiveStats] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        shapes: List[tuple] = []
        op = None
        if m:
            op = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                op = mt.group(2)
                for part in mt.group(1).split(","):
                    part = part.strip()
                    sm = re.match(r"([a-z0-9]+)\[([\d,]*)\]", part)
                    if sm:
                        shapes.append((sm.group(1), sm.group(2)))
        if not op or not shapes:
            continue
        g = _group_size(line, total_devices)
        for dtype, dims in shapes:
            if dtype not in _DTYPE_BYTES:
                continue
            numel = _numel(dims)
            nbytes = numel * _DTYPE_BYTES[dtype]
            out.append(CollectiveStats(
                op=op, dtype=dtype, numel=numel, bytes=nbytes, group_size=g,
                wire_bytes=_wire_bytes(op, nbytes, g)))
    return out


def collective_summary(colls: List[CollectiveStats]) -> Dict[str, dict]:
    summary: Dict[str, dict] = {}
    for c in colls:
        s = summary.setdefault(c.op, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        s["count"] += 1
        s["bytes"] += c.bytes
        s["wire_bytes"] += c.wire_bytes
    return summary


def count_remat_flops_waste(hlo_text: str) -> int:
    """Counts duplicate fusion signatures as a proxy for remat recompute."""
    names = re.findall(r"%(fused_computation[.\w]*)", hlo_text)
    return max(0, len(set(names)) and len(names) - len(set(names)))


@dataclasses.dataclass
class RooflineTerms:
    """Three-term roofline per device (seconds)."""

    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self) | {
            "dominant": self.dominant, "step_time_s": self.step_time_s}


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   wire_bytes_per_device: float, *, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> RooflineTerms:
    return RooflineTerms(
        flops=flops_per_device,
        hbm_bytes=hbm_bytes_per_device,
        wire_bytes=wire_bytes_per_device,
        compute_s=flops_per_device / peak_flops,
        memory_s=hbm_bytes_per_device / hbm_bw,
        collective_s=wire_bytes_per_device / ici_bw,
    )
