"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any scanned
layer stack is undercounted by ~n_layers. This module parses the optimized
HLO text into its computation graph, extracts while-loop trip counts from
loop-condition constants, and walks from ENTRY with a multiplier:

  * flops        — 2 * numel(result) * contracted-dim product, per `dot`
  * hbm traffic  — per post-fusion op: result bytes (write) + operand bytes
                   (reads); parameters/GTE/tuple/constant/bitcast are free
  * wire bytes   — ring-model collective cost (hlo_analysis._wire_bytes)

Conditionals take the max across branches. Numbers are per-device (the HLO
module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch.hlo_analysis import _DTYPE_BYTES, _group_size, _wire_bytes

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*(.+?)\s+([\w-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s+\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "fusion", "custom-call",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


def _numel(dims: str) -> int:
    if not dims.strip():
        return 1
    return int(np.prod([int(d) for d in dims.split(",") if d]))


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            total += _numel(dims) * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]  # value name -> type string


def parse_hlo_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and not line.lstrip().startswith("%constant"):
            current = Computation(name=hdr.group(1), ops=[], shapes={})
            comps[current.name] = current
            if line.strip().startswith("ENTRY"):
                entry_name = current.name
            # parameters: "p.1: f32[2,3]" pairs
            for pname, ptype in re.findall(r"([\w.-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]))",
                                           hdr.group(2)):
                current.shapes[pname] = ptype
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        current.ops.append(Op(name=name, type_str=type_str, opcode=opcode, rest=rest))
        current.shapes[name] = type_str
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest constant compared against in the loop condition."""
    best = 1
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            mm = re.search(r"\bconstant\((\d+)\)", op.type_str + " " + op.rest) or \
                 _CONST_RE.search(op.rest)
            if mm:
                consts[op.name] = int(mm.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for operand in _OPERAND_RE.findall(op.rest):
                if operand in consts:
                    best = max(best, consts[operand])
            mm = _CONST_RE.search(op.rest)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    result_elems = 0
    for dtype, dims in _SHAPE_RE.findall(op.type_str):
        result_elems += _numel(dims)
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    if mm and operands:
        lhs_shape = shapes.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in mm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * result_elems * contract


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    wire_bytes: float = 0.0
    # traffic attributed to jax.named_scope tags (e.g. "xla_flash_attention"):
    # the part a fused Pallas kernel keeps in VMEM on real TPU
    scoped_traffic: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Costs"):
        self.flops += other.flops
        self.traffic_bytes += other.traffic_bytes
        self.wire_bytes += other.wire_bytes
        for k, v in other.scoped_traffic.items():
            self.scoped_traffic[k] = self.scoped_traffic.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.traffic_bytes * k, self.wire_bytes * k,
                     {s: v * k for s, v in self.scoped_traffic.items()})


TRACKED_SCOPES = ("xla_flash_attention", "xla_ssd_scan")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _op_scope(op: "Op", inherited: Optional[str] = None) -> Optional[str]:
    m = _OPNAME_RE.search(op.rest)
    if m:
        for scope in TRACKED_SCOPES:
            if scope in m.group(1):
                return scope
    return inherited


def _add_traffic(total: Costs, nbytes: float, op: "Op",
                 inherited: Optional[str] = None) -> None:
    total.traffic_bytes += nbytes
    scope = _op_scope(op, inherited)
    if scope:
        total.scoped_traffic[scope] = total.scoped_traffic.get(scope, 0.0) + nbytes


def _comp_costs(comp: Computation, comps: Dict[str, Computation],
                total_devices: int, memo: Dict[Tuple[str, bool, Optional[str]], Costs],
                count_traffic: bool = True, scope: Optional[str] = None) -> Costs:
    key = (comp.name, count_traffic, scope)
    if key in memo:
        return memo[key]
    memo[key] = Costs()  # cycle guard
    total = Costs()
    for op in comp.ops:
        if op.opcode == "dot":
            total.flops += _dot_flops(op, comp.shapes)
            if count_traffic:
                t = _shapes_bytes(op.type_str)
                for operand in _OPERAND_RE.findall(op.rest.split(")")[0]):
                    t += _shapes_bytes(comp.shapes.get(operand, ""))
                _add_traffic(total, t, op, scope)
        elif op.opcode in _COLLECTIVES:
            base = op.opcode.replace("-start", "")
            g = _group_size(op.rest, total_devices)
            nbytes = _shapes_bytes(op.type_str)
            total.wire_bytes += _wire_bytes(base, nbytes, g)
            if count_traffic:
                total.traffic_bytes += 2 * nbytes
        elif op.opcode == "while":
            bm = re.search(r"body=%?([\w.-]+)", op.rest)
            cm = re.search(r"condition=%?([\w.-]+)", op.rest)
            body_name = bm.group(1) if bm else None
            cond_name = cm.group(1) if cm else None
            # XLA records the statically-known trip count in backend_config
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
            if tm:
                trip = int(tm.group(1))
            else:
                trip = _trip_count(comps[cond_name]) if cond_name in comps else 1
            if body_name in comps:
                body = _comp_costs(comps[body_name], comps, total_devices, memo,
                                   count_traffic, _op_scope(op, scope))
                total += body.scaled(trip)
        elif op.opcode in ("fusion", "call", "custom-call", "async-start"):
            cm = re.search(r"(?:calls|to_apply)=%?([\w.-]+)", op.rest)
            inner_traffic = count_traffic and op.opcode == "call"
            if cm and cm.group(1) in comps:
                total += _comp_costs(comps[cm.group(1)], comps, total_devices,
                                     memo, inner_traffic, _op_scope(op, scope))
            # post-fusion boundary traffic: result + operands. Fusions rooted
            # at dynamic-update-slice alias their destination buffer in place:
            # only the non-aliased operands + the updated slice move.
            if count_traffic and op.opcode in ("fusion", "custom-call"):
                operands = _OPERAND_RE.findall(op.rest.split(")")[0])
                sizes = [_shapes_bytes(comp.shapes.get(o, "")) for o in operands]
                if "dynamic-update-slice" in op.name or "dynamic_update_slice" in op.rest:
                    big = max(sizes) if sizes else 0
                    t = 2.0 * (sum(sizes) - big)
                else:
                    t = _shapes_bytes(op.type_str) + sum(sizes)
                _add_traffic(total, t, op, scope)
        elif op.opcode == "conditional":
            branches = re.findall(r"%([\w.-]+)", op.rest)
            branch_costs = [
                _comp_costs(comps[b], comps, total_devices, memo, count_traffic,
                            scope)
                for b in branches if b in comps
            ]
            if branch_costs:
                best = max(branch_costs, key=lambda c: c.flops + c.traffic_bytes)
                total += best
        elif count_traffic and op.opcode == "dynamic-update-slice":
            # in-place update touches only the updated slice (operand 1),
            # not the whole destination buffer
            operands = _OPERAND_RE.findall(op.rest.split(")")[0])
            upd = operands[1] if len(operands) > 1 else None
            _add_traffic(total, 2 * _shapes_bytes(comp.shapes.get(upd, "")), op,
                         scope)
        elif count_traffic and op.opcode not in _NO_TRAFFIC:
            # standalone elementwise / reduce / copy / gather / scatter ...
            t = _shapes_bytes(op.type_str)
            for operand in _OPERAND_RE.findall(op.rest.split(")")[0]):
                t += _shapes_bytes(comp.shapes.get(operand, ""))
            _add_traffic(total, t, op, scope)
    memo[key] = total
    return total


def analyze_hlo(text: str, total_devices: int) -> Costs:
    """Loop-corrected per-device costs from optimized HLO text."""
    comps = parse_hlo_module(text)
    if "__entry__" not in comps:
        return Costs()
    memo: Dict[Tuple[str, bool, Optional[str]], Costs] = {}
    entry = comps["__entry__"]
    return _comp_costs(entry, comps, total_devices, memo)
