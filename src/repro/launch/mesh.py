"""Production mesh definition (multi-pod dry-run contract).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. Axis types go through repro.compat so the module imports
on JAX versions without jax.sharding.AxisType.
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading pure-DP 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests / reduced smoke runs)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (per-chip effective, conservative)
HBM_BYTES = 16e9              # per chip
