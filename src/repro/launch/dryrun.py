import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DRYRUN"] = "1"  # lower native bf16 dots (TPU semantics)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import — jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --sweep --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_v2_236b \
        --shape train_4k --mesh multi

Per cell it records: compiled memory_analysis (proves it fits),
cost_analysis FLOPs/bytes, the collective schedule (parsed from optimized
HLO), and the three roofline terms — into results/dryrun/<cell>.json,
which EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_report read.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_arch, shape_supported
from repro.distributed.sharding import ShardingCtx, make_rules, tree_shardings
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import (
    collective_summary,
    parse_collectives,
    roofline_terms,
)
from repro.models import build_model
from repro.train.step import (
    TrainConfig,
    build_serve_steps,
    build_train_step,
    train_state_axes,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _shaped_state(model, train_config):
    """ShapeDtypeStructs for the train state (no allocation)."""
    from repro.train.step import init_train_state

    return jax.eval_shape(
        lambda: init_train_state(model, train_config, jax.random.PRNGKey(0)))


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               overrides: dict | None = None, train_overrides: dict | None = None):
    """Lower + compile one cell; returns the result record."""
    shape = SHAPES[shape_name]
    cfg = get_arch(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    rules = make_rules(shape.kind,
                       context_parallel=(shape.name == "long_500k"))
    ctx = ShardingCtx(mesh=mesh, rules=rules)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            tc = TrainConfig(num_microbatches=cfg.num_microbatches,
                             **(train_overrides or {}))
            step_fn = build_train_step(model, tc, ctx=ctx)
            state_shapes = _shaped_state(model, tc)
            state_axes = train_state_axes(model, tc)
            batch_specs = model.input_specs(shape)
            batch_axes = model.batch_axes(shape)
            in_shardings = (
                tree_shardings(ctx, state_shapes, state_axes),
                tree_shardings(ctx, batch_specs, batch_axes),
            )
            lowered = jax.jit(step_fn, in_shardings=in_shardings,
                              donate_argnums=(0,)).lower(
                state_shapes, batch_specs)
        elif shape.kind == "prefill":
            prefill_step, _ = build_serve_steps(model, ctx=ctx)
            param_shapes = model.param_shapes()
            param_axes = model.param_axes()
            batch_specs = model.input_specs(shape)
            batch_axes = model.batch_axes(shape)
            in_shardings = (
                tree_shardings(ctx, param_shapes, param_axes),
                tree_shardings(ctx, batch_specs, batch_axes),
            )
            lowered = jax.jit(prefill_step, in_shardings=in_shardings).lower(
                param_shapes, batch_specs)
        else:  # decode
            _, decode_step = build_serve_steps(model, ctx=ctx)
            param_shapes = model.param_shapes()
            param_axes = model.param_axes()
            cache_specs = model.cache_spec(shape.global_batch, shape.seq_len)
            cache_axes = model.cache_axes()
            batch_specs = model.input_specs(shape)
            batch_axes = model.batch_axes(shape)
            in_shardings = (
                tree_shardings(ctx, param_shapes, param_axes),
                tree_shardings(ctx, cache_specs, cache_axes),
                tree_shardings(ctx, batch_specs["tokens"], batch_axes["tokens"]),
            )
            lowered = jax.jit(decode_step, in_shardings=in_shardings,
                              donate_argnums=(1,)).lower(
                param_shapes, cache_specs, batch_specs["tokens"])

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo, n_dev)
    csum = collective_summary(colls)

    # loop-corrected costs (cost_analysis counts while bodies once — see
    # hlo_costs.py); raw values retained for reference
    from repro.launch.hlo_costs import analyze_hlo

    corrected = analyze_hlo(hlo, n_dev)
    flops = corrected.flops
    hbm = corrected.traffic_bytes
    wire = corrected.wire_bytes
    terms = roofline_terms(
        flops, hbm, wire,
        peak_flops=mesh_lib.PEAK_FLOPS_BF16, hbm_bw=mesh_lib.HBM_BW,
        ici_bw=mesh_lib.ICI_BW)
    # kernel-adjusted memory term: traffic inside the tagged attention/SSD
    # scopes stays in VMEM under the validated Pallas kernels on real TPU
    # (the CPU dry-run cannot lower Mosaic, so the XLA fallback materializes
    # those intermediates; see kernels/flash_attention.py, mamba2_ssd.py)
    scoped = sum(corrected.scoped_traffic.values())
    hbm_fused = max(hbm - scoped, 0.0)
    terms_fused = roofline_terms(
        flops, hbm_fused, wire,
        peak_flops=mesh_lib.PEAK_FLOPS_BF16, hbm_bw=mesh_lib.HBM_BW,
        ici_bw=mesh_lib.ICI_BW)

    model_flops = 6 * cfg.active_param_count() * shape.seq_len * shape.global_batch
    if shape.kind == "decode":
        model_flops = 2 * cfg.active_param_count() * shape.global_batch
    if shape.kind == "prefill":
        model_flops = 2 * cfg.active_param_count() * shape.seq_len * shape.global_batch

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "ok": True,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
            "total_per_device": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                                 + ma.temp_size_in_bytes + ma.generated_code_size_in_bytes
                                 - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm,
            "raw_cost_analysis_flops": float(ca.get("flops", 0.0)),
            "raw_cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": csum,
        "wire_bytes_per_device": wire,
        "roofline": terms.to_dict(),
        "scoped_traffic": corrected.scoped_traffic,
        "roofline_kernel_fused": terms_fused.to_dict(),
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / n_dev,
        "useful_flops_fraction": (model_flops / n_dev) / flops if flops else 0.0,
        "sharding_fallbacks": sorted({f"{n}:{a}:{d}" for n, a, d in ctx.fallbacks}),
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    return record


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             overrides=None, tag: str = "") -> dict:
    mesh_tag = "multi" if multi_pod else "single"
    cell = f"{arch_id}.{shape_name}.{mesh_tag}{('.' + tag) if tag else ''}"
    supported, why = shape_supported(arch_id, shape_name)
    if not supported:
        record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                  "ok": False, "skipped": True, "reason": why}
    else:
        try:
            record = lower_cell(arch_id, shape_name, multi_pod=multi_pod,
                                overrides=overrides)
        except Exception as e:  # noqa: BLE001 — sweep must continue
            record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                      "ok": False, "skipped": False,
                      "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-4000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    status = "SKIP" if record.get("skipped") else ("OK" if record["ok"] else "FAIL")
    extra = ""
    if record.get("ok"):
        r = record["roofline"]
        extra = (f" compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s"
                 f" coll={r['collective_s']:.4f}s dom={r['dominant']}"
                 f" mem/dev={record['memory']['total_per_device']/1e9:.2f}GB"
                 f" compile={record['compile_s']:.0f}s")
    print(f"[dryrun] {cell}: {status}{extra}", flush=True)
    return record


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--sweep", action="store_true", help="all archs x shapes")
    p.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    args = p.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = ARCH_IDS if (args.sweep or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.sweep or not args.shape) else [args.shape]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
                if not rec.get("ok") and not rec.get("skipped"):
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
