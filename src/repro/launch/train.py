"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi_34b --reduced \
        --steps 100 --checkpoint-dir /tmp/ckpt

Runs reduced configs on local devices (this container) or full configs on a
real pod (same code path; the mesh comes from make_production_mesh when
--production is set).
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_arch
from repro.distributed.sharding import ShardingCtx, make_rules
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model
from repro.train.data import DataConfig
from repro.train.step import TrainConfig
from repro.train.train_loop import LoopConfig, train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi_34b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--production", action="store_true",
                   help="use the 16x16 production mesh (real pod)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    args = p.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")
    cfg = get_arch(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production else make_local_mesh())
    ctx = ShardingCtx(mesh=mesh, rules=make_rules("train"))

    tc = TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(1, args.steps // 10),
                     num_microbatches=args.microbatches,
                     grad_compression=args.grad_compression)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)
    lc = LoopConfig(total_steps=args.steps,
                    checkpoint_every=args.checkpoint_every,
                    checkpoint_dir=args.checkpoint_dir)
    with jax.set_mesh(mesh):
        result = train(model, tc, dc, lc, ctx=ctx, mesh=mesh)
    print(f"finished at step {result.final_step}; "
          f"loss {result.losses[0]:.4f} -> {result.losses[-1]:.4f}; "
          f"stragglers={len(result.straggler_events)} "
          f"resumed_from={result.resumed_from}")


if __name__ == "__main__":
    main()
