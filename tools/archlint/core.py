"""archlint core: findings, suppressions, baselines, and the pass runner.

A *finding* is (path, line, rule, message). Suppression is per-line via

    # archlint: disable=rule-id[,rule-id]  <reason>

on the offending line itself or on a standalone comment line directly above
it. A suppression with no reason text is itself reported
(``suppression-missing-reason``) so every disable stays auditable in review.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*archlint:\s*disable="
    r"([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)(.*)$")

RULE_SUPPRESSION_NO_REASON = "suppression-missing-reason"
RULE_SYNTAX_ERROR = "syntax-error"


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str          # repo-relative, forward slashes
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def baseline_key(self) -> str:
        # line numbers drift; baseline entries pin (path, rule, message)
        return f"{self.path}::{self.rule}::{self.message}"


@dataclasses.dataclass
class SourceFile:
    """One parsed module, shared by every pass (parse once)."""

    path: Path          # absolute
    rel: str            # repo-relative display path
    text: str
    lines: List[str]
    tree: Optional[ast.Module]          # None when the file fails to parse
    syntax_error: Optional[str] = None

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text(encoding="utf-8")
        try:
            rel = str(path.resolve().relative_to(root.resolve()))
        except ValueError:
            rel = str(path)
        rel = rel.replace("\\", "/")
        try:
            tree = ast.parse(text, filename=str(path))
            err = None
        except SyntaxError as e:
            tree, err = None, f"{e.msg} (line {e.lineno})"
        return cls(path=path, rel=rel, text=text,
                   lines=text.splitlines(), tree=tree, syntax_error=err)

    # -- suppressions --------------------------------------------------------
    def suppressions(self) -> Dict[int, Set[str]]:
        """line -> suppressed rule ids (covering that line)."""
        out: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out.setdefault(i, set()).update(rules)
            if line.lstrip().startswith("#"):
                # standalone comment: covers the next non-comment line (the
                # reason text may continue over several comment lines)
                j = i + 1
                while j <= len(self.lines) \
                        and self.lines[j - 1].lstrip().startswith("#"):
                    out.setdefault(j, set()).update(rules)
                    j += 1
                out.setdefault(j, set()).update(rules)
        return out

    def suppression_reason_findings(self) -> List[Finding]:
        out = []
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if m and not m.group(2).strip():
                out.append(Finding(
                    self.rel, i, RULE_SUPPRESSION_NO_REASON,
                    "archlint disable comment has no reason string"))
        return out


def collect_files(root: Path, sub: str = "") -> List[Path]:
    base = root / sub if sub else root
    if base.is_file():
        return [base]
    return sorted(p for p in base.rglob("*.py") if p.is_file())


def load_sources(paths: Iterable[Path], root: Path) -> List[SourceFile]:
    return [SourceFile.load(p, root) for p in paths]


def filter_suppressed(findings: Sequence[Finding],
                      sources: Sequence[SourceFile]) -> List[Finding]:
    by_rel = {s.rel: s.suppressions() for s in sources}
    kept = []
    for f in findings:
        rules = by_rel.get(f.path, {}).get(f.line, set())
        if f.rule in rules or "all" in rules:
            continue
        kept.append(f)
    return kept


def load_baseline(path: Path) -> Set[str]:
    """Baseline file: one ``Finding.baseline_key()`` per line; '#' comments."""
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text(encoding="utf-8").splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


# ---------------------------------------------------------------------------
# Pass runner
# ---------------------------------------------------------------------------


def analyze_paths(
    root: Path,
    paths: Optional[Sequence[Path]] = None,
    *,
    fast: bool = False,
    diff_base: Optional[str] = "HEAD",
) -> Tuple[List[Finding], List[SourceFile]]:
    """Run every pass over ``paths`` (default: src/repro under root).

    ``fast`` skips the git-diff schema check (the only subprocess) — the
    syntax-only mode ``make smoke`` runs. Returns (unsuppressed findings,
    parsed sources).
    """
    from archlint import (
        chaos_pass,
        error_pass,
        lock_pass,
        retrace_pass,
        schema_pass,
    )

    if paths is None:
        paths = collect_files(root, "src/repro")
    sources = load_sources(paths, root)

    findings: List[Finding] = []
    for s in sources:
        if s.syntax_error is not None:
            findings.append(Finding(s.rel, 1, RULE_SYNTAX_ERROR,
                                    f"cannot parse: {s.syntax_error}"))
        findings.extend(s.suppression_reason_findings())
    parsed = [s for s in sources if s.tree is not None]

    findings.extend(lock_pass.run(parsed))
    findings.extend(retrace_pass.run(parsed))
    findings.extend(schema_pass.run(
        parsed, root=root, diff_base=None if fast else diff_base))
    findings.extend(error_pass.run(parsed))
    findings.extend(chaos_pass.run(parsed))

    findings = filter_suppressed(findings, parsed)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, sources
