"""archlint command line.

    python tools/archlint [--fast] [--baseline tools/archlint/baseline.txt]
                          [--diff-base REF] [paths...]

Exit status: 0 when every finding is suppressed or baselined, 1 otherwise
(and 2 on usage errors). ``--fast`` skips the git subprocess (the schema
version diff) so ``make smoke`` stays instant; every AST pass still runs.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import List, Optional

REPO_ROOT = Path(__file__).resolve().parent.parent.parent
if str(REPO_ROOT / "tools") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "tools"))

from archlint import core  # noqa: E402


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="archlint", description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: src/repro)")
    ap.add_argument("--fast", action="store_true",
                    help="skip the git-based schema-version check")
    ap.add_argument("--baseline", type=Path,
                    default=REPO_ROOT / "tools" / "archlint" / "baseline.txt",
                    help="accepted-findings file (default: the checked-in "
                         "baseline, which must stay empty)")
    ap.add_argument("--diff-base", default="HEAD",
                    help="git ref for the schema-version diff (default HEAD)")
    ap.add_argument("--root", type=Path, default=REPO_ROOT)
    args = ap.parse_args(argv)

    t0 = time.monotonic()
    paths = None
    if args.paths:
        paths = []
        for p in args.paths:
            paths.extend(core.collect_files(args.root, p))
    findings, _sources = core.analyze_paths(
        args.root, paths, fast=args.fast, diff_base=args.diff_base)

    baseline = core.load_baseline(args.baseline)
    new = [f for f in findings if f.baseline_key() not in baseline]
    for f in new:
        print(f.render())
    dt = time.monotonic() - t0
    n_base = len(findings) - len(new)
    tail = f" ({n_base} baselined)" if n_base else ""
    print(f"archlint: {len(new)} finding(s){tail} in {dt:.2f}s",
          file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
