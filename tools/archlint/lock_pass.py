"""Lock-discipline pass (rules ``lock-*``, ``queue-*``, ``unguarded-*``).

Scope: the sharded service tier (``src/repro/service/``; any file defining
classes that hold ``threading`` primitives is analyzed the same way, so the
fixture tests can exercise the rules on synthetic modules).

Model
-----
* A *lock node* is ``(ClassName, attr)`` for ``self.attr = threading.Lock()/
  RLock()/Condition()`` (or the ``_lockwitness.make_*`` factories), plus
  ``(ClassName, method())`` for lock-returning helper methods (name contains
  "lock", e.g. ``VizierService._study_lock``) used as a ``with`` context.
* Intraprocedural ``with`` tracking gives the held-lock stack at every call
  site; an interprocedural fixpoint over resolvable calls (``self.m()``,
  ``self.attr.m()`` with the attr's class inferred from ``__init__``
  annotations or direct construction, ``super().m()``) propagates which
  locks each method eventually acquires and whether it may block.

Rules
-----
* ``lock-order-cycle``      — the "A held while acquiring B" graph has a
  cycle (includes a self-acquire of a non-reentrant Lock).
* ``lock-blocking-call``    — a blocking operation (time.sleep, socket
  send/recv, RPC call, thread join, Event.wait without timeout, logging
  I/O, a Pythia dispatch) while holding a lock. Waiting on the condition
  variable you hold is the sanctioned exception.
* ``queue-datastore-call``  — a datastore method invoked while holding a
  work-queue lock (the queue CV is the service's hottest lock; datastore
  I/O under it serializes every shard).
* ``unguarded-study-write`` — in classes with a per-study lock helper, a
  study/trial read-modify-write datastore call outside any study-lock
  block (methods named ``*_locked`` assert the caller holds it and are
  exempt).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from archlint.core import Finding, SourceFile

RULE_ORDER = "lock-order-cycle"
RULE_BLOCKING = "lock-blocking-call"
RULE_QUEUE_DS = "queue-datastore-call"
RULE_UNGUARDED = "unguarded-study-write"

LOCK_FACTORY_NAMES = {"Lock": "lock", "RLock": "rlock",
                      "Condition": "condition", "Semaphore": "lock",
                      "BoundedSemaphore": "lock"}
WITNESS_FACTORIES = {"make_lock": "lock", "make_rlock": "rlock",
                     "make_condition": "condition"}

# datastore RMW writes that must run under the per-study lock
STUDY_WRITE_METHODS = {"update_study", "update_trial", "apply_metadata_delta"}

LOG_METHODS = {"debug", "info", "warning", "error", "exception", "critical"}
SOCKET_METHODS = {"sendall", "sendto", "recv", "recv_into", "connect",
                  "accept", "makefile"}
RPC_RECEIVER_HINTS = ("rpc", "client", "transport", "pythia", "channel",
                     "stub")

LockNode = Tuple[str, str]  # (class name, attr or "method()")


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """x.y.z -> ["x", "y", "z"]; None for anything not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def _is_lock_factory(call: ast.AST) -> Optional[str]:
    """threading.Lock() / Lock() / _lockwitness.make_lock(...) -> kind."""
    if not isinstance(call, ast.Call):
        return None
    chain = _attr_chain(call.func)
    if not chain:
        return None
    name = chain[-1]
    if name in LOCK_FACTORY_NAMES:
        return LOCK_FACTORY_NAMES[name]
    if name in WITNESS_FACTORIES:
        return WITNESS_FACTORIES[name]
    return None


@dataclasses.dataclass
class ClassInfo:
    name: str
    rel: str
    bases: List[str]
    lock_attrs: Dict[str, str]                  # attr -> kind
    attr_types: Dict[str, str]                  # attr -> class name
    lock_methods: Set[str]                      # lock-returning helpers
    methods: Dict[str, ast.FunctionDef]


@dataclasses.dataclass
class MethodSummary:
    qual: Tuple[str, str]                       # (class, method)
    rel: str
    acquires: Set[LockNode] = dataclasses.field(default_factory=set)
    # blocking ops reachable in this method when *no* lock is required:
    # (reason, rel, line)
    blocking: Set[Tuple[str, str, int]] = dataclasses.field(default_factory=set)
    # (held locks at site, callee key, rel, line)
    calls: List[Tuple[Tuple[LockNode, ...], Tuple[str, str], str, int]] = \
        dataclasses.field(default_factory=list)
    # direct edges recorded while analyzing: (held, acquired, rel, line)
    edges: List[Tuple[LockNode, LockNode, str, int]] = \
        dataclasses.field(default_factory=list)
    # direct blocking ops observed under a held lock: (held, reason, line)
    blocked_sites: List[Tuple[LockNode, str, str, int]] = \
        dataclasses.field(default_factory=list)


def _collect_classes(sources: Sequence[SourceFile]) -> Dict[str, ClassInfo]:
    classes: Dict[str, ClassInfo] = {}
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            methods = {
                n.name: n for n in node.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            lock_attrs: Dict[str, str] = {}
            attr_types: Dict[str, str] = {}
            for fn in methods.values():
                # param annotations: def __init__(self, ds: Datastore)
                ann: Dict[str, str] = {}
                for arg in fn.args.args + fn.args.kwonlyargs:
                    if arg.annotation is not None:
                        chain = _attr_chain(arg.annotation)
                        if chain:
                            ann[arg.arg] = chain[-1]
                for stmt in ast.walk(fn):
                    if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                        continue
                    tgt = stmt.targets[0]
                    if not (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        continue
                    kind = _is_lock_factory(stmt.value)
                    if kind:
                        lock_attrs[tgt.attr] = kind
                        continue
                    if isinstance(stmt.value, ast.Name) and stmt.value.id in ann:
                        attr_types[tgt.attr] = ann[stmt.value.id]
                    elif isinstance(stmt.value, ast.Call):
                        chain = _attr_chain(stmt.value.func)
                        if chain and chain[-1][:1].isupper():
                            attr_types[tgt.attr] = chain[-1]
            lock_methods = {
                name for name, fn in methods.items()
                if "lock" in name.lower() and _returns_lockish(fn)
            }
            bases = []
            for b in node.bases:
                chain = _attr_chain(b)
                if chain:
                    bases.append(chain[-1])
            classes[node.name] = ClassInfo(
                name=node.name, rel=src.rel, bases=bases,
                lock_attrs=lock_attrs, attr_types=attr_types,
                lock_methods=lock_methods, methods=methods)
    return classes


def _returns_lockish(fn: ast.FunctionDef) -> bool:
    """Heuristic: the helper hands out a threading primitive."""
    if fn.returns is not None:
        chain = _attr_chain(fn.returns)
        if chain and chain[-1] in LOCK_FACTORY_NAMES:
            return True
    for node in ast.walk(fn):
        if _is_lock_factory(node):
            return True
    return False


def _subclass_map(classes: Dict[str, ClassInfo]) -> Dict[str, Set[str]]:
    subs: Dict[str, Set[str]] = {name: {name} for name in classes}
    changed = True
    while changed:
        changed = False
        for info in classes.values():
            for base in info.bases:
                if base in subs and info.name not in subs[base]:
                    subs[base].add(info.name)
                    changed = True
                # transitive: everything under info.name flows up to base
                if base in subs and not subs[info.name] <= subs[base]:
                    subs[base] |= subs[info.name]
                    changed = True
    return subs


class _MethodAnalyzer(ast.NodeVisitor):
    """Walks one method tracking the held-lock stack."""

    def __init__(self, cls: ClassInfo, fn: ast.FunctionDef, rel: str,
                 classes: Dict[str, ClassInfo]):
        self.cls = cls
        self.fn = fn
        self.rel = rel
        self.classes = classes
        self.held: List[Tuple[LockNode, str]] = []   # (node, kind)
        self.summary = MethodSummary(qual=(cls.name, fn.name), rel=rel)

    # -- lock-expression classification -------------------------------------
    def _lock_of_expr(self, expr: ast.AST) -> Optional[Tuple[LockNode, str]]:
        chain = _attr_chain(expr)
        if chain and len(chain) == 2 and chain[0] == "self":
            attr = chain[1]
            if attr in self.cls.lock_attrs:
                return (self.cls.name, attr), self.cls.lock_attrs[attr]
        if isinstance(expr, ast.Call):
            chain = _attr_chain(expr.func)
            if (chain and len(chain) == 2 and chain[0] == "self"
                    and chain[1] in self.cls.lock_methods):
                return (self.cls.name, chain[1] + "()"), "lock"
        return None

    def _record_acquire(self, node: LockNode, kind: str, line: int) -> None:
        self.summary.acquires.add(node)
        for held, held_kind in self.held:
            if held == node:
                if kind == "lock" and held_kind == "lock":
                    self.summary.edges.append((held, node, self.rel, line))
                continue
            self.summary.edges.append((held, node, self.rel, line))

    # -- blocking classification --------------------------------------------
    def _blocking_reason(self, call: ast.Call) -> Optional[str]:
        func = call.func
        if isinstance(func, ast.Name):
            if func.id == "sleep":
                return "time.sleep"
            if func.id == "input":
                return "console input"
            return None
        if not isinstance(func, ast.Attribute):
            return None
        attr = func.attr
        recv = func.value
        recv_chain = _attr_chain(recv)
        if attr == "sleep" and recv_chain == ["time"]:
            return "time.sleep"
        if attr in SOCKET_METHODS:
            return f"socket .{attr}()"
        if attr == "join":
            if isinstance(recv, ast.Constant):
                return None                      # ",".join / b"".join
            if recv_chain and "path" in recv_chain:
                return None                      # os.path.join
            return "blocking .join()"
        if attr == "wait":
            held_exprs = {h for h, _ in self.held}
            lockish = self._lock_of_expr(recv)
            if lockish is not None and lockish[0] in held_exprs:
                return None                      # cv.wait on the held CV
            has_timeout = bool(call.args) or any(
                kw.arg == "timeout" for kw in call.keywords)
            if has_timeout:
                return None                      # bounded wait
            return "unbounded .wait()"
        if attr in {"call", "call_many"} and recv_chain:
            leaf = recv_chain[-1].lower()
            if any(h in leaf for h in RPC_RECEIVER_HINTS):
                return "RPC send"
        if attr in {"suggest", "suggest_batch", "early_stop"} and recv_chain:
            if recv_chain[-1] in {"_pythia", "pythia"}:
                return "Pythia dispatch"
        if attr in LOG_METHODS and recv_chain:
            if recv_chain[0] in {"log", "logger", "logging"}:
                return f"logging I/O (log.{attr})"
        return None

    # -- callee resolution ---------------------------------------------------
    def _callee_key(self, call: ast.Call) -> Optional[Tuple[str, str]]:
        """("self", m) | ("super", m) | (AttrType, m).

        Self/super calls are resolved context-sensitively later — the
        receiver class constrains dispatch, which is what keeps sibling
        subclasses (the two datastore backends) from creating phantom
        cross-backend lock edges.
        """
        func = call.func
        if not isinstance(func, ast.Attribute):
            return None
        if (isinstance(func.value, ast.Call)
                and isinstance(func.value.func, ast.Name)
                and func.value.func.id == "super"):
            return ("super", func.attr)
        chain = _attr_chain(func.value)
        if not chain or chain[0] != "self":
            return None
        if len(chain) == 1:
            return ("self", func.attr)
        if len(chain) == 2:
            t = self.cls.attr_types.get(chain[1])
            if t:
                return (t, func.attr)
        return None

    # -- visitors ------------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            # the context expression runs BEFORE the lock is held
            self.visit(item.context_expr)
            lockish = self._lock_of_expr(item.context_expr)
            if lockish is not None:
                ln, kind = lockish
                self._record_acquire(ln, kind, item.context_expr.lineno)
                self.held.append((ln, kind))
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.held.pop()

    def visit_Call(self, node: ast.Call) -> None:
        line = node.lineno
        # .acquire() outside a with-statement: record the ordering edge
        if isinstance(node.func, ast.Attribute) and node.func.attr == "acquire":
            lockish = self._lock_of_expr(node.func.value)
            if lockish is not None:
                self._record_acquire(lockish[0], lockish[1], line)
        reason = self._blocking_reason(node)
        if reason is not None:
            if self.held:
                self.summary.blocked_sites.append(
                    (self.held[-1][0], reason, self.rel, line))
            else:
                self.summary.blocking.add((reason, self.rel, line))
        key = self._callee_key(node)
        if key is not None:
            held = tuple(h for h, _ in self.held)
            self.summary.calls.append((held, key, self.rel, line))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is not self.fn:
            return              # nested defs analyzed only if called — skip
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def _mro(cname: str, classes: Dict[str, ClassInfo]) -> List[str]:
    """DFS first-wins linearization over the classes we can see."""
    out: List[str] = []

    def walk(c: str) -> None:
        if c in out or c not in classes:
            return
        out.append(c)
        for b in classes[c].bases:
            walk(b)

    walk(cname)
    return out


def _lookup(receiver: str, method: str,
            classes: Dict[str, ClassInfo]) -> Optional[str]:
    for c in _mro(receiver, classes):
        if method in classes[c].methods:
            return c
    return None


Ctx = Tuple[str, str, str]  # (receiver class, defining class, method)


def _targets(ctx: Ctx, key: Tuple[str, str], classes: Dict[str, ClassInfo],
             subs: Dict[str, Set[str]]) -> List[Ctx]:
    """Resolve a call key in a receiver context.

    The receiver class constrains dispatch: ``self.m()`` with receiver R
    runs exactly R's implementation of m (each concrete class gets its own
    top-level context, so subclass overrides are covered there) — this is
    what keeps sibling subclasses, e.g. the two datastore backends, from
    creating phantom cross-backend lock edges.
    """
    receiver, definer, _ = ctx
    kind, m = key
    out: List[Ctx] = []
    if kind == "self":
        d = _lookup(receiver, m, classes)
        if d is not None:
            out.append((receiver, d, m))
    elif kind == "super":
        chain = _mro(definer, classes)
        for c in chain[1:]:
            if m in classes[c].methods:
                out.append((receiver, c, m))
                break
    else:
        for r in sorted(subs.get(kind, set())):
            d = _lookup(r, m, classes)
            if d is not None:
                out.append((r, d, m))
    return out


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    classes = _collect_classes(sources)
    subs = _subclass_map(classes)

    summaries: Dict[Tuple[str, str], MethodSummary] = {}
    for src in sources:
        for cname, info in classes.items():
            if info.rel != src.rel:
                continue
            for mname, fn in info.methods.items():
                an = _MethodAnalyzer(info, fn, src.rel, classes)
                an.visit(fn)
                summaries[(cname, mname)] = an.summary

    contexts: List[Ctx] = [
        (r, c, m)
        for r in classes
        for c in _mro(r, classes)
        for m in classes[c].methods
    ]

    # fixpoint: transitive acquisitions + blocking reachability per context
    acquires: Dict[Ctx, Set[LockNode]] = {
        ctx: set(summaries[(ctx[1], ctx[2])].acquires) for ctx in contexts}
    blocking: Dict[Ctx, Set[Tuple[str, str, int]]] = {
        ctx: set(summaries[(ctx[1], ctx[2])].blocking) for ctx in contexts}
    changed = True
    while changed:
        changed = False
        for ctx in contexts:
            s = summaries[(ctx[1], ctx[2])]
            for _held, key, _rel, _line in s.calls:
                for tgt in _targets(ctx, key, classes, subs):
                    if tgt == ctx or tgt not in acquires:
                        continue
                    if not acquires[tgt] <= acquires[ctx]:
                        acquires[ctx] |= acquires[tgt]
                        changed = True
                    if not blocking[tgt] <= blocking[ctx]:
                        blocking[ctx] |= blocking[tgt]
                        changed = True

    findings: Set[Finding] = set()
    edges: Dict[Tuple[LockNode, LockNode], Tuple[str, int]] = {}

    def note_edge(a: LockNode, b: LockNode, rel: str, line: int) -> None:
        edges.setdefault((a, b), (rel, line))

    kind_of: Dict[LockNode, str] = {}
    for info in classes.values():
        for attr, kind in info.lock_attrs.items():
            kind_of[(info.name, attr)] = kind
        for m in info.lock_methods:
            kind_of[(info.name, m + "()")] = "lock"

    # direct (intraprocedural) evidence: once per method body
    for s in summaries.values():
        for a, b, rel, line in s.edges:
            note_edge(a, b, rel, line)
        for held, reason, rel, line in s.blocked_sites:
            findings.add(Finding(
                rel, line, RULE_BLOCKING,
                f"{reason} while holding {held[0]}.{held[1]}"))

    # interprocedural evidence: per receiver context
    for ctx in contexts:
        s = summaries[(ctx[1], ctx[2])]
        for held, key, rel, line in s.calls:
            if not held:
                continue
            callee_acq: Set[LockNode] = set()
            callee_blk: Set[Tuple[str, str, int]] = set()
            callee_desc = key[1]
            for tgt in _targets(ctx, key, classes, subs):
                if tgt in acquires:
                    callee_acq |= acquires[tgt]
                    callee_blk |= blocking[tgt]
                    callee_desc = f"{tgt[1]}.{key[1]}"
            for acq in callee_acq:
                for h in held:
                    if h == acq:
                        if kind_of.get(acq) == "lock":
                            note_edge(h, acq, rel, line)
                        continue
                    note_edge(h, acq, rel, line)
            for reason, brel, bline in callee_blk:
                findings.add(Finding(
                    rel, line, RULE_BLOCKING,
                    f"call to {callee_desc} may block ({reason} at "
                    f"{brel}:{bline}) while holding "
                    f"{held[-1][0]}.{held[-1][1]}"))
            queue_held = [h for h in held if "Queue" in h[0]]
            if queue_held and _is_datastore_key(key, ctx[1], classes):
                findings.add(Finding(
                    rel, line, RULE_QUEUE_DS,
                    f"datastore call {callee_desc} under queue lock "
                    f"{queue_held[-1][0]}.{queue_held[-1][1]}"))

    # lock-order cycles over the merged edge graph
    graph: Dict[LockNode, Set[LockNode]] = {}
    for (a, b), _site in edges.items():
        graph.setdefault(a, set()).add(b)
    for cycle in _find_cycles(graph):
        pairs = list(zip(cycle, cycle[1:] + cycle[:1]))
        rel, line = edges[pairs[0]]
        desc = " -> ".join(f"{c}.{a}" for c, a in cycle + [cycle[0]])
        findings.add(Finding(
            rel, line, RULE_ORDER, f"lock-order cycle: {desc}"))

    # unguarded study writes (classes exposing a per-study lock helper)
    for (cname, mname), s in summaries.items():
        info = classes[cname]
        if not any(m.startswith("_study_lock") for m in info.lock_methods):
            continue
        if mname.endswith("_locked") or mname.startswith("__"):
            continue
        study_nodes = {(cname, m + "()") for m in info.lock_methods}
        for held, key, rel, line in s.calls:
            if key[1] not in STUDY_WRITE_METHODS:
                continue
            if not _is_datastore_key(key, cname, classes):
                continue
            if any(h in study_nodes for h in held):
                continue
            findings.add(Finding(
                rel, line, RULE_UNGUARDED,
                f"{key[1]} read-modify-write outside the per-study lock "
                f"(take self._study_lock or rename the method *_locked)"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))


def _is_datastore_key(key: Tuple[str, str], caller_cls: str,
                      classes: Dict[str, ClassInfo]) -> bool:
    t = caller_cls if key[0] in {"self", "super"} else key[0]
    if "Datastore" in t:
        return True
    info = classes.get(t)
    if not info:
        return False
    return any("Datastore" in c for c in _mro(t, classes))


def _find_cycles(graph: Dict[LockNode, Set[LockNode]]
                 ) -> List[List[LockNode]]:
    """Simple cycles via DFS; self-loops included. Deduplicated by node set."""
    cycles: List[List[LockNode]] = []
    seen_sets: Set[frozenset] = set()
    nodes = sorted(set(graph) | {b for vs in graph.values() for b in vs})
    for start in nodes:
        stack: List[LockNode] = []
        on_stack: Set[LockNode] = set()

        def dfs(n: LockNode) -> None:
            stack.append(n)
            on_stack.add(n)
            for m in sorted(graph.get(n, ())):
                if m == start and stack:
                    key = frozenset(stack)
                    if key not in seen_sets:
                        seen_sets.add(key)
                        cycles.append(list(stack))
                elif m not in on_stack and m > start:
                    dfs(m)
            stack.pop()
            on_stack.discard(n)

        dfs(start)
    return cycles
