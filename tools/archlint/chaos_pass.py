"""Chaos-hook discipline pass.

The fault-injection seams (``chaos.inject(...)`` call sites) must be free
when ``CHAOS_SEED`` is unset and must never run under a held lock: the
injected action may sleep for a configured delay or raise, and doing either
inside a critical section turns a *simulated* slow network into a *real*
stalled service (every other thread queues on the lock behind the sleeping
one — a failure mode the chaos run is supposed to surface in the system
under test, not create in the harness).

Rules
-----
* ``chaos-call-under-lock`` — a ``chaos.inject(...)`` (or imported
  ``inject(...)``) call lexically inside a ``with`` block whose context
  expression looks lock-like (source mentions ``lock``/``_cv``/``guard``/
  ``cond``). Decisions belong under the lock only inside the injector
  itself; every seam in the service tier injects after release. The two
  transport sends in ``rpc.py`` carry sanctioned suppressions: the socket
  lock there serializes a *single peer connection*, not shared service
  state, and the framing protocol cannot tolerate an interleaved writer.
* ``chaos-ungated-hook``   — the module-level ``inject`` hook in
  ``chaos.py`` must open with the ``if _injector is None: return`` guard,
  so with no injector installed every seam is two loads and a branch
  (dead code, no lock taken, nothing allocated).

Scope: every analyzed file for ``chaos-call-under-lock`` except
``chaos.py`` itself; ``chaos.py`` (by basename) for ``chaos-ungated-hook``.
Nested ``def``/``lambda`` bodies inside a lock-holding ``with`` are *not*
flagged — they run when called, not while the lock is held.
"""

from __future__ import annotations

import ast
from typing import List, Sequence

from archlint.core import Finding, SourceFile

RULE_UNDER_LOCK = "chaos-call-under-lock"
RULE_UNGATED = "chaos-ungated-hook"

_LOCKY_SUBSTRINGS = ("lock", "_cv", "guard", "cond")


def _expr_src(src: SourceFile, node: ast.AST) -> str:
    seg = ast.get_source_segment(src.text, node)
    if seg is None:
        try:
            seg = ast.unparse(node)
        except Exception:
            seg = ""
    return seg


def _looks_locky(text: str) -> bool:
    low = text.lower()
    return any(s in low for s in _LOCKY_SUBSTRINGS)


def _is_inject_call(node: ast.Call) -> bool:
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == "inject":
        return isinstance(f.value, ast.Name) and f.value.id == "chaos"
    return isinstance(f, ast.Name) and f.id == "inject"


def _find_under_lock(src: SourceFile) -> List[Finding]:
    out: List[Finding] = []

    def scan(node: ast.AST, under_lock: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                # A nested callable's body executes later, not under the
                # enclosing lock; restart with a clean flag.
                scan(child, False)
                continue
            child_locked = under_lock
            if isinstance(child, (ast.With, ast.AsyncWith)):
                for item in child.items:
                    if _looks_locky(_expr_src(src, item.context_expr)):
                        child_locked = True
                        break
            if isinstance(child, ast.Call) and _is_inject_call(child) \
                    and under_lock:
                out.append(Finding(
                    src.rel, child.lineno, RULE_UNDER_LOCK,
                    "chaos.inject() under a held lock: injected delays/"
                    "raises stall every thread queued on the lock; move "
                    "the seam outside the critical section"))
            scan(child, child_locked)

    scan(src.tree, False)
    return out


def _guard_is_injector_none(stmt: ast.stmt) -> bool:
    """Match ``if _injector is None: return`` (optionally ``return None``)."""
    if not isinstance(stmt, ast.If) or stmt.orelse:
        return False
    t = stmt.test
    if not (isinstance(t, ast.Compare) and len(t.ops) == 1
            and isinstance(t.ops[0], ast.Is)
            and isinstance(t.left, ast.Name) and t.left.id == "_injector"
            and len(t.comparators) == 1
            and isinstance(t.comparators[0], ast.Constant)
            and t.comparators[0].value is None):
        return False
    body = stmt.body
    return len(body) == 1 and isinstance(body[0], ast.Return) and (
        body[0].value is None
        or (isinstance(body[0].value, ast.Constant)
            and body[0].value.value is None))


def _find_ungated_hook(src: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    for node in src.tree.body:
        if not (isinstance(node, ast.FunctionDef) and node.name == "inject"):
            continue
        body = list(node.body)
        if body and isinstance(body[0], ast.Expr) \
                and isinstance(body[0].value, ast.Constant) \
                and isinstance(body[0].value.value, str):
            body = body[1:]  # docstring
        if not body or not _guard_is_injector_none(body[0]):
            out.append(Finding(
                src.rel, node.lineno, RULE_UNGATED,
                "inject() must begin with the 'if _injector is None: "
                "return' guard so chaos seams are dead code when "
                "CHAOS_SEED is unset"))
    return out


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        if src.tree is None:
            continue
        name = src.path.name
        if name == "chaos.py":
            findings.extend(_find_ungated_hook(src))
        else:
            findings.extend(_find_under_lock(src))
    return findings
