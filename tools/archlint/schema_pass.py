"""Schema/namespace pass.

Two rules pinning the ROADMAP "State schema discipline" invariant:

* ``reserved-namespace-write`` — a ``repro.*`` namespace literal appearing
  anywhere outside the whitelisted policy-state module
  (``src/repro/pythia/state.py``). The reserved prefix is the built-in
  policies' private storage; external code writing there can corrupt
  warm-start blobs that loaders must then treat as hostile.
* ``schema-version-bump``      — git-diff-aware: the serialized-field set
  of ``PolicyState`` in ``pythia/state.py`` changed relative to the diff
  base but ``STATE_SCHEMA_VERSION`` did not. Runs only when a diff base
  is given (the CLI skips it in ``--fast`` mode and when the tree is not
  a git checkout).
"""

from __future__ import annotations

import ast
import re
import subprocess
from pathlib import Path
from typing import List, Optional, Sequence, Set, Tuple

from archlint.core import Finding, SourceFile

RULE_NAMESPACE = "reserved-namespace-write"
RULE_VERSION = "schema-version-bump"

RESERVED_RE = re.compile(r"^repro\.[A-Za-z0-9_.]*$")
STATE_REL = "src/repro/pythia/state.py"
NAMESPACE_WHITELIST = {STATE_REL}
STATE_CLASS = "PolicyState"
VERSION_NAME = "STATE_SCHEMA_VERSION"


def _docstring_linenos(tree: ast.Module) -> Set[int]:
    out: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = node.body
            if body and isinstance(body[0], ast.Expr) \
                    and isinstance(body[0].value, ast.Constant) \
                    and isinstance(body[0].value.value, str):
                c = body[0].value
                end = getattr(c, "end_lineno", c.lineno) or c.lineno
                out.update(range(c.lineno, end + 1))
    return out


def _repro_packages(root: Path) -> Set[str]:
    """Subpackage names under src/repro — ``"repro.configs.base"`` is an
    import path, not a metadata namespace, and must not be flagged."""
    pkg = root / "src" / "repro"
    if not pkg.is_dir():
        return set()
    return {p.name for p in pkg.iterdir() if p.is_dir()} | \
        {p.stem for p in pkg.glob("*.py")}


def _namespace_findings(src: SourceFile, packages: Set[str]) -> List[Finding]:
    if src.rel in NAMESPACE_WHITELIST or src.rel.endswith("/state.py"):
        return []
    docs = _docstring_linenos(src.tree)
    out: List[Finding] = []
    for node in ast.walk(src.tree):
        if not (isinstance(node, ast.Constant) and isinstance(node.value, str)):
            continue
        if node.lineno in docs:
            continue
        head = node.value.split(".")[1] if "." in node.value else ""
        if head in packages:
            continue
        if RESERVED_RE.match(node.value):
            out.append(Finding(
                src.rel, node.lineno, RULE_NAMESPACE,
                f'"{node.value}" is in the reserved repro.* namespace; '
                f"only {STATE_REL} may name it (store external state "
                f"under your own prefix)"))
    return out


def _state_signature(text: str) -> Optional[Tuple[Tuple[str, ...], object]]:
    """(sorted PolicyState field names, STATE_SCHEMA_VERSION value)."""
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return None
    fields: List[str] = []
    version: object = None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == STATE_CLASS:
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) \
                        and isinstance(stmt.target, ast.Name):
                    fields.append(stmt.target.id)
                elif isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            fields.append(t.id)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == VERSION_NAME \
                        and isinstance(node.value, ast.Constant):
                    version = node.value.value
    return tuple(sorted(fields)), version


def _git_show(root: Path, ref: str, rel: str) -> Optional[str]:
    try:
        proc = subprocess.run(
            ["git", "show", f"{ref}:{rel}"], cwd=root,
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    return proc.stdout if proc.returncode == 0 else None


def _version_line(src: SourceFile) -> int:
    for node in src.tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == VERSION_NAME:
                    return node.lineno
    return 1


def _version_findings(sources: Sequence[SourceFile], root: Path,
                      diff_base: str) -> List[Finding]:
    state = next((s for s in sources if s.rel == STATE_REL), None)
    if state is None:
        return []
    base_text = _git_show(root, diff_base, STATE_REL)
    if base_text is None:
        return []                      # no git / file new at base: nothing to diff
    base_sig = _state_signature(base_text)
    cur_sig = _state_signature(state.text)
    if base_sig is None or cur_sig is None:
        return []
    base_fields, base_ver = base_sig
    cur_fields, cur_ver = cur_sig
    if base_fields != cur_fields and base_ver == cur_ver:
        added = sorted(set(cur_fields) - set(base_fields))
        removed = sorted(set(base_fields) - set(cur_fields))
        delta = []
        if added:
            delta.append("added " + ", ".join(added))
        if removed:
            delta.append("removed " + ", ".join(removed))
        return [Finding(
            state.rel, _version_line(state), RULE_VERSION,
            f"{STATE_CLASS} serialized fields changed vs {diff_base} "
            f"({'; '.join(delta)}) without a {VERSION_NAME} bump "
            f"(still {cur_ver!r})")]
    return []


def run(sources: Sequence[SourceFile], *, root: Path,
        diff_base: Optional[str] = "HEAD") -> List[Finding]:
    findings: List[Finding] = []
    packages = _repro_packages(root)
    for src in sources:
        findings.extend(_namespace_findings(src, packages))
    if diff_base is not None:
        findings.extend(_version_findings(sources, root, diff_base))
    return findings
