"""Error-discipline pass over the per-item isolation paths.

Scope: the service modules where one item's failure must become a status
code on that item and nothing else (``operations.py``, ``vizier_service.py``,
``work_queue.py``, ``pythia_service.py``, ``rpc.py``) — plus any fixture
module handed in (scoping is by basename so tests can exercise the rules).

Rules
-----
* ``bare-except``           — ``except:`` or ``except BaseException:`` in an
  isolation path; it catches ``KeyboardInterrupt``/``SystemExit`` and hides
  which status the item should carry.
* ``swallowed-status-code`` — an ``except Exception`` handler that
  hard-codes ``StatusCode.INTERNAL`` without consulting the exception's
  carried code (``e.code`` / ``getattr(e, "code", ...)`` /
  ``fail_operation_from_exception`` / ``_fail_op``). Policy-construction
  errors carry ``INVALID_ARGUMENT``; collapsing them to ``INTERNAL`` turns
  a permanent client error into something retried forever.
* ``unmapped-service-raise``— a ``raise X(...)`` inside an RPC handler
  (PascalCase method of a service class) where ``X`` does not carry a
  gRPC-style code (no ``code`` attribute statically visible). Handlers
  raise ``VizierRpcError`` (or a carrier) so ``Servicer.dispatch`` can map
  the failure; anything else surfaces as an anonymous ``INTERNAL``.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from archlint.core import Finding, SourceFile

RULE_BARE = "bare-except"
RULE_SWALLOW = "swallowed-status-code"
RULE_UNMAPPED = "unmapped-service-raise"

ISOLATION_BASENAMES = {
    "operations.py", "vizier_service.py", "work_queue.py",
    "pythia_service.py", "rpc.py",
}

# builtins the dispatch layer has no mapping for (ValueError et al. become
# INTERNAL); NotImplementedError is the abstract-method marker and exempt.
EXEMPT_RAISES = {"NotImplementedError", "StopIteration"}

CODE_CONSULT_CALLS = {"fail_operation_from_exception", "_fail_op"}


def _code_carrier_classes(sources: Sequence[SourceFile]) -> Set[str]:
    """Exception classes that statically carry a ``code`` attribute."""
    carriers: Set[str] = {"VizierRpcError"}
    by_name = {}
    for src in sources:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                by_name[node.name] = node
    for name, node in by_name.items():
        if _defines_code(node):
            carriers.add(name)
    # subclasses of carriers inherit the attribute
    changed = True
    while changed:
        changed = False
        for name, node in by_name.items():
            if name in carriers:
                continue
            for b in node.bases:
                base = b.attr if isinstance(b, ast.Attribute) else \
                    (b.id if isinstance(b, ast.Name) else None)
                if base in carriers:
                    carriers.add(name)
                    changed = True
    return carriers


def _defines_code(cls: ast.ClassDef) -> bool:
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "code"
                for t in stmt.targets):
            return True
        if isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name) \
                and stmt.target.id == "code":
            return True
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and stmt.name == "__init__":
            for n in ast.walk(stmt):
                if isinstance(n, ast.Assign) and any(
                        isinstance(t, ast.Attribute) and t.attr == "code"
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        for t in n.targets):
                    return True
    return False


def _is_exception_type(expr: Optional[ast.AST], names: Set[str]) -> bool:
    """Does the except clause include any of ``names``?"""
    if expr is None:
        return False
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in names:
            return True
        if isinstance(node, ast.Attribute) and node.attr in names:
            return True
    return False


def _handler_consults_code(handler: ast.ExceptHandler) -> bool:
    exc_name = handler.name
    for node in ast.walk(handler):
        if isinstance(node, ast.Attribute) and node.attr == "code":
            return True
        if isinstance(node, ast.Call):
            fname = None
            if isinstance(node.func, ast.Name):
                fname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            if fname in CODE_CONSULT_CALLS:
                return True
            if fname == "getattr" and node.args:
                first = node.args[0]
                if isinstance(first, ast.Name) and first.id == exc_name:
                    if len(node.args) > 1 \
                            and isinstance(node.args[1], ast.Constant) \
                            and node.args[1].value == "code":
                        return True
        if isinstance(node, ast.Raise) and node.exc is None:
            return True                          # re-raise preserves the code
    return False


def _hardcodes_internal(handler: ast.ExceptHandler) -> Optional[int]:
    for node in ast.walk(handler):
        if isinstance(node, ast.Attribute) and node.attr == "INTERNAL":
            chain_base = node.value
            if isinstance(chain_base, ast.Name) \
                    and chain_base.id == "StatusCode":
                return node.lineno
    return None


def _except_findings(src: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None or _is_exception_type(
                node.type, {"BaseException"}):
            out.append(Finding(
                src.rel, node.lineno, RULE_BARE,
                "bare/BaseException except in a per-item isolation path "
                "swallows the item's status code (catch Exception and map "
                "the carried code)"))
            continue
        if _is_exception_type(node.type, {"Exception"}):
            line = _hardcodes_internal(node)
            if line is not None and not _handler_consults_code(node):
                out.append(Finding(
                    src.rel, line, RULE_SWALLOW,
                    "except Exception hard-codes StatusCode.INTERNAL "
                    "without consulting the carried code (use "
                    "fail_operation_from_exception or getattr(e, 'code'))"))
    return out


def _raise_findings(src: SourceFile, carriers: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    for cls in ast.walk(src.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not fn.name[:1].isupper():
                continue                        # RPC handlers are PascalCase
            for node in ast.walk(fn):
                if not isinstance(node, ast.Raise) or node.exc is None:
                    continue
                exc = node.exc
                name = None
                if isinstance(exc, ast.Call):
                    if isinstance(exc.func, ast.Name):
                        name = exc.func.id
                    elif isinstance(exc.func, ast.Attribute):
                        name = exc.func.attr
                elif isinstance(exc, ast.Name):
                    continue                    # re-raise of a stored exc
                if name is None or name in EXEMPT_RAISES or name in carriers:
                    continue
                out.append(Finding(
                    src.rel, node.lineno, RULE_UNMAPPED,
                    f"RPC handler {cls.name}.{fn.name} raises {name} which "
                    f"carries no status code; raise VizierRpcError (or a "
                    f"code-carrying error) so dispatch can map it"))
    return out


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    carriers = _code_carrier_classes(sources)
    findings: List[Finding] = []
    for src in sources:
        base = src.rel.rsplit("/", 1)[-1]
        if base not in ISOLATION_BASENAMES:
            continue
        findings.extend(_except_findings(src))
        findings.extend(_raise_findings(src, carriers))
    return findings
