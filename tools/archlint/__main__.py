"""``python tools/archlint`` entry point.

Run as a directory, sys.path[0] is tools/archlint itself, so the package
is not importable until its parent (tools/) is on the path.
"""

import sys
from pathlib import Path

_TOOLS = str(Path(__file__).resolve().parent.parent)
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)

from archlint.cli import main  # noqa: E402

sys.exit(main())
