"""Retrace-hygiene pass over the Pythia engine + Pallas kernels.

Scope: ``src/repro/pythia/`` and ``src/repro/kernels/`` (plus any fixture
module handed to it). The engine invariant (ROADMAP "Engine rules") is that
steady-state serving never retraces: jitted kernels see only bucket-padded
shapes, and jit bodies never sync back to the host.

Traced-function discovery handles every idiom used in this repo:

* ``@jax.jit`` / ``@functools.partial(jax.jit, static_argnames=...)``
  decorators (with ``partial`` imported bare as well);
* module-level ``name = jax.jit(f)``, ``jax.jit(jax.vmap(f))``, and
  ``jax.jit(lambda ...: ...)`` where ``f`` is defined in the same module;
* nested ``def``s inside a traced body (traced transitively).

Rules
-----
* ``jit-host-sync``     — ``float()/int()/bool()`` on a traced value,
  ``.item()``, or ``np.asarray/np.array`` inside a traced body. Shape
  arithmetic (anything derived from ``.shape``/``len()``/``.ndim``/
  ``.size``) is static under trace and exempt.
* ``jit-tracer-branch`` — a Python ``if``/``while`` whose test reads a
  non-static traced parameter (static_argnames and shape-derived tests
  are exempt; use ``jnp.where``/``lax.cond`` instead).
* ``jit-in-function``   — ``jax.jit(...)`` called inside a function body
  (a fresh jit wrapper per call defeats the trace cache; build jitted
  callables at module scope or once in ``__init__``).
* ``jit-unpadded-shape``— a call to a known-jitted kernel passing a
  freshly-materialized ragged argument (``jnp.array``/``np.asarray`` of a
  Python list, or a non-constant slice) from a function that never runs a
  bucket/padding helper — the blessed wrappers pad via ``*_bucket``/
  ``pad*`` before entering jit.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from archlint.core import Finding, SourceFile

RULE_HOST_SYNC = "jit-host-sync"
RULE_TRACER_BRANCH = "jit-tracer-branch"
RULE_JIT_IN_FN = "jit-in-function"
RULE_UNPADDED = "jit-unpadded-shape"

SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}
PAD_HINTS = ("bucket", "pad")


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    return chain is not None and chain[-1] == "jit" and (
        len(chain) == 1 or chain[-2] in {"jax", "api", "xla"})


def _static_argnames(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in {"static_argnames", "static_argnums"}:
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
    return out


def _jit_call_info(call: ast.Call) -> Optional[Tuple[Optional[ast.AST], Set[str]]]:
    """If ``call`` is ``jax.jit(...)`` or ``partial(jax.jit, ...)``, return
    (wrapped target expr or None, static argnames)."""
    if not isinstance(call, ast.Call):
        return None
    if _is_jax_jit(call.func):
        target = call.args[0] if call.args else None
        return target, _static_argnames(call)
    chain = _attr_chain(call.func)
    if chain and chain[-1] == "partial" and call.args \
            and _is_jax_jit(call.args[0]):
        return None, _static_argnames(call)
    return None


def _shape_derived(expr: ast.AST) -> bool:
    """True when every data dependency is shape metadata (static at trace)."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
            return True
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain and chain[-1] == "len":
                return True
    return False


class _ModuleScan:
    """Discover the traced-function set for one module."""

    def __init__(self, src: SourceFile):
        self.src = src
        # fn-def -> static argnames for traced functions
        self.traced: Dict[ast.FunctionDef, Set[str]] = {}
        self.jitted_names: Set[str] = set()
        self.in_function_jits: List[int] = []
        self._fn_defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in src.tree.body
            if isinstance(n, ast.FunctionDef)}
        self._scan()

    def _mark(self, fn: ast.FunctionDef, static: Set[str]) -> None:
        self.traced.setdefault(fn, set()).update(static)
        self.jitted_names.add(fn.name)

    def _target_fn(self, expr: Optional[ast.AST]) -> Optional[ast.FunctionDef]:
        """Resolve jax.jit(<expr>) to a module-level def (unwraps vmap etc)."""
        while isinstance(expr, ast.Call):
            expr = expr.args[0] if expr.args else None
        if isinstance(expr, ast.Name):
            return self._fn_defs.get(expr.id)
        return None

    def _scan(self) -> None:
        for node in self.src.tree.body:
            if isinstance(node, ast.FunctionDef):
                static = self._decorated_static(node)
                if static is not None:
                    self._mark(node, static)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                info = _jit_call_info(node.value) if \
                    isinstance(node.value, ast.Call) else None
                if info is not None:
                    target, static = info
                    self.jitted_names.add(node.targets[0].id)
                    fn = self._target_fn(target)
                    if fn is not None:
                        self._mark(fn, static)
                        self.jitted_names.add(node.targets[0].id)
                    elif isinstance(target, ast.Lambda):
                        # analyze the lambda body as a traced expression
                        self.traced.setdefault(
                            _LambdaShim(target), set()).update(static)
        # jit created inside a function body (any def, incl. methods)
        for node in ast.walk(self.src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for inner in ast.walk(node):
                if isinstance(inner, ast.Call) and _is_jax_jit(inner.func):
                    if node.name == "__init__":
                        continue        # one-time construction is fine
                    self.in_function_jits.append(inner.lineno)

    def _decorated_static(self, fn: ast.FunctionDef) -> Optional[Set[str]]:
        for dec in fn.decorator_list:
            if _is_jax_jit(dec):
                return set()
            if isinstance(dec, ast.Call):
                info = _jit_call_info(dec)
                if info is not None:
                    return info[1]
        return None


class _LambdaShim:
    """Adapter so a jitted lambda walks like a FunctionDef."""

    def __init__(self, lam: ast.Lambda):
        self.name = "<lambda>"
        self.args = lam.args
        self.body = [ast.Expr(value=lam.body)]
        self.lineno = lam.lineno


def _param_names(fn) -> Set[str]:
    a = fn.args
    names = {p.arg for p in a.args + a.kwonlyargs + a.posonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    names.discard("self")
    return names


def _check_traced_body(fn, static: Set[str], rel: str,
                       findings: List[Finding]) -> None:
    traced_params = _param_names(fn) - static

    def check_node(node: ast.AST) -> None:
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain and chain[-1] in {"float", "int", "bool"} \
                    and len(chain) == 1 and node.args:
                if not _shape_derived(node.args[0]):
                    findings.append(Finding(
                        rel, node.lineno, RULE_HOST_SYNC,
                        f"{chain[-1]}() on a traced value forces a host "
                        f"sync inside a jit body"))
            elif chain and chain[-1] == "item":
                findings.append(Finding(
                    rel, node.lineno, RULE_HOST_SYNC,
                    ".item() forces a host sync inside a jit body"))
            elif chain and len(chain) >= 2 and chain[0] in {"np", "numpy"} \
                    and chain[-1] in {"asarray", "array"}:
                findings.append(Finding(
                    rel, node.lineno, RULE_HOST_SYNC,
                    f"{'.'.join(chain)}() materializes a traced value on "
                    f"the host inside a jit body"))
        elif isinstance(node, (ast.If, ast.While)):
            test = node.test
            if _shape_derived(test):
                return
            if _reads_any(test, traced_params) and not _is_none_check(test):
                findings.append(Finding(
                    rel, test.lineno, RULE_TRACER_BRANCH,
                    "Python branch on a traced value (use jnp.where / "
                    "lax.cond, or mark the arg static)"))

    for stmt in fn.body:
        for node in ast.walk(stmt):
            check_node(node)


def _reads_any(expr: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(expr))


def _is_none_check(test: ast.AST) -> bool:
    for node in ast.walk(test):
        if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return True
    return False


def _check_unpadded_calls(src: SourceFile, scan: _ModuleScan,
                          findings: List[Finding]) -> None:
    for node in ast.walk(src.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node in scan.traced:
            continue
        calls_padding = any(
            isinstance(c, ast.Call) and _call_name_has(c, PAD_HINTS)
            for c in ast.walk(node))
        if calls_padding:
            continue
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            fname = _called_name(call)
            if fname not in scan.jitted_names:
                continue
            for arg in call.args:
                if _ragged_expr(arg):
                    findings.append(Finding(
                        src.rel, call.lineno, RULE_UNPADDED,
                        f"jitted kernel {fname}() called with a "
                        f"shape-varying argument; route through a "
                        f"bucket-padding wrapper"))
                    break


def _call_name_has(call: ast.Call, hints: Tuple[str, ...]) -> bool:
    chain = _attr_chain(call.func)
    if not chain:
        return False
    leaf = chain[-1].lower()
    return any(h in leaf for h in hints)


def _called_name(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


def _ragged_expr(arg: ast.AST) -> bool:
    """jnp.array([..list..]) / np.asarray(pylist) / x[:n] with variable n."""
    if isinstance(arg, ast.Call):
        chain = _attr_chain(arg.func)
        if chain and chain[-1] in {"array", "asarray", "stack"} and arg.args:
            inner = arg.args[0]
            if isinstance(inner, (ast.List, ast.ListComp, ast.GeneratorExp)):
                return True
    if isinstance(arg, ast.Subscript) and isinstance(arg.slice, ast.Slice):
        for bound in (arg.slice.lower, arg.slice.upper):
            if bound is not None and not isinstance(bound, ast.Constant):
                return True
    return False


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        if "/pythia/" not in f"/{src.rel}" and "/kernels/" not in f"/{src.rel}":
            continue
        scan = _ModuleScan(src)
        for line in scan.in_function_jits:
            findings.append(Finding(
                src.rel, line, RULE_JIT_IN_FN,
                "jax.jit(...) constructed inside a function body defeats "
                "the trace cache; build jitted callables at module scope"))
        seen: Set[int] = set()
        work = list(scan.traced.items())
        while work:
            fn, static = work.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            _check_traced_body(fn, static, src.rel, findings)
            # nested defs are traced transitively
            for stmt in fn.body:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.FunctionDef):
                        work.append((node, set(static)))
        _check_unpadded_calls(src, scan, findings)
    return findings
