"""archlint — AST-based architecture-invariant analyzer for this repo.

Four passes, each encoding one ROADMAP "Architecture invariants" entry as a
machine-checked rule set (see README.md for the rule catalog):

* lock_pass    — lock discipline in the sharded service tier
* retrace_pass — retrace hygiene in the Pythia engine + Pallas kernels
* schema_pass  — reserved-namespace writes + STATE_SCHEMA_VERSION bumps
* error_pass   — error/status-code discipline in per-item isolation paths

The static passes are complemented by a runtime lock-order witness
(``repro.service._lockwitness``) that records the real acquisition graph
during the fault-injection suite and fails on cycles — the dynamic check
catches cross-thread orders the static call graph cannot see.
"""

from archlint.core import Finding, analyze_paths, load_baseline  # noqa: F401

__all__ = ["Finding", "analyze_paths", "load_baseline"]
