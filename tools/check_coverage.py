#!/usr/bin/env python
"""Coverage floors for the core + service + algorithm + kernel layers.

``repro.service`` must stay >= 80%, ``repro.pythia`` >= 70%,
``repro.core`` >= 70%, and ``repro.kernels`` >= 70%. With pytest-cov
installed this is one run per package of

    pytest --cov=<pkg> --cov-fail-under=<floor> <coverage tests>

This container ships no coverage wheel and dependencies cannot be added, so
the fallback measures line coverage with the stdlib ``trace`` module over the
coverage-focused test modules and enforces the same floors in ONE traced
pytest run: executable lines come from ``trace._find_executable_linenos``
(the same lnotab walk the trace CLI uses), executed lines from a count-mode
tracer installed on every thread (the RPC servers handle frames on worker
threads).

Usage: python tools/check_coverage.py [--fail-under PCT] [--pythia-fail-under PCT]
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import threading
import trace as trace_mod

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

# The tests that exercise the measured layers. Slow/distributed markers are
# excluded: the floors must be cheap enough to run on every `make test`.
COVERAGE_TESTS = [
    "tests/test_rpc.py",
    "tests/test_datastore.py",
    "tests/test_chaos.py",
    "tests/test_service.py",
    "tests/test_batch_suggest.py",
    "tests/test_pythia_remote.py",
    "tests/test_work_queue.py",
    "tests/test_scaleout.py",
    "tests/test_early_stopping.py",
    "tests/test_designers.py",
    "tests/test_gp_bandit.py",
    "tests/test_posterior.py",
    "tests/test_sparse_posterior.py",
    "tests/test_kernels.py",
    "tests/test_tri_solve.py",
    "tests/test_policy_state.py",
    "tests/test_transfer.py",
    "tests/test_search_space.py",
    "tests/test_proto_roundtrip.py",
    "tests/test_pareto.py",
    "tests/test_multimetric.py",
]


def _packages(args) -> "list[tuple[str, str, float]]":
    return [
        ("repro.service", os.path.join(SRC, "repro", "service"), args.fail_under),
        ("repro.pythia", os.path.join(SRC, "repro", "pythia"),
         args.pythia_fail_under),
        ("repro.core", os.path.join(SRC, "repro", "core"),
         args.core_fail_under),
        ("repro.kernels", os.path.join(SRC, "repro", "kernels"),
         args.kernels_fail_under),
    ]


def run_with_pytest_cov(packages) -> int:
    import pytest

    # One pytest run per package: --cov-fail-under is a single global floor,
    # so per-package floors need separate runs (or parsing coverage data,
    # which cannot be validated in this container — it ships no pytest-cov;
    # the stdlib-trace fallback below scores both packages in one run).
    for name, _pkg_dir, floor in packages:
        rc = pytest.main([
            "-q", "-m", "not slow",
            f"--cov={name}", f"--cov-fail-under={floor}",
            *COVERAGE_TESTS,
        ])
        if rc != 0:
            return int(rc)
    return 0


def run_with_stdlib_trace(packages) -> int:
    # Pay the heavy third-party imports BEFORE the tracer is installed: the
    # per-call hook makes jax's import graph crawl, and none of it counts
    # toward the measured packages anyway.
    import msgpack  # noqa: F401
    import pytest

    try:
        import jax  # noqa: F401
    except ImportError:
        pass

    # Only the measured packages count, so skip the line hook everywhere
    # else: tracing the model code (which jax re-traces through Python)
    # would make this check minutes slower without changing the verdict.
    # repro.kernels IS measured — its Pallas kernels execute through the
    # interpreter in the kernel tests, which the tracer handles fine.
    measured_dirs = [pkg_dir for _, pkg_dir, _ in packages]
    repro_dir = os.path.join(SRC, "repro")
    ignore_dirs = [sys.prefix, sys.exec_prefix] + [
        os.path.join(repro_dir, d) for d in os.listdir(repro_dir)
        if os.path.isdir(os.path.join(repro_dir, d))
        and os.path.join(repro_dir, d) not in measured_dirs
    ]
    tracer = trace_mod.Trace(count=1, trace=0, ignoredirs=ignore_dirs)
    threading.settrace(tracer.globaltrace)
    sys.settrace(tracer.globaltrace)
    try:
        rc = pytest.main(["-q", "-m", "not slow", "-p", "no:cacheprovider",
                          *COVERAGE_TESTS])
    finally:
        sys.settrace(None)
        threading.settrace(None)  # type: ignore[arg-type]
    if rc != 0:
        print(f"coverage: tests failed (exit {rc}); no coverage verdict")
        return int(rc)

    executed: dict[str, set] = {}
    for (fname, lineno) in tracer.results().counts:
        fname = os.path.abspath(fname)
        for _, pkg_dir, _ in packages:
            if fname.startswith(pkg_dir):
                executed.setdefault(fname, set()).add(lineno)
                break

    worst_rc = 0
    for name, pkg_dir, floor in packages:
        total_executable = total_executed = 0
        print(f"\ncoverage of {name} ({os.path.relpath(pkg_dir, ROOT)}):")
        for py in sorted(glob.glob(os.path.join(pkg_dir, "*.py"))):
            executable = set(trace_mod._find_executable_linenos(py))
            if not executable:
                continue
            hit = executed.get(os.path.abspath(py), set()) & executable
            total_executable += len(executable)
            total_executed += len(hit)
            pct = 100.0 * len(hit) / len(executable)
            print(f"  {os.path.basename(py):24s} {len(hit):4d}/{len(executable):4d}"
                  f"  {pct:5.1f}%")
        pct = 100.0 * total_executed / max(total_executable, 1)
        verdict = "PASS" if pct >= floor else "FAIL"
        print(f"  {'TOTAL':24s} {total_executed:4d}/{total_executable:4d}"
              f"  {pct:5.1f}%  (floor {floor:.0f}%)  {verdict}")
        if pct < floor:
            worst_rc = 2
    return worst_rc


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fail-under", type=float, default=80.0,
                        help="repro.service floor (default 80)")
    parser.add_argument("--pythia-fail-under", type=float, default=70.0,
                        help="repro.pythia floor (default 70)")
    parser.add_argument("--core-fail-under", type=float, default=70.0,
                        help="repro.core floor (default 70)")
    parser.add_argument("--kernels-fail-under", type=float, default=70.0,
                        help="repro.kernels floor (default 70)")
    args = parser.parse_args()
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    os.chdir(ROOT)
    packages = _packages(args)
    try:
        import pytest_cov  # noqa: F401
        has_pytest_cov = True
    except ImportError:
        has_pytest_cov = False
    if has_pytest_cov:
        return run_with_pytest_cov(packages)
    return run_with_stdlib_trace(packages)


if __name__ == "__main__":
    sys.exit(main())
