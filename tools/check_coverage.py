#!/usr/bin/env python
"""Coverage floor for the service layer: repro.service must stay >= 80%.

With pytest-cov installed this is exactly

    pytest --cov=repro.service --cov-fail-under=80 <service tests>

This container ships no coverage wheel and dependencies cannot be added, so
the fallback measures line coverage with the stdlib ``trace`` module over the
service-focused test modules and enforces the same floor: executable lines
come from ``trace._find_executable_linenos`` (the same lnotab walk the trace
CLI uses), executed lines from a count-mode tracer installed on every thread
(the RPC servers handle frames on worker threads).

Usage: python tools/check_coverage.py [--fail-under PCT]
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import threading
import trace as trace_mod

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
PKG_DIR = os.path.join(SRC, "repro", "service")

# The tests that exercise the service layer. Slow/distributed markers are
# excluded: the floor must be cheap enough to run on every `make test`.
SERVICE_TESTS = [
    "tests/test_rpc.py",
    "tests/test_datastore.py",
    "tests/test_service.py",
    "tests/test_batch_suggest.py",
    "tests/test_pythia_remote.py",
    "tests/test_early_stopping.py",
]


def run_with_pytest_cov(fail_under: float) -> int:
    import pytest

    return pytest.main([
        "-q", "-m", "not slow",
        "--cov=repro.service", f"--cov-fail-under={fail_under}",
        *SERVICE_TESTS,
    ])


def run_with_stdlib_trace(fail_under: float) -> int:
    # Pay the heavy third-party imports BEFORE the tracer is installed: the
    # per-call hook makes jax's import graph crawl, and none of it counts
    # toward repro.service coverage anyway.
    import msgpack  # noqa: F401
    import pytest

    try:
        import jax  # noqa: F401
    except ImportError:
        pass

    # Only repro.service is measured, so skip the line hook everywhere else:
    # tracing the GP/kernel code (which jax re-traces through Python) would
    # make this check minutes slower without changing the verdict.
    repro_dir = os.path.join(SRC, "repro")
    ignore_dirs = [sys.prefix, sys.exec_prefix] + [
        os.path.join(repro_dir, d) for d in os.listdir(repro_dir)
        if d != "service" and os.path.isdir(os.path.join(repro_dir, d))
    ]
    tracer = trace_mod.Trace(count=1, trace=0, ignoredirs=ignore_dirs)
    threading.settrace(tracer.globaltrace)
    sys.settrace(tracer.globaltrace)
    try:
        rc = pytest.main(["-q", "-m", "not slow", "-p", "no:cacheprovider",
                          *SERVICE_TESTS])
    finally:
        sys.settrace(None)
        threading.settrace(None)  # type: ignore[arg-type]
    if rc != 0:
        print(f"coverage: service tests failed (exit {rc}); no coverage verdict")
        return int(rc)

    executed: dict[str, set] = {}
    for (fname, lineno) in tracer.results().counts:
        fname = os.path.abspath(fname)
        if fname.startswith(PKG_DIR):
            executed.setdefault(fname, set()).add(lineno)

    total_executable = total_executed = 0
    print(f"\ncoverage of repro.service ({os.path.relpath(PKG_DIR, ROOT)}):")
    for py in sorted(glob.glob(os.path.join(PKG_DIR, "*.py"))):
        executable = set(trace_mod._find_executable_linenos(py))
        if not executable:
            continue
        hit = executed.get(os.path.abspath(py), set()) & executable
        total_executable += len(executable)
        total_executed += len(hit)
        pct = 100.0 * len(hit) / len(executable)
        print(f"  {os.path.basename(py):24s} {len(hit):4d}/{len(executable):4d}"
              f"  {pct:5.1f}%")
    pct = 100.0 * total_executed / max(total_executable, 1)
    verdict = "PASS" if pct >= fail_under else "FAIL"
    print(f"  {'TOTAL':24s} {total_executed:4d}/{total_executable:4d}"
          f"  {pct:5.1f}%  (floor {fail_under:.0f}%)  {verdict}")
    return 0 if pct >= fail_under else 2


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fail-under", type=float, default=80.0)
    args = parser.parse_args()
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    os.chdir(ROOT)
    try:
        import pytest_cov  # noqa: F401
        has_pytest_cov = True
    except ImportError:
        has_pytest_cov = False
    if has_pytest_cov:
        return run_with_pytest_cov(args.fail_under)
    return run_with_stdlib_trace(args.fail_under)


if __name__ == "__main__":
    sys.exit(main())
