"""Paper Table 1: the feature matrix, exercised end-to-end.

Each claimed feature (multi-objective, early stopping, transfer learning,
conditional search, parallel trials, any-language client = wire protocol)
runs for real; the benchmark reports per-feature latency and PASS/FAIL.
"""

from benchmarks.bench_util import emit, timeit

from repro.core import (
    AutomatedStoppingConfig,
    Measurement,
    ScaleType,
    StudyConfig,
    Trial,
    TrialState,
)
from repro.service import DefaultVizierServer, VizierClient


def _base_config(algorithm="RANDOM_SEARCH") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


def bench_multi_objective(server) -> str:
    cfg = _base_config()
    cfg.metrics.add("cost", "MINIMIZE")
    c = VizierClient.load_or_create_study("f-mo", cfg, client_id="c",
                                          target=server.address)
    for i in range(6):
        (t,) = c.get_suggestions(count=1)
        x = t.parameters.get_value("x")
        c.complete_trial({"obj": x, "cost": x * x}, trial_id=t.id)
    front = c.list_optimal_trials()
    assert 1 <= len(front) <= 6
    return f"pareto_front={len(front)}"


def bench_early_stopping(server) -> str:
    cfg = _base_config()
    cfg.automated_stopping = (
        AutomatedStoppingConfig.median_automated_stopping_config(
            min_completed_trials=1))
    c = VizierClient.load_or_create_study("f-es", cfg, client_id="c",
                                          target=server.address)
    (good,) = c.get_suggestions(count=1)
    for s, v in [(1, 0.8), (2, 0.9)]:
        c.report_intermediate_objective_value({"obj": v}, trial_id=good.id, step=s)
    c.complete_trial({"obj": 0.9}, trial_id=good.id)
    (bad,) = c.get_suggestions(count=1)
    c.report_intermediate_objective_value({"obj": 0.05}, trial_id=bad.id, step=1)
    c.report_intermediate_objective_value({"obj": 0.06}, trial_id=bad.id, step=2)
    assert c.should_trial_stop(bad.id) is True
    return "median_rule_stops=True"


def bench_transfer_learning(server) -> str:
    cfg = _base_config()
    c = VizierClient.load_or_create_study("f-tl", cfg, client_id="c",
                                          target=server.address)
    prior = Trial(parameters={"x": 0.7})
    prior.complete(Measurement(metrics={"obj": 0.99}))
    added = c.add_trial(prior)  # seed from a prior study
    assert c.get_trial(added.id).state == TrialState.COMPLETED
    return "prior_trials_injected=1"


def bench_conditional_search(server) -> str:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    model = root.add_categorical_param("model", ["linear", "dnn"])
    model.select_values(["dnn"]).add_int_param("layers", 1, 4)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "RANDOM_SEARCH"
    c = VizierClient.load_or_create_study("f-cond", cfg, client_id="c",
                                          target=server.address)
    kinds = set()
    for _ in range(8):
        (t,) = c.get_suggestions(count=1)
        has_layers = "layers" in t.parameters
        assert has_layers == (t.parameters.get_value("model") == "dnn")
        kinds.add(t.parameters.get_value("model"))
        c.complete_trial({"obj": 0.5}, trial_id=t.id)
    return f"models_seen={len(kinds)}"


def bench_parallel_trials(server) -> str:
    cfg = _base_config()
    c = VizierClient.load_or_create_study("f-par", cfg, client_id="seed",
                                          target=server.address)
    clients = [VizierClient(server.address, c.study_name, f"w{i}")
               for i in range(4)]
    trials = [cl.get_suggestions(count=1)[0] for cl in clients]
    assert len({t.id for t in trials}) == 4
    for cl, t in zip(clients, trials):
        cl.complete_trial({"obj": 0.1}, trial_id=t.id)
    return "parallel_clients=4"


def main() -> None:
    server = DefaultVizierServer()
    for name, fn in [
        ("table1.multi_objective", bench_multi_objective),
        ("table1.early_stopping", bench_early_stopping),
        ("table1.transfer_learning", bench_transfer_learning),
        ("table1.conditional_search", bench_conditional_search),
        ("table1.parallel_trials", bench_parallel_trials),
    ]:
        import time

        t0 = time.perf_counter()
        derived = fn(server)
        us = (time.perf_counter() - t0) * 1e6
        emit(name, us, f"PASS {derived}")
    server.stop()


if __name__ == "__main__":
    main()
