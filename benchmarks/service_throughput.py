"""Paper Figure 2: distributed pipeline throughput + crash recovery time.

suggestions/sec and RPC latency vs #concurrent clients, plus the time for a
freshly-restarted server (same durable datastore) to recover pending ops.
"""

import threading
import time

from benchmarks.bench_util import emit

from repro.core import ScaleType, StudyConfig
from repro.service import DefaultVizierServer, VizierClient
from repro.service.datastore import SQLiteDatastore
from repro.service.vizier_service import VizierService


def _config() -> StudyConfig:
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1,
                                                   scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "RANDOM_SEARCH"
    return cfg


def bench_throughput(n_clients: int, n_trials: int = 12) -> None:
    server = DefaultVizierServer()
    seed = VizierClient.load_or_create_study(
        f"tput-{n_clients}", _config(), client_id="seed", target=server.address)
    latencies, errs = [], []
    lock = threading.Lock()

    def worker(wid):
        try:
            c = VizierClient(server.address, seed.study_name, f"w{wid}")
            for _ in range(n_trials):
                t0 = time.perf_counter()
                (t,) = c.get_suggestions(count=1)
                c.complete_trial({"obj": 0.1}, trial_id=t.id)
                with lock:
                    latencies.append(time.perf_counter() - t0)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errs, errs
    total = n_clients * n_trials
    latencies.sort()
    p50 = latencies[len(latencies) // 2] * 1e3
    p95 = latencies[int(len(latencies) * 0.95)] * 1e3
    emit(f"fig2.throughput.clients={n_clients}", wall / total * 1e6,
         f"trials_per_sec={total/wall:.1f} p50={p50:.1f}ms p95={p95:.1f}ms")
    server.stop()


def bench_crash_recovery(tmpdir="/tmp/bench_crash.db") -> None:
    import os

    if os.path.exists(tmpdir):
        os.remove(tmpdir)
    ds = SQLiteDatastore(tmpdir)
    svc = VizierService(ds)
    client = VizierClient.load_or_create_study("crash", _config(),
                                               client_id="c", target=svc)
    (t,) = client.get_suggestions(count=1)  # normal op committed
    # enqueue an op that the "crashing" server never finishes
    import repro.service.operations as ops_lib

    op = ops_lib.new_suggest_operation(client.study_name, "c2", 1)
    ds.put_operation(op)
    svc.shutdown()  # crash

    t0 = time.perf_counter()
    svc2 = VizierService(SQLiteDatastore(tmpdir))
    n = svc2.recover_pending_operations()
    deadline = time.time() + 30
    while time.time() < deadline:
        if svc2._ds.get_operation(op["name"])["done"]:
            break
        time.sleep(0.01)
    recovery = (time.perf_counter() - t0) * 1e6
    assert svc2._ds.get_operation(op["name"])["done"]
    emit("fig2.crash_recovery", recovery, f"recovered_ops={n} PASS")
    svc2.shutdown()


def main() -> None:
    for n in (1, 4, 16):
        bench_throughput(n)
    bench_crash_recovery()


if __name__ == "__main__":
    main()
