"""Paper Figure 2: distributed pipeline throughput + crash recovery time.

suggestions/sec and RPC latency vs #concurrent clients, plus the time for a
freshly-restarted server (same durable datastore) to recover pending ops.

``--batched`` additionally runs the batched-suggestion scenario: the same
per-(study, client) workload issued through BatchSuggestTrials /
BatchCompleteTrials (one RPC + one coalesced Pythia dispatch per round)
instead of one thread + one SuggestTrials poll-loop per client, at 1, 8 and
64 concurrent clients.
"""

import argparse
import threading
import time

from benchmarks.bench_util import emit

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    VizierBatchClient,
    VizierClient,
)
from repro.service.datastore import SQLiteDatastore
from repro.service.vizier_service import VizierService


def _config() -> StudyConfig:
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1,
                                                   scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "RANDOM_SEARCH"
    return cfg


def bench_throughput(n_clients: int, n_trials: int = 12) -> None:
    server = DefaultVizierServer()
    seed = VizierClient.load_or_create_study(
        f"tput-{n_clients}", _config(), client_id="seed", target=server.address)
    latencies, errs = [], []
    lock = threading.Lock()

    def worker(wid):
        try:
            c = VizierClient(server.address, seed.study_name, f"w{wid}")
            for _ in range(n_trials):
                t0 = time.perf_counter()
                (t,) = c.get_suggestions(count=1)
                c.complete_trial({"obj": 0.1}, trial_id=t.id)
                with lock:
                    latencies.append(time.perf_counter() - t0)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errs, errs
    total = n_clients * n_trials
    latencies.sort()
    p50 = latencies[len(latencies) // 2] * 1e3
    p95 = latencies[int(len(latencies) * 0.95)] * 1e3
    emit(f"fig2.throughput.clients={n_clients}", wall / total * 1e6,
         f"trials_per_sec={total/wall:.1f} p50={p50:.1f}ms p95={p95:.1f}ms")
    server.stop()


def bench_batched_throughput(n_clients: int, n_rounds: int = 12) -> None:
    """suggestions/sec with server-side coalescing: each round is ONE
    BatchSuggestTrials RPC covering every (study, client) pair, then ONE
    BatchCompleteTrials for the evaluations."""
    server = DefaultVizierServer()
    studies = []
    for i in range(n_clients):
        c = VizierClient.load_or_create_study(
            f"btput-{n_clients}-{i}", _config(), client_id="seed",
            target=server.address)
        studies.append(c.study_name)
        c.close()

    batch = VizierBatchClient(server.address)
    requests = [
        {"study_name": s, "client_id": f"w{i}", "count": 1}
        for i, s in enumerate(studies)
    ]
    latencies = []
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        r0 = time.perf_counter()
        per_req = batch.get_suggestions(requests)
        batch.complete_trials([
            {"study_name": s, "trial_name": f"{s}/trials/{trials[0].id}",
             "metrics": {"obj": 0.1}}
            for s, trials in zip(studies, per_req)
        ])
        latencies.append(time.perf_counter() - r0)
    wall = time.perf_counter() - t0
    total = n_clients * n_rounds
    latencies.sort()
    p50 = latencies[len(latencies) // 2] * 1e3
    p95 = latencies[int(len(latencies) * 0.95)] * 1e3
    emit(f"fig2.batched_throughput.clients={n_clients}", wall / total * 1e6,
         f"suggestions_per_sec={total/wall:.1f} round_p50={p50:.1f}ms "
         f"round_p95={p95:.1f}ms")
    batch.close()
    server.stop()


def bench_remote_pythia(n_clients: int, n_rounds: int = 10,
                        n_seed_trials: int = 200) -> float:
    """Figure-2 topology (separate Pythia service): coalesced
    PythiaBatchSuggest vs the per-study PythiaSuggest baseline.

    Each round is one BatchSuggestTrials covering every (study, client)
    pair. The baseline forwards that batch to the Pythia service one study
    at a time with the pre-batch wire pattern (each PythiaSuggest re-fetches
    the study and the full trial list for max_trial_id, then the policy
    re-fetches per state); the coalesced path ships the whole work-list in
    one PythiaBatchSuggest frame backed by a single
    GetTrialsMulti(include_studies) prefetch shared by every policy.
    Returns the coalesced/baseline suggestions-per-sec ratio.
    """
    rates = {}
    for coalesce in (False, True):
        server = DistributedVizierServer(coalesce_remote=coalesce,
                                         pythia_single_fetch=coalesce)
        studies = []
        for i in range(n_clients):
            c = VizierClient.load_or_create_study(
                f"rmt-{coalesce}-{n_clients}-{i}", _config(), client_id="seed",
                target=server.address)
            for j in range(n_seed_trials):  # realistic trial payloads
                t = Trial(parameters={"x": (j + 1) / (n_seed_trials + 1)})
                t.complete(Measurement(metrics={"obj": 0.1 * j}))
                c.add_trial(t)
            studies.append(c.study_name)
            c.close()

        batch = VizierBatchClient(server.address, poll_interval=0.001)
        requests = [
            {"study_name": s, "client_id": f"w{i}", "count": 1}
            for i, s in enumerate(studies)
        ]
        t0 = time.perf_counter()
        for r in range(n_rounds):
            per_req = batch.get_suggestions(requests)
            batch.complete_trials([
                {"trial_name": f"{s}/trials/{trials[0].id}",
                 "metrics": {"obj": 0.1}}
                for s, trials in zip(studies, per_req)
            ])
        wall = time.perf_counter() - t0
        total = n_clients * n_rounds
        rates[coalesce] = total / wall
        label = "coalesced" if coalesce else "per_study_rpc"
        emit(f"fig2.remote_pythia.{label}.clients={n_clients}",
             wall / total * 1e6, f"suggestions_per_sec={total/wall:.1f}")
        batch.close()
        server.stop()
    ratio = rates[True] / rates[False]
    emit(f"fig2.remote_pythia.speedup.clients={n_clients}", ratio,
         f"coalesced_vs_per_study_rpc={ratio:.2f}x")
    return ratio


def _gp_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0, 1, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0, 1, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def bench_warm_start(trial_counts=(50, 200, 500), n_repeats=7) -> None:
    """Warm-started GP-bandit suggest (persisted PolicyState, paper §6.3) vs
    the cold per-operation refit, at fixed completed-trial counts.

    Each operation constructs a fresh policy (the stateless Pythia lifespan)
    against the same datastore; the warm scenario keeps the persisted
    ``repro.gp_bandit`` checkpoint between operations, the cold scenario
    wipes it first. Reports median fit wall-time and suggest latency, plus
    the warm-vs-cold fit speedup.
    """
    from repro.core.study import Study
    from repro.pythia.gp_bandit import GPBanditPolicy
    from repro.pythia.policy import StudyDescriptor, SuggestRequest
    from repro.pythia.state import GP_BANDIT_NAMESPACE
    from repro.pythia.supporter import DatastorePolicySupporter
    from repro.service.datastore import InMemoryDatastore

    med = lambda xs: sorted(xs)[len(xs) // 2]
    for n in trial_counts:
        ds = InMemoryDatastore()
        study = Study(name=f"owners/bench/studies/warm-{n}",
                      study_config=_gp_config())
        ds.create_study(study)
        for i in range(n):  # deterministic smooth objective
            x = (i + 1) / (n + 1)
            y = ((i * 7919) % n) / n
            t = Trial(parameters={"x": x, "y": y})
            t.complete(Measurement(
                metrics={"obj": -(x - 0.37) ** 2 - 0.5 * (y - 0.61) ** 2}))
            ds.create_trial(study.name, t)
        supporter = DatastorePolicySupporter(ds, study.name)

        def one_suggest():
            config = ds.get_study(study.name).study_config  # fresh metadata
            policy = GPBanditPolicy(supporter)
            t0 = time.perf_counter()
            policy.suggest(SuggestRequest(
                study_descriptor=StudyDescriptor(config=config, guid=study.name),
                count=1))
            return time.perf_counter() - t0, policy

        def wipe_state():
            s = ds.get_study(study.name)
            s.study_config.metadata.clear_ns(GP_BANDIT_NAMESPACE)
            ds.update_study(s)

        # cold scenario: state wiped before every op (first run untimed: jit)
        wipe_state()
        one_suggest()
        cold_fit, cold_wall = [], []
        for _ in range(n_repeats):
            wipe_state()
            wall, policy = one_suggest()
            assert not policy.last_fit_warm
            cold_wall.append(wall)
            cold_fit.append(policy.last_fit_seconds)
        # warm scenario: checkpoint persists; two untimed ops let the resumed
        # trajectory reach the convergence exit (as a live study would)
        wipe_state()
        one_suggest()
        one_suggest()
        warm_fit, warm_wall = [], []
        for _ in range(n_repeats):
            wall, policy = one_suggest()
            assert policy.last_fit_warm
            warm_wall.append(wall)
            warm_fit.append(policy.last_fit_seconds)

        emit(f"warmstart.n={n}.cold", med(cold_fit) * 1e6,
             f"median_fit_ms={med(cold_fit)*1e3:.2f} "
             f"suggest_ms={med(cold_wall)*1e3:.2f}")
        emit(f"warmstart.n={n}.warm", med(warm_fit) * 1e6,
             f"median_fit_ms={med(warm_fit)*1e3:.2f} "
             f"suggest_ms={med(warm_wall)*1e3:.2f}")
        ratio = med(cold_fit) / max(med(warm_fit), 1e-9)
        verdict = "PASS" if n < 200 or ratio >= 2.0 else "FAIL"
        emit(f"warmstart.n={n}.fit_speedup", ratio,
             f"warm_vs_cold={ratio:.1f}x (floor 2x at n>=200) {verdict}")


def bench_transfer(n_prior_trials=60, shift=0.07, tol=0.01, max_trials=25,
                   n_repeats=3) -> None:
    """Transfer learning (stacked residual GP over prior studies) vs a cold
    study, on a shifted-objective family: trials-to-target and the
    suggestion-latency overhead the prior stack adds.

    A prior study is seeded with ``n_prior_trials`` evaluations of the base
    objective; the target study optimizes the same family with its optimum
    shifted by ``shift``. Target reached when the best observed value is
    within ``tol`` of the optimum (0.0). The transfer study must reach it in
    no more trials than the cold study (floor, asserted PASS/FAIL).
    """
    import numpy as np

    def objective(params, s):
        x, y = float(params["x"]), float(params["y"])
        return -((x - (0.30 + s)) ** 2) - 0.5 * ((y - (0.60 - s)) ** 2)

    server = DefaultVizierServer()
    prior = VizierClient.load_or_create_study(
        "xfer-prior", _gp_config(), client_id="seed", target=server.address)
    rng = np.random.RandomState(0)
    for _ in range(n_prior_trials):
        p = {"x": float(rng.rand()), "y": float(rng.rand())}
        t = Trial(parameters=p)
        t.complete(Measurement(metrics={"obj": objective(p, 0.0)}))
        prior.add_trial(t)

    def run_to_target(tag, priors):
        trials_used, suggest_ms = [], []
        for rep in range(n_repeats):
            c = VizierClient.load_or_create_study(
                f"xfer-{tag}-{rep}", _gp_config(), client_id="w",
                target=server.address, prior_studies=priors)
            best, used = float("-inf"), max_trials
            for i in range(1, max_trials + 1):
                t0 = time.perf_counter()
                (t,) = c.get_suggestions(count=1)
                suggest_ms.append((time.perf_counter() - t0) * 1e3)
                val = objective(t.parameters.as_dict(), shift)
                c.complete_trial({"obj": val}, trial_id=t.id)
                best = max(best, val)
                if best >= -tol:
                    used = i
                    break
            trials_used.append(used)
            c.close()
        med = lambda xs: sorted(xs)[len(xs) // 2]
        return med(trials_used), med(suggest_ms)

    cold_trials, cold_ms = run_to_target("cold", None)
    xfer_trials, xfer_ms = run_to_target("warm", [prior.study_name])
    emit("transfer.cold.trials_to_target", cold_trials,
         f"median over {n_repeats} runs, suggest_p50={cold_ms:.1f}ms")
    emit("transfer.stacked.trials_to_target", xfer_trials,
         f"median over {n_repeats} runs, suggest_p50={xfer_ms:.1f}ms")
    verdict = "PASS" if xfer_trials <= cold_trials else "FAIL"
    emit("transfer.trials_saved", cold_trials - xfer_trials,
         f"cold={cold_trials} transfer={xfer_trials} "
         f"latency_overhead={xfer_ms - cold_ms:+.1f}ms {verdict}")
    prior.close()
    server.stop()


def bench_crash_recovery(tmpdir="/tmp/bench_crash.db") -> None:
    import os

    if os.path.exists(tmpdir):
        os.remove(tmpdir)
    ds = SQLiteDatastore(tmpdir)
    svc = VizierService(ds)
    client = VizierClient.load_or_create_study("crash", _config(),
                                               client_id="c", target=svc)
    (t,) = client.get_suggestions(count=1)  # normal op committed
    # enqueue an op that the "crashing" server never finishes
    import repro.service.operations as ops_lib

    op = ops_lib.new_suggest_operation(client.study_name, "c2", 1)
    ds.put_operation(op)
    svc.shutdown()  # crash

    t0 = time.perf_counter()
    svc2 = VizierService(SQLiteDatastore(tmpdir))
    n = svc2.recover_pending_operations()
    deadline = time.time() + 30
    while time.time() < deadline:
        if svc2._ds.get_operation(op["name"])["done"]:
            break
        time.sleep(0.01)
    recovery = (time.perf_counter() - t0) * 1e6
    assert svc2._ds.get_operation(op["name"])["done"]
    emit("fig2.crash_recovery", recovery, f"recovered_ops={n} PASS")
    svc2.shutdown()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--batched", action="store_true",
                        help="run the BatchSuggestTrials coalescing scenario")
    parser.add_argument("--remote-pythia", action="store_true",
                        help="run the Figure-2 remote-Pythia scenario "
                             "(coalesced vs per-study-RPC dispatch)")
    parser.add_argument("--warm-start", action="store_true",
                        help="run the warm-started GP-bandit scenario "
                             "(persisted PolicyState vs cold refit)")
    parser.add_argument("--transfer", action="store_true",
                        help="run the transfer-learning scenario (stacked "
                             "residual GP over a prior study vs cold, "
                             "trials-to-target on a shifted objective)")
    args = parser.parse_args()
    if args.batched:
        for n in (1, 8, 64):
            bench_batched_throughput(n)
        return
    if args.remote_pythia:
        for n in (1, 8, 64):
            bench_remote_pythia(n)
        return
    if args.warm_start:
        bench_warm_start()
        return
    if args.transfer:
        bench_transfer()
        return
    for n in (1, 4, 16):
        bench_throughput(n)
    bench_crash_recovery()


if __name__ == "__main__":
    main()
