"""Roofline report: renders the §Roofline table from the dry-run JSONs.

Per (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS (useful-compute fraction), per-device
memory, and the roofline fraction (useful compute time / optimistic step
time) that §Perf hillclimbs.
"""

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
PEAK = 197e12


def load_records(results_dir=RESULTS):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_fraction(rec) -> float:
    """useful model FLOPs time / optimistic step time (higher = better)."""
    if not rec.get("ok"):
        return 0.0
    useful_s = rec["model_flops_per_device"] / PEAK
    step = rec["roofline"]["step_time_s"]
    return useful_s / step if step > 0 else 0.0


def render_table(recs, *, mesh="16x16") -> str:
    rows = []
    header = (f"{'arch':<18} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
              f"{'coll_s':>10} {'dom':>10} {'mem/dev':>8} {'useful%':>8} "
              f"{'roofline%':>9}")
    rows.append(header)
    rows.append("-" * len(header))
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            rows.append(f"{r['arch']:<18} {r['shape']:<12} "
                        f"{'SKIP (' + r['reason'][:48] + ')':>60}")
            continue
        if not r.get("ok"):
            rows.append(f"{r['arch']:<18} {r['shape']:<12} FAILED: "
                        f"{r.get('error', '')[:60]}")
            continue
        rf = r["roofline"]
        rows.append(
            f"{r['arch']:<18} {r['shape']:<12} {rf['compute_s']:>10.4f} "
            f"{rf['memory_s']:>10.4f} {rf['collective_s']:>10.4f} "
            f"{rf['dominant']:>10} "
            f"{r['memory']['total_per_device']/1e9:>7.1f}G "
            f"{100*min(r['useful_flops_fraction'],9.99):>7.1f}% "
            f"{100*roofline_fraction(r):>8.2f}%")
    return "\n".join(rows)


def main() -> None:
    recs = load_records()
    if not recs:
        print("roofline.report,0,NO_DRYRUN_RESULTS (run repro.launch.dryrun --sweep)")
        return
    ok = [r for r in recs if r.get("ok")]
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in ok if r.get("mesh") == mesh]
        if not sub:
            continue
        fracs = [roofline_fraction(r) for r in sub]
        mean_frac = sum(fracs) / len(fracs)
        print(f"roofline.cells.{mesh},{len(sub)},mean_roofline_frac="
              f"{100*mean_frac:.2f}%")
    print()
    print(render_table(recs, mesh="16x16"))


if __name__ == "__main__":
    main()
