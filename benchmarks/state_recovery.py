"""Paper §6.3: metadata state saving makes policy restore O(1) in study size.

Compares suggestion latency of DesignerPolicy (replays ALL completed trials)
vs SerializableDesignerPolicy (restores from metadata + loads only NEW
trials), as the study grows. The paper's claim: the gap widens linearly.
"""

from benchmarks.bench_util import emit, timeit

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.core.study import Study
from repro.pythia.designers import DesignerPolicy, SerializableDesignerPolicy
from repro.pythia.evolution import RegularizedEvolutionDesigner
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore


def _setup(n_trials: int):
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1,
                                                   scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    ds = InMemoryDatastore()
    study = Study(name=f"owners/b/studies/sr{n_trials}", study_config=cfg)
    ds.create_study(study)
    for i in range(n_trials):
        t = Trial(parameters={"x": (i % 100) / 100})
        t = ds.create_trial(study.name, t)
        t.complete(Measurement(metrics={"obj": (i % 7) / 7}))
        ds.update_trial(study.name, t)
    return cfg, ds, study


def main() -> None:
    for n in (100, 1000, 5000):
        cfg, ds, study = _setup(n)
        supporter = DatastorePolicySupporter(ds, study.name)

        def replay_suggest():
            policy = DesignerPolicy(
                supporter, lambda c: RegularizedEvolutionDesigner(c))
            req = SuggestRequest(
                study_descriptor=StudyDescriptor(config=ds.get_study(study.name
                                                                     ).study_config,
                                                 guid=study.name), count=1)
            policy.suggest(req)

        us_replay = timeit(replay_suggest, repeats=3)

        # warm up the serializable policy once so state exists in metadata
        ser = SerializableDesignerPolicy(
            supporter, lambda c: RegularizedEvolutionDesigner(c),
            RegularizedEvolutionDesigner)
        req = SuggestRequest(
            study_descriptor=StudyDescriptor(
                config=ds.get_study(study.name).study_config, guid=study.name),
            count=1)
        ser.suggest(req)

        def metadata_suggest():
            policy = SerializableDesignerPolicy(
                supporter, lambda c: RegularizedEvolutionDesigner(c),
                RegularizedEvolutionDesigner)
            r = SuggestRequest(
                study_descriptor=StudyDescriptor(
                    config=ds.get_study(study.name).study_config,
                    guid=study.name), count=1)
            policy.suggest(r)
            assert policy.last_restore_was_incremental

        us_meta = timeit(metadata_suggest, repeats=3)
        emit(f"sec6.3.state_recovery.n={n}", us_meta,
             f"replay_us={us_replay:.0f} speedup={us_replay/us_meta:.1f}x")


if __name__ == "__main__":
    main()
