"""Paper §5: convergence vs #workers, with injected worker crashes.

Synthetic objective (6-d shifted sphere in log space); measures best-so-far
after a fixed trial budget for 1 vs 4 workers, and with a crash+rebind in
the middle (result must not regress — the reassigned trial completes).
"""

import threading
import time

from benchmarks.bench_util import emit

from repro.core import ScaleType, StudyConfig
from repro.service import DefaultVizierServer, VizierClient


def objective(params) -> float:
    import math

    total = 0.0
    for i in range(6):
        x = params.get_value(f"x{i}")
        total -= (x - 0.3 - 0.05 * i) ** 2
    return total


def _config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    for i in range(6):
        root.add_float_param(f"x{i}", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def run_study(n_workers: int, budget: int, crash: bool = False) -> float:
    server = DefaultVizierServer(reassign_stalled_after=0.5)
    seed = VizierClient.load_or_create_study(
        f"pt-{n_workers}-{crash}", _config(), client_id="seed",
        target=server.address)
    done = {"count": 0}
    lock = threading.Lock()

    def worker(wid, max_trials):
        from repro.service.rpc import StatusCode, VizierRpcError

        c = VizierClient(server.address, seed.study_name, f"w{wid}")
        while True:
            with lock:
                if done["count"] >= budget:
                    return
            (t,) = c.get_suggestions(count=1)
            try:
                c.complete_trial({"obj": objective(t.parameters)}, trial_id=t.id)
            except VizierRpcError as e:
                # a reassigned trial may race to completion between workers —
                # the service correctly rejects the second CompleteTrial
                if e.code != StatusCode.FAILED_PRECONDITION:
                    raise
            with lock:
                done["count"] += 1

    if crash:
        # worker 0 takes a trial and dies; its trial must be recovered
        c0 = VizierClient(server.address, seed.study_name, "w0")
        c0.get_suggestions(count=1)
        time.sleep(0.6)  # exceed the stall timeout

    threads = [threading.Thread(target=worker, args=(i, budget))
               for i in range(n_workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    trials = seed.list_trials()
    best = max(t.final_objective("obj") for t in trials
               if t.final_objective("obj") is not None)
    server.stop()
    return best, wall, len(trials)


def main() -> None:
    for workers, crash in [(1, False), (4, False), (4, True)]:
        best, wall, n = run_study(workers, budget=24, crash=crash)
        emit(f"sec5.parallel.workers={workers}.crash={crash}",
             wall / max(n, 1) * 1e6,
             f"best={best:.4f} trials={n} wall_s={wall:.1f}")


if __name__ == "__main__":
    main()
