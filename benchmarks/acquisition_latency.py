"""`make bench-acquisition`: suggest-op latency, engine vs pre-engine path.

Measures median GP-bandit suggest-operation wall time in the STEADY-STATE
SERVING regime at n in {50, 300, 1000} completed trials x count in {1, 8}
batch members: every measured round first lands one newly completed trial
(as a live study does between operations), then times one suggest op per
path against the identical datastore state. The growing trial count is the
point — it is exactly what made the pre-engine acquisition retrace its
jitted ``_ucb``/``_posterior`` kernels on every operation (each distinct
(n_trials, pool) shape recompiles) on top of refactorizing K(X, X) once per
batch member; the engine's bucket-padded shapes absorb the growth with zero
recompiles and one Cholesky + rank-1 appends per op.

Paths: the factorized-posterior engine (default) vs the pre-engine
acquisition kept in-tree (``GPBanditPolicy(use_engine=False)``). Both run
warm-started (persisted PolicyState) on the same study.

Emits one line per scenario plus the speedup, and writes the whole run to
``BENCH_acquisition.json`` so the perf trajectory is machine-readable from
this PR onward.

Large-n regime: above ``SPARSE_THRESHOLD`` completed trials the engine
switches to the SGPR inducing-point posterior (Pallas/XLA triangular-solve +
cholupdate kernels against the m×m inducing factor), so n=5000 runs
ENGINE-ONLY — the pre-engine path at that scale refactorizes an n×n
Cholesky per batch member and is not a serving configuration.

Floors (asserted PASS/FAIL, mirrored in the acceptance criteria):
  * >= 5x median suggest-op speedup at n=300, count=8
  * no regression at n=50, count=1 (engine <= 1.15x of the baseline)
  * <= 100 ms median suggest op at n=5000, count=1 (sparse path)
"""

import argparse
import json
import os
import time

from benchmarks.bench_util import emit

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.core.study import Study
from repro.pythia.gp_bandit import GPBanditPolicy
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore

SPEEDUP_FLOOR = 5.0          # at n=300, count=8
REGRESSION_CEILING = 1.15    # at n=50, count=1
SPARSE_FLOOR_MS = 100.0      # at n=5000, count=1 (engine-only, sparse path)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(_ROOT, "BENCH_acquisition.json")


def _config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0, 1, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0, 1, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def _add_trial(ds, study, i: int, n: int) -> None:
    x = (i * 0.6180339887) % 1.0
    y = ((i * 7919) % max(n, 2)) / max(n, 2)
    t = Trial(parameters={"x": x, "y": y})
    t.complete(Measurement(
        metrics={"obj": -(x - 0.37) ** 2 - 0.5 * (y - 0.61) ** 2}))
    ds.create_trial(study.name, t)


def _seeded_study(n: int, count: int):
    ds = InMemoryDatastore()
    study = Study(name=f"owners/bench/studies/acq-{n}-{count}",
                  study_config=_config())
    ds.create_study(study)
    for i in range(n):
        _add_trial(ds, study, i, n)
    return ds, study


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def bench_scenario(n: int, count: int, *, repeats: int, warmup: int) -> dict:
    """Median suggest-op wall per path, live-serving regime.

    Each round lands one newly completed trial, then times one op per path
    at the identical datastore state — so the pre-engine path pays what it
    really paid in production (a fresh (n_trials, pool) shape every op ->
    retrace + per-member refactorization) while the engine stays inside its
    shape bucket. Paths alternate within a round for a paired comparison.
    """
    ds, study = _seeded_study(n, count)
    supporter = DatastorePolicySupporter(ds, study.name)

    def run(use_engine: bool) -> float:
        config = ds.get_study(study.name).study_config  # fresh metadata
        policy = GPBanditPolicy(supporter, use_engine=use_engine)
        t0 = time.perf_counter()
        decision = policy.suggest(SuggestRequest(
            study_descriptor=StudyDescriptor(config=config, guid=study.name),
            count=count))
        assert len(decision.suggestions) == count
        return time.perf_counter() - t0

    engine_s, pre_engine_s = [], []
    for r in range(warmup + repeats):
        _add_trial(ds, study, n + r, n)  # the study grows between ops
        te = run(True)
        tl = run(False)
        if r >= warmup:  # warmup rounds settle the warm-started fit
            engine_s.append(te)
            pre_engine_s.append(tl)
    results = {"engine": _median(engine_s), "pre_engine": _median(pre_engine_s)}
    speedup = results["pre_engine"] / max(results["engine"], 1e-9)
    emit(f"acquisition.n={n}.count={count}", results["engine"] * 1e6,
         f"engine_ms={results['engine']*1e3:.1f} "
         f"pre_engine_ms={results['pre_engine']*1e3:.1f} "
         f"speedup={speedup:.2f}x")
    return {"n": n, "count": count,
            "engine_ms": results["engine"] * 1e3,
            "pre_engine_ms": results["pre_engine"] * 1e3,
            "speedup": speedup}


def bench_sparse_scenario(n: int, count: int, *, repeats: int,
                          warmup: int) -> dict:
    """Median ENGINE-ONLY suggest-op wall at large n (sparse posterior).

    Same live-serving regime as ``bench_scenario`` (one completion lands
    between ops) without the pre-engine baseline: at this scale the
    pre-engine path refactorizes the full n×n Cholesky per batch member and
    is not something anyone serves. Asserts the op actually took the sparse
    path."""
    ds, study = _seeded_study(n, count)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter)

    samples = []
    for r in range(warmup + repeats):
        _add_trial(ds, study, n + r, n)
        config = ds.get_study(study.name).study_config  # fresh metadata
        t0 = time.perf_counter()
        decision = policy.suggest(SuggestRequest(
            study_descriptor=StudyDescriptor(config=config, guid=study.name),
            count=count))
        wall = time.perf_counter() - t0
        assert len(decision.suggestions) == count
        assert policy.last_sparse, "n=%d op did not take the sparse path" % n
        if r >= warmup:
            samples.append(wall)
    med_ms = _median(samples) * 1e3
    emit(f"acquisition.sparse.n={n}.count={count}", med_ms * 1e3,
         f"engine_ms={med_ms:.1f} (sparse inducing-point path)")
    return {"n": n, "count": count, "engine_ms": med_ms,
            "pre_engine_ms": None, "speedup": None, "sparse": True}


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--repeats", type=int, default=5)
    parser.add_argument("--warmup", type=int, default=2)
    parser.add_argument("--out", default=OUT_PATH)
    args = parser.parse_args()

    scenarios = []
    for n in (50, 300, 1000):
        for count in (1, 8):
            scenarios.append(bench_scenario(n, count, repeats=args.repeats,
                                            warmup=args.warmup))
    scenarios.append(bench_sparse_scenario(5000, 1, repeats=args.repeats,
                                           warmup=args.warmup))

    by_key = {(s["n"], s["count"]): s for s in scenarios}
    hot = by_key[(300, 8)]
    small = by_key[(50, 1)]
    sparse = by_key[(5000, 1)]
    hot_pass = hot["speedup"] >= SPEEDUP_FLOOR
    small_pass = small["engine_ms"] <= small["pre_engine_ms"] * REGRESSION_CEILING
    sparse_pass = sparse["engine_ms"] <= SPARSE_FLOOR_MS
    verdict = "PASS" if (hot_pass and small_pass and sparse_pass) else "FAIL"
    emit("acquisition.floor.n=300.count=8", hot["speedup"],
         f"speedup={hot['speedup']:.2f}x (floor {SPEEDUP_FLOOR}x) "
         f"{'PASS' if hot_pass else 'FAIL'}")
    emit("acquisition.floor.n=50.count=1",
         small["engine_ms"] / max(small["pre_engine_ms"], 1e-9),
         f"engine/pre_engine={small['engine_ms']/small['pre_engine_ms']:.2f} "
         f"(ceiling {REGRESSION_CEILING}) {'PASS' if small_pass else 'FAIL'}")
    emit("acquisition.floor.n=5000.count=1", sparse["engine_ms"],
         f"engine_ms={sparse['engine_ms']:.1f} (floor {SPARSE_FLOOR_MS}ms) "
         f"{'PASS' if sparse_pass else 'FAIL'}")

    payload = {
        "bench": "acquisition_latency",
        "unit": "ms per suggest operation (median, warm-started)",
        "floors": {"speedup_n300_count8": SPEEDUP_FLOOR,
                   "regression_ceiling_n50_count1": REGRESSION_CEILING,
                   "sparse_ms_n5000_count1": SPARSE_FLOOR_MS},
        "scenarios": scenarios,
        "verdict": verdict,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} verdict={verdict}")
    return 0 if verdict == "PASS" else 1


if __name__ == "__main__":
    raise SystemExit(main())
