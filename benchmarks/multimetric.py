"""`make bench-multimetric`: hypervolume-vs-trials, GP bandit vs NSGA-II.

Runs the multi-metric GP bandit (the DEFAULT policy for multi-objective
studies since schema v4) and the NSGA-II baseline head-to-head on two
synthetic multi-objective problems — sequential suggest/evaluate/complete
loops of ``N_TRIALS`` trials each — and reports the hypervolume of the
observed Pareto frontier at fixed checkpoints against a FIXED, explicit
reference point (never the data-derived one: both algorithms must be scored
in the same box).

Problems (unit square inputs, larger-is-better objectives):
  * branin2d-ish "two peaks" (k=2): m_j = -||x - c_j||², competing optima at
    c_1 = (0.2, 0.7) and c_2 = (0.8, 0.3); the Pareto set is the segment
    between the peaks.
  * "three peaks" (k=3): same construction with three competing centers;
    hypervolume via the Monte-Carlo estimator (k >= 3).

Floor (asserted PASS/FAIL, mirrored in the acceptance criteria): the GP
bandit's hypervolume at ``N_TRIALS`` completed trials must be >= NSGA-II's
on BOTH problems. The model-based policy should buy its fit cost back in
sample efficiency at expensive-evaluation trial counts; if it cannot even
match the evolutionary baseline, the scalarized acquisition regressed.

Writes ``BENCH_multimetric.json`` so the trajectory is machine-readable
from this PR onward.
"""

import argparse
import json
import os

import numpy as np

from benchmarks.bench_util import emit

from repro.core import Measurement, StudyConfig, Trial
from repro.core.pareto import hypervolume, pareto_frontier_indices
from repro.core.study import Study
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.registry import make_policy
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore

N_TRIALS = 50
CHECKPOINTS = (10, 25, 50)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(_ROOT, "BENCH_multimetric.json")

# Objective values are bounded below by -(the squared diameter of the unit
# square) = -2; the reference point sits below every achievable value so
# frontier growth anywhere is rewarded, and is shared by both algorithms.
REF_VALUE = -2.1

PROBLEMS = {
    "two-peaks-k2": [(0.2, 0.7), (0.8, 0.3)],
    "three-peaks-k3": [(0.2, 0.7), (0.8, 0.3), (0.5, 0.95)],
}


def _objectives(centers, x0: float, x1: float) -> dict:
    return {
        f"m{j}": -((x0 - cx) ** 2 + (x1 - cy) ** 2)
        for j, (cx, cy) in enumerate(centers)
    }


def _config(centers, algorithm: str) -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x0", 0.0, 1.0)
    root.add_float_param("x1", 0.0, 1.0)
    for j in range(len(centers)):
        cfg.metrics.add(f"m{j}", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


def run_loop(problem: str, algorithm: str) -> dict:
    """One sequential optimization loop; hypervolume at each checkpoint."""
    centers = PROBLEMS[problem]
    k = len(centers)
    cfg = _config(centers, algorithm)
    ds = InMemoryDatastore()
    study = Study(name=f"owners/bench/studies/mm-{problem}-{algorithm}",
                  study_config=cfg)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = make_policy(algorithm, supporter, cfg)
    ref = np.full((k,), REF_VALUE)
    ys = []
    hv_at = {}
    for i in range(N_TRIALS):
        config = ds.get_study(study.name).study_config  # fresh metadata
        decision = policy.suggest(SuggestRequest(
            study_descriptor=StudyDescriptor(config=config, guid=study.name),
            count=1))
        params = decision.suggestions[0].parameters
        x0 = params["x0"].as_float
        x1 = params["x1"].as_float
        metrics = _objectives(centers, x0, x1)
        t = Trial(parameters={"x0": x0, "x1": x1})
        t.complete(Measurement(metrics=metrics))
        ds.create_trial(study.name, t)
        ys.append([metrics[f"m{j}"] for j in range(k)])
        if (i + 1) in CHECKPOINTS:
            y = np.asarray(ys)
            front = y[pareto_frontier_indices(y)]
            hv_at[i + 1] = float(hypervolume(front, ref))
    return {"problem": problem, "algorithm": algorithm, "k": k,
            "hv_at": hv_at}


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default=OUT_PATH)
    args = parser.parse_args()

    runs = []
    floors = []
    for problem in PROBLEMS:
        gp = run_loop(problem, "DEFAULT")
        nsga = run_loop(problem, "NSGA2")
        runs += [gp, nsga]
        gp_hv = gp["hv_at"][N_TRIALS]
        nsga_hv = nsga["hv_at"][N_TRIALS]
        ok = gp_hv >= nsga_hv
        floors.append(ok)
        emit(f"multimetric.{problem}.hv_at_{N_TRIALS}", gp_hv * 1e6,
             f"gp_hv={gp_hv:.4f} nsga_hv={nsga_hv:.4f} "
             f"{'PASS' if ok else 'FAIL'}")

    verdict = "PASS" if all(floors) else "FAIL"
    payload = {
        "bench": "multimetric",
        "unit": f"hypervolume at trial checkpoints {list(CHECKPOINTS)} "
                f"(fixed reference point {REF_VALUE} per metric)",
        "floors": {f"gp_hv_ge_nsga_hv_at_{N_TRIALS}": True},
        "runs": runs,
        "verdict": verdict,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} verdict={verdict}")
    return 0 if verdict == "PASS" else 1


if __name__ == "__main__":
    raise SystemExit(main())
