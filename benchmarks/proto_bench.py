"""Paper Figure 3 / §4.3: PyVizier <-> wire-format conversion throughput."""

import msgpack

from benchmarks.bench_util import emit, timeit

from repro.core import Measurement, StudyConfig, Trial, ScaleType


def main() -> None:
    t = Trial(id=42, parameters={"lr": 3e-4, "model": "vgg", "layers": 5})
    for i in range(20):
        t.add_measurement(Measurement(metrics={"acc": 0.5 + i / 100,
                                               "loss": 2.0 - i / 50}, steps=i))
    t.complete(Measurement(metrics={"acc": 0.7, "num_params": 20423}))

    proto = t.to_proto()
    emit("fig3.trial.to_proto", timeit(lambda: t.to_proto(), repeats=20),
         f"measurements={len(t.measurements)}")
    emit("fig3.trial.from_proto", timeit(lambda: Trial.from_proto(proto),
                                         repeats=20), "")
    wire = msgpack.packb(proto, use_bin_type=True)
    emit("fig3.trial.wire_encode",
         timeit(lambda: msgpack.packb(proto, use_bin_type=True), repeats=20),
         f"wire_bytes={len(wire)}")
    emit("fig3.trial.wire_decode",
         timeit(lambda: msgpack.unpackb(wire, raw=False), repeats=20), "")

    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    cat = root.add_categorical_param("model", ["linear", "dnn"])
    cat.select_values(["dnn"]).add_int_param("layers", 1, 8)
    cfg.metrics.add("acc", "MAXIMIZE")
    sproto = cfg.to_proto()
    emit("fig3.study_config.roundtrip",
         timeit(lambda: StudyConfig.from_proto(cfg.to_proto()), repeats=20),
         f"params={len(cfg.search_space.all_parameters())}")


if __name__ == "__main__":
    main()
