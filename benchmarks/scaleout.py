"""`make bench-scaleout`: Pythia worker-pool throughput + long-poll latency.

Two claims of the scale-out serving tier, measured end-to-end over real
sockets and written to ``BENCH_scaleout.json``:

1. **Worker-pool scaling** — suggestions/sec with N threaded clients driving
   16 studies through one API server, 1 Pythia worker vs 8. The policy is a
   fixed-cost stand-in (``FIXED_COST_BENCH``: ~4 ms sleep per *suggestion*,
   releasing the GIL — the shape of per-candidate acquisition work in a
   model-backed policy), so the pool's shard-parallelism is what moves the
   number, not Python overhead noise. Floor: **8 workers >= 2x 1 worker at
   64 and 256 clients**.

2. **WaitOperation long-poll latency** — median end-to-end suggest latency
   for one client, long-poll vs the legacy GetOperation poll ladder whose
   first sleep alone was ``poll_interval`` = 20 ms. Floor: **long-poll
   median < 20 ms** (completion latency is no longer quantized by the
   client's poll schedule).

3. **Sharded datastore isolation** — the single-file SQLite backend holds
   ONE connection lock across every transaction, so one study's heavy
   writes serialize all studies (ROADMAP open item: the storage tier as a
   single point of contention). Workload: 8 "worker" threads continuously
   persisting 1 MiB checkpoint blobs (the shape of ``repro.gp_bandit``
   state writes) to their own studies while 56 client threads run
   suggest-shaped trial writes on 16 other studies — 64 concurrent clients
   total, both backends at ``synchronous=FULL`` (commits fsync; acked work
   survives power loss, the durability level the crash tests assume).
   Floor: **sharded light-op throughput >= 2x single-file** at 64 clients /
   8 checkpointing workers. Per-commit fsync bandwidth is identical for
   both backends (same disk); the ratio isolates exactly the lock: on the
   sharded backend a checkpoint only stalls its own shard file, never the
   other 7.
"""

import argparse
import json
import os
import tempfile
import threading
import time

from benchmarks.bench_util import emit

from repro.core import ScaleType, StudyConfig, Trial
from repro.core.study import Study
from repro.pythia.baseline_designers import RandomSearchDesigner
from repro.pythia.policy import Policy, SuggestDecision
from repro.pythia.registry import register
from repro.service import DefaultVizierServer, VizierClient
from repro.service.datastore import ShardedSqliteDatastore, SQLiteDatastore

TPUT_FLOOR = 2.0        # 8-worker suggestions/sec >= 2x 1-worker, 64+ clients
LATENCY_FLOOR_S = 0.02  # long-poll median < the old first poll interval
DATASTORE_FLOOR = 2.0   # sharded light-op tput >= 2x single-file, 64 clients

N_STUDIES = 16
POLICY_COST_S = 0.004
CHECKPOINT_BYTES = 1 << 20  # one repro.gp_bandit state blob per hot write

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(_ROOT, "BENCH_scaleout.json")


class _FixedCostPolicy(Policy):
    """Burns ~4 ms per *suggestion* (sleep releases the GIL), then suggests
    uniformly — models per-candidate acquisition cost, the shape of a
    model-backed policy. Per-suggestion (not per-invocation) cost matters:
    the coalesced dispatch folds a whole shard backlog into one invocation
    with the summed count, so a per-invocation cost would be amortized away
    by batching and hide the worker parallelism this benchmark measures."""

    def __init__(self, config: StudyConfig):
        self._config = config

    def suggest(self, request) -> SuggestDecision:
        time.sleep(POLICY_COST_S * max(int(request.count), 1))
        designer = RandomSearchDesigner(request.study_config)
        return SuggestDecision(suggestions=list(designer.suggest(request.count)))


@register("FIXED_COST_BENCH")
def _fixed_cost(supporter, config):
    return _FixedCostPolicy(config)


def _config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0, 1, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0, 1, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "FIXED_COST_BENCH"
    return cfg


def bench_suggest_tput(n_clients: int, n_workers: int, rounds: int) -> dict:
    """N threaded clients round-robined over 16 studies; suggestions/sec."""
    server = DefaultVizierServer(n_pythia_workers=n_workers,
                                 n_shards=N_STUDIES)
    names = []
    for i in range(N_STUDIES):
        c = VizierClient.load_or_create_study(
            f"scaleout-{i}", _config(), client_id="seed",
            target=server.address)
        names.append(c.study_name)
        c.close()
    errs, done = [], [0]
    lock = threading.Lock()

    def worker(wid):
        try:
            c = VizierClient(server.address, names[wid % N_STUDIES],
                             f"w{wid}")
            for _ in range(rounds):
                (t,) = c.get_suggestions(count=1, timeout=120.0)
                c.complete_trial({"obj": 0.1}, trial_id=t.id)
                with lock:
                    done[0] += 1
            c.close()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    server.stop()
    assert not errs, errs[:3]
    tput = done[0] / wall
    emit(f"scaleout.tput.clients={n_clients}.workers={n_workers}",
         wall / done[0] * 1e6,
         f"suggestions_per_sec={tput:.1f} wall={wall:.2f}s")
    return {"clients": n_clients, "workers": n_workers,
            "suggestions": done[0], "wall_s": wall,
            "suggestions_per_sec": tput}


def bench_longpoll_latency(rounds: int = 30) -> dict:
    """Median end-to-end suggest latency, long-poll vs legacy polling."""
    server = DefaultVizierServer(n_pythia_workers=1, n_shards=4)
    seed = VizierClient.load_or_create_study(
        "longpoll", _config(), client_id="seed", target=server.address)
    out = {}
    for mode, long_poll in (("long_poll", True), ("legacy_poll", False)):
        c = VizierClient(server.address, seed.study_name, f"lat-{mode}",
                         long_poll=long_poll)
        lats = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            (t,) = c.get_suggestions(count=1, timeout=60.0)
            lats.append(time.perf_counter() - t0)
            c.complete_trial({"obj": 0.1}, trial_id=t.id)
        c.close()
        lats.sort()
        out[mode] = lats[len(lats) // 2]
        emit(f"scaleout.latency.{mode}", out[mode] * 1e6,
             f"median_ms={out[mode]*1e3:.2f} p90_ms={lats[int(len(lats)*0.9)]*1e3:.2f}")
    seed.close()
    server.stop()
    return {"long_poll_median_s": out["long_poll"],
            "legacy_poll_median_s": out["legacy_poll"]}


def _bench_study_config() -> StudyConfig:
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1)
    cfg.metrics.add("m", "MAXIMIZE")
    return cfg


def _drive_datastore(ds, n_hot: int, n_light: int, secs: float) -> dict:
    """Hot checkpoint writers + light suggest-shaped writers, direct drive.

    Returns light/hot ops-per-second. Direct datastore calls (no sockets)
    so the backend lock is the only thing under test."""
    cfg = _bench_study_config()
    light_names = []
    for i in range(N_STUDIES):
        s = Study(name=f"owners/bench/studies/light{i}", display_name="s",
                  study_config=cfg)
        ds.create_study(s)
        light_names.append(s.name)
    hot_names = []
    for i in range(n_hot):
        s = Study(name=f"owners/bench/studies/hot{i}", display_name="s",
                  study_config=cfg)
        ds.create_study(s)
        hot_names.append(s.name)
    blob = os.urandom(CHECKPOINT_BYTES)
    stop = threading.Event()
    errs, counts = [], {"light": 0, "hot": 0}
    lock = threading.Lock()

    def hot(hid: int):
        i = 0
        try:
            while not stop.is_set():
                ds.put_operation({
                    "name": f"{hot_names[hid]}/operations/ckpt{i}",
                    "study_name": hot_names[hid], "done": True,
                    "result": {"state": blob}})
                i += 1
        except Exception as e:  # noqa: BLE001
            errs.append(e)
        with lock:
            counts["hot"] += i

    def light(wid: int):
        name = light_names[wid % N_STUDIES]
        n = 0
        try:
            while not stop.is_set():
                ds.create_trial(name, Trial(parameters={"x": 0.5},
                                            client_id=f"c{wid}"))
                n += 1
        except Exception as e:  # noqa: BLE001
            errs.append(e)
        with lock:
            counts["light"] += n

    threads = ([threading.Thread(target=hot, args=(i,))
                for i in range(n_hot)] +
               [threading.Thread(target=light, args=(i,))
                for i in range(n_light)])
    for t in threads:
        t.start()
    time.sleep(secs)
    stop.set()
    for t in threads:
        t.join()
    assert not errs, errs[:3]
    return {"light_ops_per_sec": counts["light"] / secs,
            "hot_ops_per_sec": counts["hot"] / secs}


def bench_datastore_backends(n_hot: int = 8, n_light: int = 56,
                             secs: float = 4.0) -> dict:
    """Single-file vs sharded SQLite under checkpoint-heavy contention."""
    out = {"clients": n_hot + n_light, "hot_writers": n_hot,
           "checkpoint_bytes": CHECKPOINT_BYTES, "synchronous": "FULL"}
    with tempfile.TemporaryDirectory(prefix="scaleout-ds-") as root:
        single = SQLiteDatastore(os.path.join(root, "single.sqlite3"),
                                 synchronous="FULL")
        out["single"] = _drive_datastore(single, n_hot, n_light, secs)
        single.close()
        sharded = ShardedSqliteDatastore(os.path.join(root, "sharded"),
                                         n_shards=8, synchronous="FULL")
        out["sharded"] = _drive_datastore(sharded, n_hot, n_light, secs)
        sharded.close()
    ratio = (out["sharded"]["light_ops_per_sec"]
             / max(out["single"]["light_ops_per_sec"], 1e-9))
    out["light_tput_ratio"] = ratio
    emit("scaleout.datastore.single_light",
         out["single"]["light_ops_per_sec"],
         f"light_ops_per_sec={out['single']['light_ops_per_sec']:.0f}")
    emit("scaleout.datastore.sharded_light",
         out["sharded"]["light_ops_per_sec"],
         f"light_ops_per_sec={out['sharded']['light_ops_per_sec']:.0f}")
    return out


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--rounds", type=int, default=6,
                        help="suggest+complete rounds per client thread")
    parser.add_argument("--clients", default="64,256",
                        help="comma-separated client counts")
    parser.add_argument("--out", default=OUT_PATH)
    args = parser.parse_args()
    client_counts = [int(x) for x in args.clients.split(",")]

    scenarios = []
    for n_clients in client_counts:
        for n_workers in (1, 8):
            scenarios.append(
                bench_suggest_tput(n_clients, n_workers, rounds=args.rounds))
    latency = bench_longpoll_latency()
    datastore = bench_datastore_backends()

    by_key = {(s["clients"], s["workers"]): s for s in scenarios}
    floors = []
    for n_clients in client_counts:
        single = by_key[(n_clients, 1)]["suggestions_per_sec"]
        pooled = by_key[(n_clients, 8)]["suggestions_per_sec"]
        scaling = pooled / max(single, 1e-9)
        ok = scaling >= TPUT_FLOOR
        floors.append(ok)
        emit(f"scaleout.floor.clients={n_clients}", scaling,
             f"8w/1w={scaling:.2f}x (floor {TPUT_FLOOR}x) "
             f"{'PASS' if ok else 'FAIL'}")
    lat_ok = latency["long_poll_median_s"] < LATENCY_FLOOR_S
    floors.append(lat_ok)
    emit("scaleout.floor.longpoll_latency",
         latency["long_poll_median_s"] * 1e6,
         f"median={latency['long_poll_median_s']*1e3:.2f}ms "
         f"(floor {LATENCY_FLOOR_S*1e3:.0f}ms) {'PASS' if lat_ok else 'FAIL'}")
    ds_ok = datastore["light_tput_ratio"] >= DATASTORE_FLOOR
    floors.append(ds_ok)
    emit("scaleout.floor.datastore_sharding", datastore["light_tput_ratio"],
         f"sharded/single={datastore['light_tput_ratio']:.2f}x "
         f"(floor {DATASTORE_FLOOR}x) {'PASS' if ds_ok else 'FAIL'}")

    verdict = "PASS" if all(floors) else "FAIL"
    payload = {
        "bench": "scaleout",
        "unit": "suggestions/sec (throughput), seconds (latency medians)",
        "policy_cost_s": POLICY_COST_S,
        "n_studies": N_STUDIES,
        "floors": {"tput_8w_over_1w": TPUT_FLOOR,
                   "longpoll_median_s": LATENCY_FLOOR_S,
                   "datastore_sharded_over_single": DATASTORE_FLOOR},
        "throughput": scenarios,
        "latency": latency,
        "datastore": datastore,
        "verdict": verdict,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} verdict={verdict}")
    return 0 if verdict == "PASS" else 1


if __name__ == "__main__":
    raise SystemExit(main())
