"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only features,...]

Output: ``name,us_per_call,derived`` CSV lines per benchmark.
"""

import argparse
import sys
import traceback

from benchmarks import bench_util  # noqa: F401  (sets sys.path)

MODULES = [
    ("features", "paper Table 1 feature matrix, exercised end-to-end"),
    ("proto_bench", "paper Fig 3 / §4.3 PyVizier<->proto conversion"),
    ("service_throughput", "paper Fig 2 service throughput + crash recovery"),
    ("state_recovery", "paper §6.3 metadata O(1) state restore"),
    ("parallel_tuning", "paper §5 parallel workers + crash rebind"),
    ("kernel_bench", "Pallas kernels (interpret) + analytic FLOPs"),
    ("acquisition_latency",
     "GP-bandit suggest-op latency: posterior engine vs pre-engine path"),
    ("scaleout",
     "Pythia worker-pool throughput scaling + WaitOperation long-poll latency"),
    ("roofline_report", "§Roofline table from dry-run artifacts"),
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated subset of benchmark modules")
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, desc in MODULES:
        if only and name not in only:
            continue
        print(f"# --- {name}: {desc}", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}.FAILED,0,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
