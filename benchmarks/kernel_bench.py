"""Kernel micro-benchmarks: CPU-interpret sanity timings + analytic FLOPs.

Wall times here are interpret-mode (Python) — meaningless as TPU perf; the
derived column carries the analytic FLOP counts the roofline uses.
"""

import numpy as np
import jax.numpy as jnp

from benchmarks.bench_util import emit, timeit

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import matern52_gram_pallas
from repro.kernels.mamba2_ssd import ssd_scan_pallas

RNG = np.random.RandomState(0)


def main() -> None:
    # gram
    n, m, d = 256, 256, 32
    x1 = jnp.asarray(RNG.randn(n, d), jnp.float32)
    x2 = jnp.asarray(RNG.randn(m, d), jnp.float32)
    amp = jnp.asarray(1.0)
    us = timeit(lambda: matern52_gram_pallas(x1, x2, amp, interpret=True
                                             ).block_until_ready(), repeats=3)
    emit("kernel.gram.256x256x32", us, f"flops={2*n*m*d:.3e}")
    us = timeit(lambda: ref.matern52_gram(x1, x2, 1.0).block_until_ready(),
                repeats=3)
    emit("kernel.gram.ref_xla", us, "")

    # flash attention
    B, S, H, D = 1, 128, 4, 64
    q = jnp.asarray(RNG.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, D), jnp.float32)
    us = timeit(lambda: flash_attention_pallas(q, k, v, bq=64, bk=64,
                                               interpret=True
                                               ).block_until_ready(), repeats=3)
    emit("kernel.flash.B1S128H4D64", us, f"flops={4*B*H*S*S*D:.3e}")
    us = timeit(lambda: ref.attention(q, k, v).block_until_ready(), repeats=3)
    emit("kernel.flash.ref_xla", us, "")

    # ssd
    B, S, Hh, P, G, N = 1, 256, 4, 32, 2, 32
    x = jnp.asarray(RNG.randn(B, S, Hh, P), jnp.float32)
    dt = jnp.asarray(RNG.rand(B, S, Hh) * 0.3 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.rand(Hh)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    us = timeit(lambda: ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=64,
                                        interpret=True)[0].block_until_ready(),
                repeats=3)
    chunk = 64
    flops = B * Hh * (S // chunk) * (2 * chunk * chunk * N + 2 * chunk * chunk * P
                                     + 4 * chunk * P * N)
    emit("kernel.ssd.B1S256H4P32", us, f"flops={flops:.3e}")


if __name__ == "__main__":
    main()
