"""Designers & policies: feasibility, convergence, O(1) state recovery."""

import math

import pytest

from repro.core import (
    CompletedTrials,
    Measurement,
    Metadata,
    ObjectiveMetricGoal,
    ScaleType,
    StudyConfig,
    Trial,
)
from repro.pythia.baseline_designers import (
    GridSearchDesigner,
    HaltonDesigner,
    RandomSearchDesigner,
)
from repro.pythia.cmaes import CMAESDesigner
from repro.pythia.designers import SerializableDesignerPolicy
from repro.pythia.evolution import NSGA2Designer, RegularizedEvolutionDesigner
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.registry import make_policy, registered_algorithms
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore
from repro.core.study import Study


def quadratic(params) -> float:
    """Max at lr=0.01, layers=4."""
    lr = params.get_value("lr")
    layers = params.get_value("layers")
    return -((math.log10(lr) + 2) ** 2) - 0.2 * (layers - 4) ** 2


def evolve(designer, config, n=60, batch=4):
    best = -1e9
    uid = 0
    for _ in range(n // batch):
        suggestions = designer.suggest(batch)
        completed = []
        for s in suggestions:
            uid += 1
            config.search_space.validate_parameters(s.parameters)
            t = Trial(id=uid, parameters=s.parameters, metadata=s.metadata)
            val = quadratic(s.parameters)
            t.complete(Measurement(metrics={"acc": val}))
            best = max(best, val)
            completed.append(t)
        designer.update(CompletedTrials(completed))
    return best


@pytest.mark.parametrize("cls", [RandomSearchDesigner, RegularizedEvolutionDesigner,
                                 CMAESDesigner, HaltonDesigner])
def test_designer_improves_quadratic(cls, basic_config):
    best = evolve(cls(basic_config), basic_config)
    assert best > -2.0, f"{cls.__name__} best={best}"


def test_grid_covers_space(basic_config):
    d = GridSearchDesigner(basic_config, double_grid_resolution=3)
    seen = set()
    while True:
        batch = d.suggest(7)
        if not batch:
            break
        for s in batch:
            basic_config.search_space.validate_parameters(s.parameters)
            seen.add(tuple(sorted(s.parameters.as_dict().items())))
    assert len(seen) == d.grid_size  # exhaustive, no duplicates


def test_evolution_respects_conditionals(conditional_config):
    d = RegularizedEvolutionDesigner(conditional_config, population_size=8)
    uid = 0
    for _ in range(10):
        batch = d.suggest(4)
        completed = []
        for s in batch:
            conditional_config.search_space.validate_parameters(s.parameters)
            uid += 1
            t = Trial(id=uid, parameters=s.parameters)
            t.complete(Measurement(metrics={"acc": float(uid % 7)}))
            completed.append(t)
        d.update(CompletedTrials(completed))


def test_nsga2_pareto(basic_config):
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("f1", ObjectiveMetricGoal.MAXIMIZE)
    cfg.metrics.add("f2", ObjectiveMetricGoal.MAXIMIZE)
    d = NSGA2Designer(cfg, population_size=16)
    uid = 0
    for _ in range(15):
        batch = d.suggest(4)
        completed = []
        for s in batch:
            uid += 1
            x = s.parameters.get_value("x")
            t = Trial(id=uid, parameters=s.parameters)
            # concave front: f1 = x, f2 = 1 - x^2
            t.complete(Measurement(metrics={"f1": x, "f2": 1 - x * x}))
            completed.append(t)
        d.update(CompletedTrials(completed))
    front = d.pareto_front()
    assert len(front) >= 5  # spread along the front


def test_serializable_state_roundtrip(basic_config):
    d1 = RegularizedEvolutionDesigner(basic_config, population_size=6, seed=3)
    evolve(d1, basic_config, n=12, batch=4)
    md = d1.dump()
    d2 = RegularizedEvolutionDesigner(basic_config, population_size=6, seed=3)
    d2.load(md)
    assert d2._population == d1._population

    c1 = CMAESDesigner(basic_config, seed=1)
    evolve(c1, basic_config, n=12, batch=6)
    c2 = CMAESDesigner(basic_config, seed=1)
    c2.load(c1.dump())
    assert (c2._mean == c1._mean).all() and c2._gen == c1._gen


def test_serializable_policy_incremental_restore(basic_config):
    """Paper §6.3: restore is O(new trials), not O(all trials)."""
    ds = InMemoryDatastore()
    basic_config.algorithm = "REGULARIZED_EVOLUTION"
    study = Study(name="owners/o/studies/s", study_config=basic_config)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    total = 0
    for round_idx in range(3):
        study = ds.get_study(study.name)
        policy = SerializableDesignerPolicy(
            supporter, lambda cfg: RegularizedEvolutionDesigner(cfg),
            RegularizedEvolutionDesigner)
        request = SuggestRequest(
            study_descriptor=StudyDescriptor(config=study.study_config,
                                             guid=study.name), count=3)
        decision = policy.suggest(request)
        assert policy.last_restore_was_incremental == (round_idx > 0)
        # after the first round, only the NEW trials are loaded
        if round_idx > 0:
            assert policy.last_trials_loaded == 3
        for s in decision.suggestions:
            total += 1
            t = Trial(parameters=s.parameters, metadata=s.metadata)
            t = ds.create_trial(study.name, t)
            t.complete(Measurement(metrics={"acc": 0.1 * total}))
            ds.update_trial(study.name, t)


def test_registry_all_algorithms_suggest(basic_config):
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/reg", study_config=basic_config)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    for name in registered_algorithms():
        policy = make_policy(name, supporter, basic_config)
        request = SuggestRequest(
            study_descriptor=StudyDescriptor(config=basic_config,
                                             guid=study.name), count=2)
        decision = policy.suggest(request)
        assert len(decision.suggestions) == 2, name
        for s in decision.suggestions:
            basic_config.search_space.validate_parameters(s.parameters)
