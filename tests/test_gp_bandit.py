"""GP bandit: posterior sanity + convergence on a smooth objective."""

import math

import numpy as np

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.pythia.gp_bandit import GPBanditPolicy, GaussianProcessBandit
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.supporter import DatastorePolicySupporter
from repro.core.study import Study
from repro.service.datastore import InMemoryDatastore


def test_gp_posterior_interpolates():
    gp = GaussianProcessBandit(dim=1, fit_steps=80)
    x = np.linspace(0, 1, 8)[:, None]
    y = np.sin(3 * x[:, 0])
    raw = gp.fit(x, y)
    from repro.pythia.gp_bandit import _posterior
    import jax.numpy as jnp

    mean, std = _posterior(raw, jnp.asarray(x, jnp.float32),
                           jnp.asarray(y, jnp.float32),
                           jnp.asarray(x, jnp.float32))
    assert float(np.max(np.abs(np.asarray(mean) - y))) < 0.3
    xq = np.array([[0.5 / 7 + 0.0001]])
    _, std_q = _posterior(raw, jnp.asarray(x, jnp.float32),
                          jnp.asarray(y, jnp.float32),
                          jnp.asarray(xq, jnp.float32))
    assert float(std_q[0]) < 0.5  # near-data uncertainty is small


def test_gp_bandit_converges_1d():
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/gp", study_config=cfg)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, n_candidates=400, min_completed=4)

    f = lambda x: -(x - 0.731) ** 2
    best = -1e9
    for i in range(14):
        request = SuggestRequest(
            study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=1)
        (s,) = policy.suggest(request).suggestions
        x = s.parameters.get_value("x")
        t = Trial(parameters=s.parameters)
        t = ds.create_trial(study.name, t)
        t.complete(Measurement(metrics={"y": f(x)}))
        ds.update_trial(study.name, t)
        best = max(best, f(x))
    assert best > -0.004, f"GP-UCB best={best} (should be near 0)"
