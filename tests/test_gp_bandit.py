"""GP bandit: posterior sanity + convergence on a smooth objective."""

import math

import numpy as np

from repro.core import Measurement, ScaleType, StudyConfig, Trial, TrialState
from repro.pythia.gp_bandit import GPBanditPolicy, GaussianProcessBandit
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.supporter import DatastorePolicySupporter
from repro.core.study import Study
from repro.service.datastore import InMemoryDatastore


def test_gp_posterior_interpolates():
    gp = GaussianProcessBandit(dim=1, fit_steps=80)
    x = np.linspace(0, 1, 8)[:, None]
    y = np.sin(3 * x[:, 0])
    raw = gp.fit(x, y)
    from repro.pythia.gp_bandit import _posterior
    import jax.numpy as jnp

    mean, std = _posterior(raw, jnp.asarray(x, jnp.float32),
                           jnp.asarray(y, jnp.float32),
                           jnp.asarray(x, jnp.float32))
    assert float(np.max(np.abs(np.asarray(mean) - y))) < 0.3
    xq = np.array([[0.5 / 7 + 0.0001]])
    _, std_q = _posterior(raw, jnp.asarray(x, jnp.float32),
                          jnp.asarray(y, jnp.float32),
                          jnp.asarray(xq, jnp.float32))
    assert float(std_q[0]) < 0.5  # near-data uncertainty is small


def test_vmapped_ucb_matches_per_candidate_reference():
    """Vectorized pool scoring == per-candidate loop oracle within 1e-5."""
    rng = np.random.RandomState(3)
    gp = GaussianProcessBandit(dim=4, fit_steps=40)
    x = rng.rand(15, 4)
    y = np.sin(2 * x.sum(axis=1))
    raw = gp.fit(x, y)
    xq = rng.rand(128, 4)
    vectorized = np.asarray(gp.ucb(raw, x, y, xq))
    reference = gp.ucb_reference(raw, x, y, xq)
    np.testing.assert_allclose(vectorized, reference, atol=1e-5, rtol=1e-5)


def test_blocked_gram_matches_unblocked():
    """Candidate pools >= 4096 rows take the column-strip path, bit-equal."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    rng = np.random.RandomState(0)
    x1 = jnp.asarray(rng.rand(23, 6), jnp.float32)
    x2 = jnp.asarray(rng.rand(kops.GRAM_BLOCK_ROWS + 500, 6), jnp.float32)
    unblocked = kops.matern52_gram(x1, x2, 1.7, impl="xla", block_rows=0)
    blocked = kops.matern52_gram(x1, x2, 1.7, impl="xla")  # auto-blocks
    assert blocked.shape == (23, kops.GRAM_BLOCK_ROWS + 500)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(unblocked),
                               atol=1e-6, rtol=1e-6)


def test_fantasized_ucb_vmap_regression():
    """Fantasization: deterministic for a fixed rng, penalizes the pending
    region's uncertainty bonus, and agrees with a per-fantasy loop."""
    import jax.numpy as jnp
    from repro.pythia.gp_bandit import _posterior, _ucb

    rng = np.random.RandomState(7)
    gp = GaussianProcessBandit(dim=2, fit_steps=40)
    x = rng.rand(10, 2)
    y = -((x[:, 0] - 0.5) ** 2) - ((x[:, 1] - 0.5) ** 2)
    raw = gp.fit(x, y)
    pending = np.array([[0.9, 0.9], [0.1, 0.85]])
    xq = rng.rand(64, 2)

    s1 = np.asarray(gp.ucb_fantasized(raw, x, y, pending, xq,
                                      np.random.RandomState(11)))
    s2 = np.asarray(gp.ucb_fantasized(raw, x, y, pending, xq,
                                      np.random.RandomState(11)))
    np.testing.assert_array_equal(s1, s2)  # fixed rng -> fixed fantasies

    # oracle: loop over the same fantasy draws, score one fantasy at a time
    F = 4
    mean_p, std_p = _posterior(raw, jnp.asarray(x, jnp.float32),
                               jnp.asarray(y, jnp.float32),
                               jnp.asarray(pending, jnp.float32))
    eps = np.random.RandomState(11).randn(F, len(pending)).astype(np.float32)
    per_fantasy = []
    for f in range(F):
        y_aug = np.concatenate(
            [y, np.asarray(mean_p) + np.asarray(std_p) * eps[f]])
        x_aug = np.vstack([x, pending])
        per_fantasy.append(np.asarray(
            _ucb(raw, jnp.asarray(x_aug, jnp.float32),
                 jnp.asarray(y_aug, jnp.float32),
                 jnp.asarray(xq, jnp.float32), jnp.float32(gp.ucb_beta))))
    oracle = np.mean(per_fantasy, axis=0)
    np.testing.assert_allclose(s1, oracle, atol=1e-5, rtol=1e-5)

    # regression: conditioning on pending points kills their exploration
    # bonus — candidates at the pending locations score lower than under the
    # pending-blind acquisition
    at_pending = np.asarray(gp.ucb_fantasized(
        raw, x, y, pending, pending, np.random.RandomState(11)))
    blind = np.asarray(gp.ucb(raw, x, y, pending))
    assert (at_pending < blind + 1e-6).all(), (at_pending, blind)


def test_gp_bandit_fantasizes_pending_trials():
    """With a pending trial at the argmax, the next suggestion moves away."""
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/pend", study_config=cfg)
    ds.create_study(study)
    for i in range(8):
        x = (i + 1) / 9.0
        t = Trial(parameters={"x": x})
        t = ds.create_trial(study.name, t)
        t.complete(Measurement(metrics={"y": -(x - 0.55) ** 2}))
        ds.update_trial(study.name, t)

    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, n_candidates=600, min_completed=4)
    request = SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=1)
    (first,) = policy.suggest(request).suggestions
    x_first = first.parameters.get_value("x")

    # park an ACTIVE (pending) trial exactly at the chosen point
    pend = Trial(parameters={"x": x_first})
    pend.state = TrialState.ACTIVE
    ds.create_trial(study.name, pend)

    (second,) = policy.suggest(request).suggestions
    x_second = second.parameters.get_value("x")
    assert abs(x_second - x_first) > 1e-3, (x_first, x_second)


def test_back_to_back_ops_at_fixed_trial_count_differ():
    """Regression: the acquisition RNG was seeded by the completed-trial
    count ALONE, so two suggest operations with no completion in between
    replayed the identical Halton scrambling, local perturbations and
    fantasy draws — the server kept re-suggesting the same point until a
    trial completed. The per-op nonce must break the replay while staying a
    deterministic function of (observed snapshot, op index): a fresh policy
    over the same snapshot still reproduces the first op exactly."""
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/nonce", study_config=cfg)
    ds.create_study(study)
    for i in range(9):
        x = (i + 0.5) / 9.0
        t = Trial(parameters={"x": x})
        t = ds.create_trial(study.name, t)
        t.complete(Measurement(metrics={"y": -(x - 0.42) ** 2}))
        ds.update_trial(study.name, t)

    supporter = DatastorePolicySupporter(ds, study.name)
    request = SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=1)

    policy = GPBanditPolicy(supporter, n_candidates=400, min_completed=4,
                            warm_start=False)
    (first,) = policy.suggest(request).suggestions
    (second,) = policy.suggest(request).suggestions  # no completions between
    x1 = first.parameters.get_value("x")
    x2 = second.parameters.get_value("x")
    assert abs(x1 - x2) > 1e-6, (x1, x2)

    # determinism is preserved: a fresh policy over the identical snapshot
    # (op counter 0, same pending set) reproduces the FIRST suggestion
    replay = GPBanditPolicy(supporter, n_candidates=400, min_completed=4,
                            warm_start=False)
    (replayed,) = replay.suggest(request).suggestions
    assert replayed.parameters.get_value("x") == x1


def test_dedup_filter_empty_pool_falls_back_to_unfiltered(monkeypatch):
    """Regression: a pending trial at EVERY candidate used to empty the
    dedup-filtered pool and crash np.argmax on a zero-length array; the
    policy must fall back to the unfiltered pool instead."""
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/dedup", study_config=cfg)
    ds.create_study(study)
    for i in range(8):
        x = (i + 1) / 9.0
        t = Trial(parameters={"x": x})
        t.complete(Measurement(metrics={"y": -(x - 0.55) ** 2}))
        ds.update_trial(study.name, ds.create_trial(study.name, t))

    fixed_pool = np.linspace(0.05, 0.95, 10)[:, None]
    for v in fixed_pool[:, 0]:  # park a pending trial on every candidate
        pend = Trial(parameters={"x": float(v)})
        pend.state = TrialState.ACTIVE
        ds.create_trial(study.name, pend)

    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, n_candidates=8, min_completed=4)
    monkeypatch.setattr(policy, "_draw_pool",
                        lambda rng, dim, incumbent: fixed_pool.copy())
    request = SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=2)
    decision = policy.suggest(request)  # must not raise on the empty filter
    assert len(decision.suggestions) == 2
    for s in decision.suggestions:
        assert 0.0 <= s.parameters.get_value("x") <= 1.0


def test_scrambled_halton_uniformity_and_determinism():
    """The global candidate pool really is quasi-random now: each 1-D
    projection's discrepancy beats iid-uniform sampling by a wide margin,
    and the sequence is deterministic per seed."""
    from repro.pythia.halton import scrambled_halton

    n, dim = 512, 6
    pts = scrambled_halton(n, dim, np.random.RandomState(0))
    assert pts.shape == (n, dim)
    assert (pts >= 0.0).all() and (pts < 1.0).all()
    grid = np.arange(1, n + 1) / n
    for d in range(dim):
        ks = np.abs(np.sort(pts[:, d]) - grid).max()
        assert ks < 0.02, f"dim {d}: KS={ks}"  # iid-uniform is ~0.03-0.06
    # deterministic per seed, fresh scrambling per generator state
    again = scrambled_halton(n, dim, np.random.RandomState(0))
    np.testing.assert_array_equal(pts, again)
    other = scrambled_halton(n, dim, np.random.RandomState(1))
    assert not np.array_equal(pts, other)
    # consecutive draws on one generator differ (per-op rescrambling)
    rng = np.random.RandomState(2)
    a, b = scrambled_halton(64, 2, rng), scrambled_halton(64, 2, rng)
    assert not np.array_equal(a, b)


def test_policy_pool_uses_halton_global_half():
    """The suggest pool's global half is the seeded scrambled-Halton set
    (plus the local-perturbation quarter around the incumbent)."""
    from repro.pythia.halton import scrambled_halton

    supporter = DatastorePolicySupporter(InMemoryDatastore(), "unused")
    policy = GPBanditPolicy(supporter, n_candidates=100)
    rng = np.random.RandomState(5)
    pool = policy._draw_pool(rng, 3, np.array([0.5, 0.5, 0.5]))
    assert pool.shape == (125, 3)
    expect = scrambled_halton(100, 3, np.random.RandomState(5))
    np.testing.assert_array_equal(pool[:100], expect)
    assert (pool >= 0.0).all() and (pool <= 1.0).all()


def test_gp_bandit_converges_1d():
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name="owners/o/studies/gp", study_config=cfg)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, n_candidates=400, min_completed=4)

    f = lambda x: -(x - 0.731) ** 2
    best = -1e9
    for i in range(14):
        request = SuggestRequest(
            study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=1)
        (s,) = policy.suggest(request).suggestions
        x = s.parameters.get_value("x")
        t = Trial(parameters=s.parameters)
        t = ds.create_trial(study.name, t)
        t.complete(Measurement(metrics={"y": f(x)}))
        ds.update_trial(study.name, t)
        best = max(best, f(x))
    assert best > -0.004, f"GP-UCB best={best} (should be near 0)"
