"""Scale-out serving tier end-to-end: worker pool, WaitOperation long-poll,
worker-granular fault injection (kill one of N workers mid-batch).

Extends the PR-2 fault harness (stop_pythia / restart_pythia: whole-process
kills) down to single workers: a worker killed mid-lease must have its
in-flight ops requeued onto survivors and re-run idempotently — every op
completes, no duplicate trials, and the op records how often it was re-handed
(``requeues``).
"""

import threading
import time

import pytest

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.service import (
    DefaultVizierServer,
    OperationFailedError,
    VizierBatchClient,
    VizierClient,
)
from repro.service.rpc import RpcClient, StatusCode, VizierRpcError
from repro.service.vizier_service import PythiaConnector


def _config(algorithm: str = "RANDOM_SEARCH") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


@pytest.fixture
def pool_server():
    s = DefaultVizierServer(n_pythia_workers=2, n_shards=4)
    yield s
    s.stop()


# ---------------------------------------------------------------------------
# Worker pool: the happy path must be indistinguishable from direct dispatch
# ---------------------------------------------------------------------------


def test_pool_serves_single_client(pool_server):
    c = VizierClient.load_or_create_study(
        "pool-basic", _config(), client_id="w0", target=pool_server.address)
    (t,) = c.get_suggestions(count=1)
    assert t.id >= 1
    c.complete_trial({"obj": 0.5}, trial_id=t.id)
    (t2,) = c.get_suggestions(count=1)
    assert t2.id != t.id
    c.close()


def test_pool_serves_batched_clients_many_studies(pool_server):
    names = []
    for i in range(6):
        c = VizierClient.load_or_create_study(
            f"pool-{i}", _config(), client_id="seed",
            target=pool_server.address)
        names.append(c.study_name)
        c.close()
    batch = VizierBatchClient(pool_server.address)
    results = batch.get_suggestions(
        [{"study_name": n, "client_id": f"w{i}", "count": 2}
         for i, n in enumerate(names)])
    assert [len(r) for r in results] == [2] * 6
    # every study got distinct trials bound to its requester
    for i, trials in enumerate(results):
        assert {t.client_id for t in trials} == {f"w{i}"}
    batch.close()


def test_pool_recovers_persisted_ops(pool_server):
    """Crash recovery routes suggest ops through the sharded queue."""
    import repro.service.operations as ops_lib

    c = VizierClient.load_or_create_study(
        "pool-recover", _config(), client_id="w", target=pool_server.address)
    op = ops_lib.new_suggest_operation(c.study_name, "w2", 1)
    pool_server.datastore.put_operation(op)
    assert pool_server.servicer.recover_pending_operations() >= 1
    deadline = time.time() + 10
    while time.time() < deadline:
        if pool_server.datastore.get_operation(op["name"])["done"]:
            break
        time.sleep(0.02)
    done = pool_server.datastore.get_operation(op["name"])
    assert done["done"] and done["error"] is None
    assert len(done["result"]["trials"]) == 1
    c.close()


# ---------------------------------------------------------------------------
# WaitOperation long-poll
# ---------------------------------------------------------------------------


def test_wait_operation_semantics(pool_server):
    import repro.service.operations as ops_lib

    rpc = RpcClient(pool_server.address)
    c = VizierClient.load_or_create_study(
        "wait-sem", _config(), client_id="w", target=pool_server.address)

    # unknown op -> NOT_FOUND
    with pytest.raises(VizierRpcError) as ei:
        rpc.call("WaitOperation",
                 {"name": f"{c.study_name}/operations/nope", "timeout_ms": 100})
    assert ei.value.code == StatusCode.NOT_FOUND

    # pending op + timeout_ms=0 -> immediate return, still pending
    op = ops_lib.new_suggest_operation(c.study_name, "parked", 1)
    pool_server.datastore.put_operation(op)
    got = rpc.call("WaitOperation", {"name": op["name"], "timeout_ms": 0})
    assert not got["operation"]["done"]

    # a parked wait wakes the moment the op completes, not at its timeout
    waked = {}

    def parked():
        t0 = time.monotonic()
        waked["op"] = rpc.call(
            "WaitOperation", {"name": op["name"], "timeout_ms": 5000},
            timeout=10.0)["operation"]
        waked["latency"] = time.monotonic() - t0

    t = threading.Thread(target=parked)
    t.start()
    time.sleep(0.15)  # let the waiter park
    pool_server.servicer._put_op(
        ops_lib.complete_operation(dict(op), {"trials": []}))
    t.join(timeout=5.0)
    assert waked["op"]["done"]
    # woke on the event: far below the 5s wait deadline
    assert waked["latency"] < 1.0, waked["latency"]

    # done op -> immediate return regardless of timeout
    t0 = time.monotonic()
    got = rpc.call("WaitOperation", {"name": op["name"], "timeout_ms": 5000})
    assert got["operation"]["done"]
    assert time.monotonic() - t0 < 1.0

    # waiter registry is not leaked (refcounted eviction)
    assert pool_server.servicer._op_waiters == {}
    rpc.close()
    c.close()


def test_client_falls_back_to_polling_without_wait_operation(pool_server):
    """Old-server compatibility: a client probing WaitOperation against a
    server that lacks it degrades (permanently) to GetOperation polling."""
    del pool_server.servicer._methods["WaitOperation"]
    c = VizierClient.load_or_create_study(
        "fallback", _config(), client_id="w", target=pool_server.address)
    (t,) = c.get_suggestions(count=1)
    assert t.id >= 1
    assert c._long_poll is False  # sticky fallback after UNIMPLEMENTED
    # batch client takes the same fallback
    batch = VizierBatchClient(pool_server.address)
    (trials,) = batch.get_suggestions(
        [{"study_name": c.study_name, "client_id": "w2"}])
    assert len(trials) == 1
    assert batch._long_poll is False
    batch.close()
    c.close()


def test_error_codes_surface_through_operation_failures(pool_server):
    """Satellite: OperationFailedError carries the op's StatusCode + name so
    schedulers can tell retryable from permanent failures. An unknown
    algorithm is a PERMANENT client error: INVALID_ARGUMENT, not the
    retryable INTERNAL it used to surface as."""
    c = VizierClient.load_or_create_study(
        "codes", _config(), client_id="w", target=pool_server.address)
    study = pool_server.datastore.get_study(c.study_name)
    study.study_config.algorithm = "NO_SUCH_ALGORITHM"
    pool_server.datastore.update_study(study)

    with pytest.raises(OperationFailedError) as ei:
        c.get_suggestions(count=1, timeout=30.0)
    assert ei.value.code == StatusCode.INVALID_ARGUMENT
    assert ei.value.operation_name and "/operations/" in ei.value.operation_name

    batch = VizierBatchClient(pool_server.address)
    with pytest.raises(OperationFailedError) as ei:
        batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w9"}], timeout=30.0)
    assert ei.value.code == StatusCode.INVALID_ARGUMENT
    assert ei.value.operation_name
    batch.close()
    c.close()


# ---------------------------------------------------------------------------
# Client deadline semantics (an op that never completes)
# ---------------------------------------------------------------------------


class _StuckConnector(PythiaConnector):
    """suggest_batch parks until released — the op never completes."""

    def __init__(self):
        self.release = threading.Event()

    def suggest_batch(self, items):
        self.release.wait(30.0)
        raise RuntimeError("released: fail the op so the server drains")

    def suggest(self, study, count, client_id):
        return self.suggest_batch(None)


@pytest.mark.parametrize("long_poll", [True, False])
def test_timeout_raises_at_deadline_and_op_survives(long_poll):
    """An op that never completes must raise DEADLINE_EXCEEDED at ~the
    client deadline (not a backoff-quantum late), and the op must still be
    pending server-side — a later GetOperation finds it undone."""
    server = DefaultVizierServer(n_pythia_workers=1, n_shards=2)
    stuck = _StuckConnector()
    server.servicer._pythia = stuck
    try:
        c = VizierClient.load_or_create_study(
            "stuck", _config(), client_id="w", target=server.address,
            long_poll=long_poll)
        start = time.monotonic()
        with pytest.raises(OperationFailedError) as ei:
            c.get_suggestions(count=1, timeout=0.5)
        elapsed = time.monotonic() - start
        assert ei.value.code == StatusCode.DEADLINE_EXCEEDED
        assert ei.value.operation_name
        assert 0.45 <= elapsed < 1.0, f"raised {elapsed:.3f}s into a 0.5s deadline"
        # the timeout abandoned the WAIT, not the op: still pending server-side
        rpc = RpcClient(server.address)
        op = rpc.call("GetOperation", {"name": ei.value.operation_name})["operation"]
        assert not op["done"]
        rpc.close()
        c.close()
    finally:
        stuck.release.set()
        time.sleep(0.05)  # let the worker fail the op and drain its lease
        server.stop()


# ---------------------------------------------------------------------------
# Worker-granular fault injection: kill 1 of N mid-batch
# ---------------------------------------------------------------------------


class _BlockOnceConnector(PythiaConnector):
    """Delegates to the real connector, but the FIRST dispatch touching the
    victim study parks until released — holding its worker's lease open so
    the test can kill that worker mid-batch."""

    def __init__(self, inner, victim_study: str):
        self._inner = inner
        self._victim = victim_study
        self._lock = threading.Lock()
        self.entered = threading.Event()
        self.release = threading.Event()
        self.victim_dispatches = 0

    def suggest(self, study, count, client_id):
        return self._inner.suggest(study, count, client_id)

    def early_stop(self, study, trial_ids):
        return self._inner.early_stop(study, trial_ids)

    def suggest_batch(self, items):
        if any(study.name == self._victim for study, _, _ in items):
            with self._lock:
                self.victim_dispatches += 1
                first = self.victim_dispatches == 1
            if first:
                self.entered.set()
                self.release.wait(30.0)
        return self._inner.suggest_batch(items)


@pytest.mark.dist
def test_kill_one_of_n_workers_mid_batch_no_duplicate_trials():
    """The tentpole's acceptance test: kill 1 of N workers mid-batch.

    A worker is parked inside its coalesced dispatch when it is killed; its
    in-flight ops are requeued and re-run by a surviving worker. Every op
    completes, the dead worker's zombie thread (released afterwards) is
    barred from finalizing by the lease-validity guard, and the trial count
    proves no suggestion was materialized twice.
    """
    server = DefaultVizierServer(n_pythia_workers=2, n_shards=4)
    try:
        c = VizierClient.load_or_create_study(
            "victim", _config(), client_id="w", target=server.address)
        victim = c.study_name
        blocker = _BlockOnceConnector(server.servicer._pythia, victim)
        server.servicer._pythia = blocker

        # issue the suggestion from a thread: the client parks in
        # WaitOperation while the server-side choreography runs
        got = {}

        def request():
            got["trials"] = c.get_suggestions(count=3, timeout=30.0)

        requester = threading.Thread(target=request)
        requester.start()

        assert blocker.entered.wait(10.0), "dispatch never reached Pythia"
        pool = server.servicer.worker_pool
        wid = pool.worker_holding(victim)
        assert wid is not None, "no worker holds the victim's shard"

        # kill the worker that is mid-dispatch; its ops must requeue
        requeued = server.stop_pythia_worker(wid)
        assert requeued == 1

        # the survivor re-runs the requeued op (2nd dispatch passes through)
        requester.join(timeout=20.0)
        assert not requester.is_alive(), "suggestion never completed"
        assert len(got["trials"]) == 3

        # now release the zombie: its late finalize must be a guarded no-op
        blocker.release.set()
        time.sleep(0.3)

        # exactly one op, completed by the successor, stamped requeues=1
        ops = server.datastore.list_operations(victim)
        assert len(ops) == 1
        assert ops[0]["done"] and ops[0]["error"] is None
        assert ops[0]["requeues"] == 1
        assert len(ops[0]["result"]["trials"]) == 3

        # no duplicate trials: the zombie's suggestions were never created
        trials = server.datastore.list_trials(victim)
        assert len(trials) == 3, [t.id for t in trials]
        assert {t.client_id for t in trials} == {"w"}

        # the pool healed: restart the dead slot and serve another round
        server.restart_pythia_worker(wid)
        for t in got["trials"]:
            c.complete_trial({"obj": 0.1}, trial_id=t.id)
        more = c.get_suggestions(count=2, timeout=30.0)
        assert len(more) == 2
        assert blocker.victim_dispatches >= 2  # zombie + successor (+ extra)
        c.close()
    finally:
        blocker.release.set()
        server.stop()


@pytest.mark.dist
def test_kill_worker_between_batches_is_harmless(pool_server):
    """Killing an idle worker (no lease held) requeues nothing and the
    remaining worker keeps serving."""
    requeued = pool_server.stop_pythia_worker(1)
    assert requeued == 0
    c = VizierClient.load_or_create_study(
        "idle-kill", _config(), client_id="w", target=pool_server.address)
    (t,) = c.get_suggestions(count=1)
    assert t.id >= 1
    pool_server.restart_pythia_worker(1)
    assert pool_server.servicer.worker_pool.alive_workers() == [0, 1]
    c.close()
