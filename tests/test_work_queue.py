"""ShardedWorkQueue + PythiaWorkerPool unit tests (scale-out serving tier).

The queue invariants everything else leans on: stable shard keying, exclusive
shard leases, generation-checked ack, requeue-at-front on worker death, lazy
lease expiry, and the pool's idempotent re-run filter.
"""

import threading
import time
import zlib

import pytest

from repro.service import operations as ops_lib
from repro.service.work_queue import PythiaWorkerPool, ShardedWorkQueue


def _op(study="owners/o/studies/s", client="c", count=1):
    return ops_lib.new_suggest_operation(study, client, count)


# ---------------------------------------------------------------------------
# Shard keying
# ---------------------------------------------------------------------------


def test_shard_of_is_stable_crc32():
    """The shard key must survive a server restart (Python's salted hash()
    would not): it is CRC32 of the study name, mod n_shards."""
    name = "owners/o/studies/stable"
    for n in (1, 2, 8, 13):
        q = ShardedWorkQueue(n)
        expected = zlib.crc32(name.encode("utf-8")) % n
        assert q.shard_of(name) == expected
        assert ops_lib.shard_of(name, n) == expected


def test_same_study_same_shard():
    q = ShardedWorkQueue(4)
    sids = {q.enqueue(_op(study="owners/o/studies/x")) for _ in range(10)}
    assert len(sids) == 1


# ---------------------------------------------------------------------------
# Lease / ack
# ---------------------------------------------------------------------------


def test_lease_takes_whole_backlog_of_one_shard():
    q = ShardedWorkQueue(4)
    ops = [_op(client=f"c{i}") for i in range(3)]  # same study -> same shard
    for op in ops:
        q.enqueue(op)
    lease = q.lease(worker_id=0, timeout=1.0)
    assert lease is not None
    assert [o["name"] for o in lease.ops] == [o["name"] for o in ops]
    assert q.pending_count() == 3  # leased ops still count as pending
    assert q.lease_valid(lease)
    assert q.ack(lease)
    assert q.pending_count() == 0
    assert not q.lease_valid(lease)  # retired


def test_leased_shard_is_exclusive():
    """While one worker holds a shard, a second worker cannot lease it —
    one study's policy state is never computed on two workers at once."""
    q = ShardedWorkQueue(2)
    q.enqueue(_op())
    lease = q.lease(worker_id=0, timeout=1.0)
    q.enqueue(_op(client="late"))  # lands on the leased shard's queue
    assert q.lease(worker_id=1, timeout=0.1) is None
    q.ack(lease)
    # the shard is free again: the late op is now leasable
    second = q.lease(worker_id=1, timeout=1.0)
    assert second is not None and second.ops[0]["client_id"] == "late"
    q.ack(second)


def test_two_workers_lease_different_shards_concurrently():
    q = ShardedWorkQueue(8)
    a, b = "owners/o/studies/aaa", "owners/o/studies/abc"
    assert q.shard_of(a) != q.shard_of(b)  # distinct shards for this test
    q.enqueue(_op(study=a))
    q.enqueue(_op(study=b))
    l0 = q.lease(worker_id=0, timeout=1.0)
    l1 = q.lease(worker_id=1, timeout=1.0)
    assert l0 is not None and l1 is not None
    assert {l0.ops[0]["study_name"], l1.ops[0]["study_name"]} == {a, b}
    assert q.ack(l0) and q.ack(l1)


def test_lease_blocks_until_enqueue():
    q = ShardedWorkQueue(2)
    got = []

    def worker():
        got.append(q.lease(worker_id=0, timeout=5.0))

    t = threading.Thread(target=worker)
    t.start()
    time.sleep(0.05)
    q.enqueue(_op())
    t.join(timeout=2.0)
    assert got and got[0] is not None and len(got[0].ops) == 1


# ---------------------------------------------------------------------------
# Requeue / generations / expiry
# ---------------------------------------------------------------------------


def test_reclaim_worker_requeues_in_order_and_stamps_requeues():
    q = ShardedWorkQueue(2)
    ops = [_op(client=f"c{i}") for i in range(3)]
    for op in ops:
        q.enqueue(op)
    lease = q.lease(worker_id=0, timeout=1.0)
    assert q.reclaim_worker(0) == 3
    assert not q.lease_valid(lease)
    assert not q.ack(lease)  # stale ack is a no-op
    takeover = q.lease(worker_id=1, timeout=1.0)
    assert [o["client_id"] for o in takeover.ops] == ["c0", "c1", "c2"]
    assert all(o["requeues"] == 1 for o in takeover.ops)
    assert q.ack(takeover)


def test_requeue_puts_ops_in_front_of_later_arrivals():
    q = ShardedWorkQueue(1)  # single shard: everything interleaves
    first = _op(client="first")
    q.enqueue(first)
    lease = q.lease(worker_id=0, timeout=1.0)
    q.enqueue(_op(client="second"))  # arrives while first is in flight
    q.reclaim_worker(0)
    takeover = q.lease(worker_id=1, timeout=1.0)
    assert [o["client_id"] for o in takeover.ops] == ["first", "second"]
    q.ack(takeover)


def test_expired_lease_is_reclaimed_lazily():
    q = ShardedWorkQueue(2, lease_timeout=0.05)
    q.enqueue(_op())
    dead = q.lease(worker_id=0, timeout=1.0)
    time.sleep(0.1)  # lease outlives its deadline; no reaper thread runs
    takeover = q.lease(worker_id=1, timeout=1.0)
    assert takeover is not None
    assert takeover.ops[0]["requeues"] == 1
    assert not q.ack(dead)  # the zombie's ack lost the generation race
    assert q.ack(takeover)


def test_close_unblocks_lease():
    q = ShardedWorkQueue(2)
    got = []

    def worker():
        got.append(q.lease(worker_id=0))  # no timeout: blocks until close

    t = threading.Thread(target=worker)
    t.start()
    time.sleep(0.05)
    q.close()
    t.join(timeout=2.0)
    assert got == [None]


def test_n_shards_validation():
    with pytest.raises(ValueError):
        ShardedWorkQueue(0)
    with pytest.raises(ValueError):
        PythiaWorkerPool(ShardedWorkQueue(1), lambda ops, g: None,
                         lambda op: False, n_workers=0)


# ---------------------------------------------------------------------------
# Worker pool
# ---------------------------------------------------------------------------


class _Runner:
    """Records every batch run; optionally blocks inside the run."""

    def __init__(self):
        self.batches = []
        self.lock = threading.Lock()
        self.block = None  # threading.Event to hold a run open
        self.entered = threading.Event()

    def run(self, ops, guard):
        self.entered.set()
        if self.block is not None:
            self.block.wait(5.0)
        with self.lock:
            self.batches.append([(op["name"], guard(op)) for op in ops])


def test_pool_runs_enqueued_ops():
    q = ShardedWorkQueue(4)
    runner = _Runner()
    pool = PythiaWorkerPool(q, runner.run, lambda op: False, n_workers=2).start()
    try:
        ops = [_op(study=f"owners/o/studies/s{i}") for i in range(6)]
        for op in ops:
            q.enqueue(op)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and q.pending_count():
            time.sleep(0.01)
        assert q.pending_count() == 0
        ran = {name for batch in runner.batches for name, _ in batch}
        assert ran == {op["name"] for op in ops}
        # guards were valid while the lease was held
        assert all(ok for batch in runner.batches for _, ok in batch)
        assert pool.alive_workers() == [0, 1]
    finally:
        pool.shutdown()


def test_pool_skips_already_done_ops():
    """Idempotent re-run: ops a dead predecessor finished are filtered out
    before dispatch, so a requeue never re-runs completed work."""
    q = ShardedWorkQueue(2)
    runner = _Runner()
    done = {_op()["name"]}  # placeholder; replaced below

    op_a, op_b = _op(client="a"), _op(client="b")
    done = {op_a["name"]}
    pool = PythiaWorkerPool(q, runner.run, lambda op: op["name"] in done,
                            n_workers=1).start()
    try:
        q.enqueue(op_a)
        q.enqueue(op_b)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and q.pending_count():
            time.sleep(0.01)
        ran = {name for batch in runner.batches for name, _ in batch}
        assert ran == {op_b["name"]}
    finally:
        pool.shutdown()


def test_stop_worker_mid_batch_requeues_and_guard_goes_stale():
    """Kill the worker while it is inside run_batch: its ops requeue (the
    kill returns the count), its guard turns False (so a zombie finalize is
    rejected), and a restarted worker re-runs the batch with a valid guard."""
    q = ShardedWorkQueue(2)
    runner = _Runner()
    runner.block = threading.Event()
    pool = PythiaWorkerPool(q, runner.run, lambda op: False, n_workers=1).start()
    try:
        op = _op()
        q.enqueue(op)
        assert runner.entered.wait(5.0)  # worker 0 is stuck inside the run
        assert pool.worker_holding(op["study_name"]) == 0
        requeued = pool.stop_worker(0)
        assert requeued == 1
        assert pool.alive_workers() in ([], [0])  # may still be parked in run
        # zombie finishes its run: guard evaluates False (lease reclaimed)
        runner.block.set()
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and not runner.batches:
            time.sleep(0.01)
        assert runner.batches[0] == [(op["name"], False)]
        # successor re-runs the requeued op with a live lease
        runner.block = None
        pool.restart_worker(0)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and len(runner.batches) < 2:
            time.sleep(0.01)
        assert runner.batches[1] == [(op["name"], True)]
        assert q.pending_count() == 0
    finally:
        pool.shutdown()


def test_restart_worker_refuses_live_worker():
    q = ShardedWorkQueue(2)
    pool = PythiaWorkerPool(q, lambda ops, g: None, lambda op: False,
                            n_workers=1).start()
    try:
        with pytest.raises(RuntimeError):
            pool.restart_worker(0)
        with pytest.raises(KeyError):
            pool.stop_worker(99)
    finally:
        pool.shutdown()
