"""Pareto utilities: frontier invariants (hypothesis) + hypervolume."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.pareto import (
    crowding_distance,
    default_reference_point,
    hypervolume,
    non_dominated_sort,
    pareto_frontier_indices,
)

points = st.lists(
    st.tuples(st.floats(min_value=-10, max_value=10, allow_nan=False),
              st.floats(min_value=-10, max_value=10, allow_nan=False)),
    min_size=1, max_size=40)


def dominates(a, b):
    return all(x >= y for x, y in zip(a, b)) and any(x > y for x, y in zip(a, b))


@given(points)
@settings(max_examples=150, deadline=None)
def test_frontier_is_nondominated_and_complete(pts):
    y = np.asarray(pts)
    idx = pareto_frontier_indices(y)
    assert idx, "frontier never empty for nonempty input"
    front = {i for i in idx}
    for i in idx:
        for j in range(len(pts)):
            assert not dominates(pts[j], pts[i]), (i, j)
    # completeness: every excluded point is dominated by someone
    for i in range(len(pts)):
        if i not in front:
            assert any(dominates(pts[j], pts[i]) for j in range(len(pts)))


@given(points)
@settings(max_examples=80, deadline=None)
def test_non_dominated_sort_partitions(pts):
    y = np.asarray(pts)
    fronts = non_dominated_sort(y)
    flat = np.concatenate(fronts)
    assert sorted(flat.tolist()) == list(range(len(pts)))
    # rank-0 front matches pareto_frontier_indices
    assert set(fronts[0].tolist()) == set(pareto_frontier_indices(y))


def test_hypervolume_2d_exact():
    y = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([0.0, 0.0])
    # union of rectangles: 3 + 2 + 2 = ... compute: sorted desc by x: (3,1):3*1=3;
    # (2,2): 2*(2-1)=2; (1,3): 1*(3-2)=1 -> 6
    assert abs(hypervolume(y, ref) - 6.0) < 1e-6


def test_hypervolume_monotone_in_points():
    ref = np.array([0.0, 0.0, 0.0])
    y1 = np.array([[1.0, 1.0, 1.0]])
    y2 = np.array([[1.0, 1.0, 1.0], [2.0, 0.5, 0.5]])
    assert hypervolume(y2, ref, seed=1) >= hypervolume(y1, ref, seed=1) - 0.05


def test_crowding_distance_boundaries_infinite():
    y = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(y)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])


def test_nan_rows_never_on_frontier():
    """Regression: a NaN objective vector compares False against everything,
    so it used to be un-dominatable and survive EVERY domination test —
    ListOptimalTrials served NaN trials as 'optimal'. Non-finite rows must
    be incomparable: excluded from the frontier and unable to dominate."""
    y = np.array([
        [1.0, 1.0],
        [np.nan, 5.0],
        [5.0, np.nan],
        [np.inf, np.inf],
        [2.0, 0.5],
        [-np.inf, 3.0],
    ])
    idx = pareto_frontier_indices(y)
    assert idx == [0, 4]
    # an all-non-finite input yields an EMPTY frontier, not a crash
    assert pareto_frontier_indices(np.full((3, 2), np.nan)) == []
    # and non-finite rows cannot knock finite rows off the frontier either
    y2 = np.array([[1.0, 1.0], [np.inf, 2.0]])
    assert pareto_frontier_indices(y2) == [0]


def test_crowding_distance_duplicates_and_constant_metric():
    """Edge cases the NSGA-II truncation leans on: exact duplicates share
    ranks without NaN/inf poisoning, and a constant metric (zero span)
    contributes nothing instead of dividing by zero."""
    dup = np.array([[1.0, 2.0], [1.0, 2.0], [1.0, 2.0], [3.0, 0.0]])
    d = crowding_distance(dup)
    assert np.all(np.isfinite(d) | np.isinf(d))  # no NaN anywhere
    assert not np.any(np.isnan(d))
    const = np.array([[0.0, 5.0], [1.0, 5.0], [2.0, 5.0], [3.0, 5.0]])
    d2 = crowding_distance(const)
    assert not np.any(np.isnan(d2))
    # boundaries on the varying metric are still infinite
    assert np.isinf(d2[0]) and np.isinf(d2[3])
    # all-identical front: every point is a boundary (all infinite)
    same = np.array([[1.0, 1.0]] * 5)
    assert np.all(np.isinf(crowding_distance(same)) |
                  (crowding_distance(same) == 0.0))


@given(points, st.integers(min_value=1, max_value=20))
@settings(max_examples=60, deadline=None)
def test_nsga2_truncation_keeps_best_fronts(pts, keep):
    """NSGA-II environmental selection property: truncating to ``keep``
    survivors by (front rank, crowding distance) never drops a point whose
    whole front fits — lower-ranked fronts are consumed in order."""
    y = np.asarray(pts)
    keep = min(keep, len(pts))
    fronts = non_dominated_sort(y)
    survivors = []
    for front in fronts:
        if len(survivors) + len(front) <= keep:
            survivors.extend(front.tolist())
        else:
            room = keep - len(survivors)
            if room > 0:
                d = crowding_distance(y[front])
                order = np.argsort(-d)
                survivors.extend(front[order[:room]].tolist())
            break
    assert len(survivors) == keep
    ranks = {i: r for r, front in enumerate(fronts) for i in front}
    kept_ranks = sorted(ranks[i] for i in survivors)
    dropped = set(range(len(pts))) - set(survivors)
    # no dropped point outranks (strictly better front than) a kept point
    for i in dropped:
        assert ranks[i] >= kept_ranks[-1]


def test_hypervolume_mc_matches_exact_at_k3():
    """MC estimator (k>=3 path) cross-checked against a hand-computable
    3-D frontier: boxes [0,p]^3 for non-dominated p's, inclusion-exclusion
    union volume."""
    ref = np.array([0.0, 0.0, 0.0])
    # two boxes: [0,2]x[0,1]x[0,1] and [0,1]x[0,2]x[0,1]; union =
    # 2 + 2 - overlap([0,1]^2x[0,1]) = 4 - 1 = 3
    y = np.array([[2.0, 1.0, 1.0], [1.0, 2.0, 1.0]])
    exact = 3.0
    mc = hypervolume(y, ref, seed=7)
    assert abs(mc - exact) / exact < 0.05  # 16384-sample MC tolerance


def test_default_reference_point_dominated_by_all():
    y = np.array([[1.0, -3.0], [2.0, -5.0], [0.5, -1.0]])
    ref = default_reference_point(y)
    assert np.all(ref < y.min(axis=0))
    # every observed point dominates a positive-volume box
    assert hypervolume(y, ref) > 0.0
