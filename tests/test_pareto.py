"""Pareto utilities: frontier invariants (hypothesis) + hypervolume."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.pareto import (
    crowding_distance,
    hypervolume,
    non_dominated_sort,
    pareto_frontier_indices,
)

points = st.lists(
    st.tuples(st.floats(min_value=-10, max_value=10, allow_nan=False),
              st.floats(min_value=-10, max_value=10, allow_nan=False)),
    min_size=1, max_size=40)


def dominates(a, b):
    return all(x >= y for x, y in zip(a, b)) and any(x > y for x, y in zip(a, b))


@given(points)
@settings(max_examples=150, deadline=None)
def test_frontier_is_nondominated_and_complete(pts):
    y = np.asarray(pts)
    idx = pareto_frontier_indices(y)
    assert idx, "frontier never empty for nonempty input"
    front = {i for i in idx}
    for i in idx:
        for j in range(len(pts)):
            assert not dominates(pts[j], pts[i]), (i, j)
    # completeness: every excluded point is dominated by someone
    for i in range(len(pts)):
        if i not in front:
            assert any(dominates(pts[j], pts[i]) for j in range(len(pts)))


@given(points)
@settings(max_examples=80, deadline=None)
def test_non_dominated_sort_partitions(pts):
    y = np.asarray(pts)
    fronts = non_dominated_sort(y)
    flat = np.concatenate(fronts)
    assert sorted(flat.tolist()) == list(range(len(pts)))
    # rank-0 front matches pareto_frontier_indices
    assert set(fronts[0].tolist()) == set(pareto_frontier_indices(y))


def test_hypervolume_2d_exact():
    y = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([0.0, 0.0])
    # union of rectangles: 3 + 2 + 2 = ... compute: sorted desc by x: (3,1):3*1=3;
    # (2,2): 2*(2-1)=2; (1,3): 1*(3-2)=1 -> 6
    assert abs(hypervolume(y, ref) - 6.0) < 1e-6


def test_hypervolume_monotone_in_points():
    ref = np.array([0.0, 0.0, 0.0])
    y1 = np.array([[1.0, 1.0, 1.0]])
    y2 = np.array([[1.0, 1.0, 1.0], [2.0, 0.5, 0.5]])
    assert hypervolume(y2, ref, seed=1) >= hypervolume(y1, ref, seed=1) - 0.05


def test_crowding_distance_boundaries_infinite():
    y = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(y)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])
