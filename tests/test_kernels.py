"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import matern52_gram_matvec_pallas, matern52_gram_pallas
from repro.kernels.mamba2_ssd import ssd_core_pallas, ssd_scan_pallas
from repro.models.mamba2 import ssd_chunked

RNG = np.random.RandomState(42)


# -- gram -----------------------------------------------------------------------


@pytest.mark.parametrize("n,m,d", [(7, 5, 3), (64, 64, 8), (130, 257, 17),
                                   (256, 256, 128), (300, 40, 64)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_gram_sweep(n, m, d, dtype):
    x1 = RNG.randn(n, d).astype(np.float32)
    x2 = RNG.randn(m, d).astype(np.float32)
    K_ref = ref.matern52_gram(jnp.asarray(x1), jnp.asarray(x2), 2.3)
    K_pal = matern52_gram_pallas(jnp.asarray(x1), jnp.asarray(x2),
                                 jnp.asarray(2.3), interpret=True)
    np.testing.assert_allclose(np.asarray(K_ref), np.asarray(K_pal),
                               rtol=1e-4, atol=1e-4)


def test_gram_psd_diagonal():
    x = RNG.randn(20, 4).astype(np.float32)
    K = np.asarray(matern52_gram_pallas(jnp.asarray(x), jnp.asarray(x),
                                        jnp.asarray(1.0), interpret=True))
    np.testing.assert_allclose(np.diag(K), 1.0, atol=1e-5)
    evals = np.linalg.eigvalsh(K + 1e-5 * np.eye(20))
    assert evals.min() > 0


# -- fused gram-matvec (posterior mean without the (n, m) cross-Gram) ----------


@pytest.mark.parametrize("n,m,d", [(5, 7, 2), (64, 64, 8), (300, 257, 17),
                                   (513, 40, 3)])
def test_gram_matvec_sweep(n, m, d):
    x1 = RNG.randn(n, d).astype(np.float32)
    x2 = RNG.randn(m, d).astype(np.float32)
    alpha = RNG.randn(n).astype(np.float32)
    want = np.asarray(ref.matern52_gram_matvec(
        jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(alpha), 1.9))
    got = np.asarray(matern52_gram_matvec_pallas(
        jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(alpha),
        jnp.asarray(1.9), interpret=True))
    assert got.shape == (m,)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_gram_matvec_dispatch_blocked_xla_matches_ref():
    """ops dispatch: the strip-folded XLA path (O(m) peak temporary) equals
    the materializing oracle, and zero-alpha padding rows contribute 0."""
    from repro.kernels import ops as kops

    x1 = jnp.asarray(RNG.randn(700, 5), jnp.float32)
    x2 = jnp.asarray(RNG.randn(123, 5), jnp.float32)
    alpha = jnp.asarray(RNG.randn(700), jnp.float32)
    want = np.asarray(ref.matern52_gram_matvec(x1, x2, alpha, 0.8))
    got = np.asarray(kops.matern52_gram_matvec(x1, x2, alpha, 0.8,
                                               impl="xla", block_rows=256))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # padding neutrality: extra rows with alpha = 0 change nothing
    x1p = jnp.concatenate([x1, jnp.zeros((41, 5), jnp.float32)])
    ap = jnp.concatenate([alpha, jnp.zeros((41,), jnp.float32)])
    got_pad = np.asarray(kops.matern52_gram_matvec(x1p, x2, ap, 0.8,
                                                   impl="xla", block_rows=256))
    np.testing.assert_allclose(got_pad, want, rtol=2e-4, atol=2e-4)


def test_gram_blocked_ragged_tail_compiles_once():
    """Regression: the blocked gram path handed the final partial strip to
    the jitted kernel at its ragged width — one fresh compile per distinct
    tail shape. The strip loop must pad the tail to ``block_rows`` (slicing
    the result back), so every tail size reuses ONE compiled kernel."""
    from repro.kernels import ops as kops

    x1 = jnp.asarray(RNG.randn(6, 4), jnp.float32)
    before = matern52_gram_pallas._cache_size()
    outs = {}
    for m in (13, 21, 29):  # three distinct ragged tails for block_rows=8
        x2 = jnp.asarray(RNG.randn(m, 4), jnp.float32)
        outs[m] = np.asarray(kops.matern52_gram(
            x1, x2, 1.3, impl="pallas_interpret", block_rows=8))
        want = np.asarray(ref.matern52_gram(x1, x2, 1.3))
        np.testing.assert_allclose(outs[m], want, rtol=1e-4, atol=1e-4)
    assert matern52_gram_pallas._cache_size() - before == 1


# -- flash attention ---------------------------------------------------------------


@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,D,causal,off",
    [
        (2, 64, 64, 4, 2, 32, True, 0),
        (1, 48, 80, 4, 1, 16, True, 32),     # MQA + decode-style offset
        (2, 32, 32, 2, 2, 64, False, 0),     # bidirectional (whisper encoder)
        (1, 100, 100, 6, 2, 24, True, 0),    # non-power-of-two everything
        (1, 16, 128, 8, 8, 128, True, 112),  # chunked prefill tail
    ])
def test_flash_sweep(B, Sq, Sk, Hq, Hkv, D, causal, off):
    q = jnp.asarray(RNG.randn(B, Sq, Hq, D), jnp.float32)
    k = jnp.asarray(RNG.randn(B, Sk, Hkv, D), jnp.float32)
    v = jnp.asarray(RNG.randn(B, Sk, Hkv, D), jnp.float32)
    o_ref = ref.attention(q, k, v, causal=causal, q_offset=off)
    o_pal = flash_attention_pallas(q, k, v, causal=causal, q_offset=off,
                                   bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               rtol=2e-3, atol=2e-3)


def test_flash_bf16():
    q = jnp.asarray(RNG.randn(1, 32, 2, 32), jnp.bfloat16)
    k = jnp.asarray(RNG.randn(1, 32, 2, 32), jnp.bfloat16)
    v = jnp.asarray(RNG.randn(1, 32, 2, 32), jnp.bfloat16)
    o_ref = ref.attention(q, k, v, causal=True)
    o_pal = flash_attention_pallas(q, k, v, causal=True, bq=16, bk=16,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32), rtol=0.05, atol=0.05)


def test_chunked_attention_matches_ref_various_chunks():
    from repro.models.attention import chunked_attention

    q = jnp.asarray(RNG.randn(2, 70, 4, 16), jnp.float32)
    k = jnp.asarray(RNG.randn(2, 70, 2, 16), jnp.float32)
    v = jnp.asarray(RNG.randn(2, 70, 2, 16), jnp.float32)
    o_ref = ref.attention(q, k, v, causal=True)
    for qc, kc in [(16, 16), (32, 8), (70, 70), (128, 128)]:
        o = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o),
                                   rtol=2e-3, atol=2e-3, err_msg=f"qc={qc} kc={kc}")


# -- SSD -----------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,P,G,N,chunk",
                         [(2, 64, 4, 8, 2, 16, 16), (1, 32, 2, 16, 1, 8, 8),
                          (2, 128, 4, 32, 4, 32, 32), (1, 96, 6, 16, 3, 8, 16)])
def test_ssd_kernel_sweep(B, S, H, P, G, N, chunk):
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.rand(B, S, H) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.rand(H)) * 2 - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    h0 = jnp.asarray(RNG.randn(B, H, P, N) * 0.1, jnp.float32)
    y_ref, h_ref = ref.ssd_scan(x, dt, A, Bm, Cm, init_state=h0)
    y_pal, h_pal = ssd_scan_pallas(x, dt, A, Bm, Cm, init_state=h0,
                                   chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_pal),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(min_value=1, max_value=3), st.sampled_from([16, 32, 48]),
       st.sampled_from([2, 4]), st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_xla_vs_sequential_property(B, S, H, P):
    """Property: chunked XLA path == sequential scan for random shapes."""
    rng = np.random.RandomState(B * 1000 + S)
    G, N = H // 2 or 1, 8
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, S, H) * 0.3 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.rand(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, G, N) * 0.3, jnp.float32)
    y_ref, h_ref = ref.ssd_scan(x, dt, A, Bm, Cm)
    y_chk, h_chk = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_matches_xla_chunked_path():
    """kernels.ops dispatch: pallas-interpret == xla impl == ref."""
    from repro.kernels import ops as kops

    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.rand(B, S, H) * 0.4 + 0.01, jnp.float32)
    A = jnp.asarray(np.array([-0.5, -1.5]), jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, G, N) * 0.3, jnp.float32)
    y_x, _ = kops.ssd_scan(x, dt, A, Bm, Cm, impl="xla", chunk=16)
    y_p, _ = kops.ssd_scan(x, dt, A, Bm, Cm, impl="pallas_interpret", chunk=16)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=2e-3, atol=2e-3)
