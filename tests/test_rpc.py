"""RPC transport: framing, retries, deadlines, reconnect, error mapping."""

import threading
import time

import pytest

from repro.service.rpc import (
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)


class EchoServicer(Servicer):
    def __init__(self):
        super().__init__()
        self.calls = 0
        self.expose("Echo", self.echo)
        self.expose("Slow", self.slow)
        self.expose("Boom", self.boom)
        self.expose("FlakyOnce", self.flaky)
        self._flaky_done = False

    def echo(self, params):
        self.calls += 1
        return {"echo": params}

    def slow(self, params):
        time.sleep(params.get("seconds", 1.0))
        return {}

    def boom(self, params):
        raise ValueError("kaboom")

    def flaky(self, params):
        if not self._flaky_done:
            self._flaky_done = True
            raise VizierRpcError(StatusCode.UNAVAILABLE, "try again")
        return {"ok": 1}


@pytest.fixture
def server():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    yield srv, servicer
    srv.stop()


def test_echo_roundtrip(server):
    srv, _ = server
    client = RpcClient(srv.address)
    result = client.call("Echo", {"x": 1, "nested": {"b": b"bytes", "s": "str"}})
    assert result["echo"]["nested"]["b"] == b"bytes"
    client.close()


def test_unknown_method(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Nope", {})
    assert e.value.code == StatusCode.UNIMPLEMENTED


def test_application_error_maps_to_internal(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Boom", {})
    assert e.value.code == StatusCode.INTERNAL
    assert "kaboom" in e.value.message
    # the connection stays usable after an error
    assert client.call("Echo", {"a": 1})["echo"]["a"] == 1


def test_deadline(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Slow", {"seconds": 5.0}, timeout=0.3)
    assert e.value.code in (StatusCode.DEADLINE_EXCEEDED, StatusCode.UNAVAILABLE)


def test_retry_on_unavailable(server):
    srv, servicer = server
    client = RpcClient(srv.address)
    assert client.call("FlakyOnce", {})["ok"] == 1  # retried transparently


def test_reconnect_after_server_restart():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    addr = srv.address
    client = RpcClient(addr, max_retries=8, backoff_base=0.05)
    assert client.call("Echo", {"n": 1})["echo"]["n"] == 1
    srv.stop()
    host, port = addr.rsplit(":", 1)

    def restart():
        time.sleep(0.3)
        srv2 = RpcServer(EchoServicer(), host=host, port=int(port)).start()
        restart.srv2 = srv2

    t = threading.Thread(target=restart)
    t.start()
    # client reconnects once the server is back (client-side fault tolerance)
    assert client.call("Echo", {"n": 2}, timeout=10)["echo"]["n"] == 2
    t.join()
    restart.srv2.stop()


def test_concurrent_clients(server):
    srv, servicer = server
    errs = []

    def worker(i):
        try:
            c = RpcClient(srv.address)
            for j in range(20):
                assert c.call("Echo", {"i": i, "j": j})["echo"]["j"] == j
            c.close()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert servicer.calls == 160
