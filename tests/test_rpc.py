"""RPC transport: framing, retries, deadlines, reconnect, error mapping."""

import threading
import time

import pytest

from repro.service.rpc import (
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)


class EchoServicer(Servicer):
    def __init__(self):
        super().__init__()
        self.calls = 0
        self.expose("Echo", self.echo)
        self.expose("Slow", self.slow)
        self.expose("Boom", self.boom)
        self.expose("FlakyOnce", self.flaky)
        self._flaky_done = False

    def echo(self, params):
        self.calls += 1
        return {"echo": params}

    def slow(self, params):
        time.sleep(params.get("seconds", 1.0))
        return {}

    def boom(self, params):
        raise ValueError("kaboom")

    def flaky(self, params):
        if not self._flaky_done:
            self._flaky_done = True
            raise VizierRpcError(StatusCode.UNAVAILABLE, "try again")
        return {"ok": 1}


@pytest.fixture
def server():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    yield srv, servicer
    srv.stop()


def test_echo_roundtrip(server):
    srv, _ = server
    client = RpcClient(srv.address)
    result = client.call("Echo", {"x": 1, "nested": {"b": b"bytes", "s": "str"}})
    assert result["echo"]["nested"]["b"] == b"bytes"
    client.close()


def test_unknown_method(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Nope", {})
    assert e.value.code == StatusCode.UNIMPLEMENTED


def test_application_error_maps_to_internal(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Boom", {})
    assert e.value.code == StatusCode.INTERNAL
    assert "kaboom" in e.value.message
    # the connection stays usable after an error
    assert client.call("Echo", {"a": 1})["echo"]["a"] == 1


def test_deadline(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Slow", {"seconds": 5.0}, timeout=0.3)
    assert e.value.code in (StatusCode.DEADLINE_EXCEEDED, StatusCode.UNAVAILABLE)


def test_retry_on_unavailable(server):
    srv, servicer = server
    client = RpcClient(srv.address)
    assert client.call("FlakyOnce", {})["ok"] == 1  # retried transparently


def test_reconnect_after_server_restart():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    addr = srv.address
    client = RpcClient(addr, max_retries=8, backoff_base=0.05)
    assert client.call("Echo", {"n": 1})["echo"]["n"] == 1
    srv.stop()
    host, port = addr.rsplit(":", 1)

    def restart():
        time.sleep(0.3)
        srv2 = RpcServer(EchoServicer(), host=host, port=int(port)).start()
        restart.srv2 = srv2

    t = threading.Thread(target=restart)
    t.start()
    # client reconnects once the server is back (client-side fault tolerance)
    assert client.call("Echo", {"n": 2}, timeout=10)["echo"]["n"] == 2
    t.join()
    restart.srv2.stop()


def test_concurrent_clients(server):
    srv, servicer = server
    errs = []

    def worker(i):
        try:
            c = RpcClient(srv.address)
            for j in range(20):
                assert c.call("Echo", {"i": i, "j": j})["echo"]["j"] == j
            c.close()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert servicer.calls == 160


class AlwaysUnavailableServicer(Servicer):
    def __init__(self):
        super().__init__()
        self.calls = 0
        self.expose("Nope", self.nope)

    def nope(self, params):
        self.calls += 1
        raise VizierRpcError(StatusCode.UNAVAILABLE, "down for maintenance")


def test_backoff_sleep_clamped_to_deadline():
    """Regression: the retry loop used to sleep a full jittered backoff past
    the caller's deadline — with backoff_base=0.5 a 0.3 s call could return
    DEADLINE_EXCEEDED ~1 s late. Each backoff sleep is now clamped to the
    remaining budget, so the error surfaces at the deadline."""
    servicer = AlwaysUnavailableServicer()
    srv = RpcServer(servicer).start()
    try:
        client = RpcClient(srv.address, backoff_base=0.5, backoff_cap=2.0,
                           max_retries=10)
        start = time.monotonic()
        with pytest.raises(VizierRpcError) as ei:
            client.call("Nope", {}, timeout=0.3)
        elapsed = time.monotonic() - start
        assert ei.value.code == StatusCode.DEADLINE_EXCEEDED
        # unclamped, the first backoff alone sleeps 0.5-1.5s
        assert elapsed < 0.6, f"slept past the deadline: {elapsed:.3f}s"
        assert elapsed >= 0.28
        assert servicer.calls >= 1
        client.close()
    finally:
        srv.stop()


def test_backoff_sleep_clamped_in_call_many():
    """Same clamp on the pipelined path's transport-retry backoff."""
    servicer = AlwaysUnavailableServicer()
    srv = RpcServer(servicer).start()
    try:
        client = RpcClient(srv.address, backoff_base=0.5, backoff_cap=2.0,
                           max_retries=10)
        start = time.monotonic()
        with pytest.raises(VizierRpcError):
            # application-level UNAVAILABLE from call_many is not retried
            # (it raises), so drive the transport retry instead: dead server
            srv.stop()
            client.call_many("Nope", [{}, {}], timeout=0.3)
        assert time.monotonic() - start < 0.8
        client.close()
    finally:
        srv.stop()


def test_pooled_client_one_connection_per_thread(server):
    srv, servicer = server
    from repro.service.rpc import PooledRpcClient

    pooled = PooledRpcClient(srv.address)
    seen = {}

    def worker(i):
        seen[i] = pooled._client()
        assert pooled.call("Echo", {"i": i})["echo"]["i"] == i
        # same thread, same underlying client
        assert pooled._client() is seen[i]

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len({id(c) for c in seen.values()}) == 4  # one client per thread
    assert pooled.call_many("Echo", [{"j": 1}, {"j": 2}])[1]["echo"]["j"] == 2
    pooled.close()
