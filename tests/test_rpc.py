"""RPC transport: framing, retries, deadlines, reconnect, error mapping."""

import threading
import time

import pytest

from repro.service.rpc import (
    RpcClient,
    RpcServer,
    Servicer,
    StatusCode,
    VizierRpcError,
)


class EchoServicer(Servicer):
    def __init__(self):
        super().__init__()
        self.calls = 0
        self.expose("Echo", self.echo)
        self.expose("Slow", self.slow)
        self.expose("Boom", self.boom)
        self.expose("FlakyOnce", self.flaky)
        self._flaky_done = False

    def echo(self, params):
        self.calls += 1
        return {"echo": params}

    def slow(self, params):
        time.sleep(params.get("seconds", 1.0))
        return {}

    def boom(self, params):
        raise ValueError("kaboom")

    def flaky(self, params):
        if not self._flaky_done:
            self._flaky_done = True
            raise VizierRpcError(StatusCode.UNAVAILABLE, "try again")
        return {"ok": 1}


@pytest.fixture
def server():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    yield srv, servicer
    srv.stop()


def test_echo_roundtrip(server):
    srv, _ = server
    client = RpcClient(srv.address)
    result = client.call("Echo", {"x": 1, "nested": {"b": b"bytes", "s": "str"}})
    assert result["echo"]["nested"]["b"] == b"bytes"
    client.close()


def test_unknown_method(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Nope", {})
    assert e.value.code == StatusCode.UNIMPLEMENTED


def test_application_error_maps_to_internal(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Boom", {})
    assert e.value.code == StatusCode.INTERNAL
    assert "kaboom" in e.value.message
    # the connection stays usable after an error
    assert client.call("Echo", {"a": 1})["echo"]["a"] == 1


def test_deadline(server):
    srv, _ = server
    client = RpcClient(srv.address)
    with pytest.raises(VizierRpcError) as e:
        client.call("Slow", {"seconds": 5.0}, timeout=0.3)
    assert e.value.code in (StatusCode.DEADLINE_EXCEEDED, StatusCode.UNAVAILABLE)


def test_retry_on_unavailable(server):
    srv, servicer = server
    client = RpcClient(srv.address)
    assert client.call("FlakyOnce", {})["ok"] == 1  # retried transparently


def test_reconnect_after_server_restart():
    servicer = EchoServicer()
    srv = RpcServer(servicer).start()
    addr = srv.address
    client = RpcClient(addr, max_retries=8, backoff_base=0.05)
    assert client.call("Echo", {"n": 1})["echo"]["n"] == 1
    srv.stop()
    host, port = addr.rsplit(":", 1)

    def restart():
        time.sleep(0.3)
        srv2 = RpcServer(EchoServicer(), host=host, port=int(port)).start()
        restart.srv2 = srv2

    t = threading.Thread(target=restart)
    t.start()
    # client reconnects once the server is back (client-side fault tolerance)
    assert client.call("Echo", {"n": 2}, timeout=10)["echo"]["n"] == 2
    t.join()
    restart.srv2.stop()


def test_concurrent_clients(server):
    srv, servicer = server
    errs = []

    def worker(i):
        try:
            c = RpcClient(srv.address)
            for j in range(20):
                assert c.call("Echo", {"i": i, "j": j})["echo"]["j"] == j
            c.close()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert servicer.calls == 160


class AlwaysUnavailableServicer(Servicer):
    def __init__(self):
        super().__init__()
        self.calls = 0
        self.expose("Nope", self.nope)

    def nope(self, params):
        self.calls += 1
        raise VizierRpcError(StatusCode.UNAVAILABLE, "down for maintenance")


def test_backoff_sleep_clamped_to_deadline():
    """Regression: the retry loop used to sleep a full jittered backoff past
    the caller's deadline — with backoff_base=0.5 a 0.3 s call could return
    DEADLINE_EXCEEDED ~1 s late. Each backoff sleep is now clamped to the
    remaining budget, so the error surfaces at the deadline."""
    servicer = AlwaysUnavailableServicer()
    srv = RpcServer(servicer).start()
    try:
        client = RpcClient(srv.address, backoff_base=0.5, backoff_cap=2.0,
                           max_retries=10)
        start = time.monotonic()
        with pytest.raises(VizierRpcError) as ei:
            client.call("Nope", {}, timeout=0.3)
        elapsed = time.monotonic() - start
        assert ei.value.code == StatusCode.DEADLINE_EXCEEDED
        # unclamped, the first backoff alone sleeps 0.5-1.5s
        assert elapsed < 0.6, f"slept past the deadline: {elapsed:.3f}s"
        assert elapsed >= 0.28
        assert servicer.calls >= 1
        client.close()
    finally:
        srv.stop()


def test_backoff_sleep_clamped_in_call_many():
    """Same clamp on the pipelined path's transport-retry backoff."""
    servicer = AlwaysUnavailableServicer()
    srv = RpcServer(servicer).start()
    try:
        client = RpcClient(srv.address, backoff_base=0.5, backoff_cap=2.0,
                           max_retries=10)
        start = time.monotonic()
        with pytest.raises(VizierRpcError):
            # application-level UNAVAILABLE from call_many is not retried
            # (it raises), so drive the transport retry instead: dead server
            srv.stop()
            client.call_many("Nope", [{}, {}], timeout=0.3)
        assert time.monotonic() - start < 0.8
        client.close()
    finally:
        srv.stop()


def test_pooled_client_one_connection_per_thread(server):
    srv, servicer = server
    from repro.service.rpc import PooledRpcClient

    pooled = PooledRpcClient(srv.address)
    seen = {}

    def worker(i):
        seen[i] = pooled._client()
        assert pooled.call("Echo", {"i": i})["echo"]["i"] == i
        # same thread, same underlying client
        assert pooled._client() is seen[i]

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len({id(c) for c in seen.values()}) == 4  # one client per thread
    assert pooled.call_many("Echo", [{"j": 1}, {"j": 2}])[1]["echo"]["j"] == 2
    pooled.close()


# ---------------------------------------------------------------------------
# Partial-delivery resend (ISSUE 10 S1): non-idempotent batches
# ---------------------------------------------------------------------------


class IncrServicer(Servicer):
    """Non-idempotent by construction: every applied Incr is visible."""

    def __init__(self):
        super().__init__()
        self.counts = {}
        self.expose("Incr", self.incr)

    def incr(self, params):
        k = params["k"]
        self.counts[k] = self.counts.get(k, 0) + 1
        return {"k": k, "count": self.counts[k]}


def test_call_many_resends_only_undelivered_after_partial_delivery():
    """Regression: a mid-batch transport failure used to resend the WHOLE
    batch, double-applying every non-idempotent sub-request whose response
    had already been read. Now delivered responses are kept and only the
    undelivered tail is resent."""
    from repro.service import chaos
    from repro.service.chaos import Fault

    servicer = IncrServicer()
    srv = RpcServer(servicer).start()
    try:
        client = RpcClient(srv.address, backoff_base=0.01, backoff_cap=0.02)
        # drop the LAST response of a pipelined batch of 4: the server
        # applied all four, the client read three
        with chaos.scenario(11, [Fault(site="transport.recv", kind="drop",
                                       after=3, times=1)]):
            results = client.call_many("Incr", [{"k": i} for i in range(4)])
        assert [r["k"] for r in results] == [0, 1, 2, 3]
        # acknowledged sub-requests were NOT resent (the regression)
        assert [servicer.counts[i] for i in range(3)] == [1, 1, 1]
        # the one genuinely ambiguous sub-request (response lost after the
        # server applied it) is at-least-once, like any single call
        assert servicer.counts[3] == 2
        assert results[3]["count"] == 2
        client.close()
    finally:
        srv.stop()


def test_default_transport_call_raw_many_attaches_delivered():
    """The sequential fallback path carries the same contract."""
    from repro.service.rpc import Transport

    class FlakyThird(Transport):
        def __init__(self):
            self.sent = []

        def call_raw(self, request, timeout):
            if len(self.sent) == 2:
                raise VizierRpcError(StatusCode.UNAVAILABLE, "boom")
            self.sent.append(request["id"])
            return {"id": request["id"], "ok": True, "result": {}}

    t = FlakyThird()
    with pytest.raises(VizierRpcError) as ei:
        t.call_raw_many([{"id": str(i)} for i in range(4)], timeout=1.0)
    assert [r["id"] for r in ei.value.delivered] == ["0", "1"]


# ---------------------------------------------------------------------------
# Retry budget + circuit breaker (ISSUE 10 tentpole, client side)
# ---------------------------------------------------------------------------


def test_retry_budget_spend_refill_and_success_credit():
    from repro.service.rpc import RetryBudget

    b = RetryBudget(capacity=2.0, refill_per_s=0.0, success_credit=1.5)
    assert b.try_spend()
    assert b.try_spend()
    assert not b.try_spend()  # dry: stop retrying
    b.record_success()        # successes refund tokens...
    assert b.try_spend()
    assert not b.try_spend()  # ...capped by what was credited


def test_retry_budget_exhaustion_stops_transport_retries():
    """A dead server with a dry budget costs ~3 attempts, not max_retries
    backoff cycles — retries track success rate, not failure rate."""
    from repro.service.rpc import RetryBudget

    srv = RpcServer(EchoServicer()).start()
    addr = srv.address
    srv.stop()
    client = RpcClient(
        addr, max_retries=10, backoff_base=0.01, backoff_cap=0.02,
        retry_budget=RetryBudget(capacity=2.0, refill_per_s=0.0))
    start = time.monotonic()
    with pytest.raises(VizierRpcError) as ei:
        client.call("Echo", {}, timeout=10.0)
    assert ei.value.code == StatusCode.UNAVAILABLE
    # 10 retries at jittered backoff would take far longer
    assert time.monotonic() - start < 2.0
    client.close()


def test_circuit_breaker_state_machine():
    from repro.service.rpc import CircuitBreaker

    cb = CircuitBreaker(failure_threshold=2, cooldown_s=0.05)
    assert cb.allow()
    cb.record_failure()
    assert not cb.is_open and cb.allow()  # below threshold: still closed
    cb.record_failure()
    assert cb.is_open and not cb.allow()  # open: reject without I/O
    time.sleep(0.06)
    assert cb.allow()        # half-open: exactly one probe
    assert not cb.allow()    # concurrent second probe refused
    cb.record_failure()      # probe failed: re-open for another cooldown
    assert not cb.allow()
    time.sleep(0.06)
    assert cb.allow()
    cb.record_success()      # probe succeeded: closed again
    assert not cb.is_open and cb.allow()


def test_circuit_breaker_trips_on_consecutive_transport_failures():
    from repro.service.rpc import CircuitBreaker

    srv = RpcServer(EchoServicer()).start()
    addr = srv.address
    srv.stop()
    cb = CircuitBreaker(failure_threshold=2, cooldown_s=30.0)
    client = RpcClient(addr, max_retries=3, backoff_base=0.01,
                       backoff_cap=0.02, circuit_breaker=cb)
    with pytest.raises(VizierRpcError):
        client.call("Echo", {}, timeout=1.0)
    assert cb.is_open
    # while open, calls fail fast without touching the socket
    with pytest.raises(VizierRpcError) as ei:
        client.call("Echo", {}, timeout=1.0)
    assert "circuit breaker open" in ei.value.message
    client.close()


def test_application_errors_do_not_trip_the_breaker(server):
    srv, servicer = server
    client = RpcClient(srv.address, max_retries=0)
    for _ in range(20):
        with pytest.raises(VizierRpcError):
            client.call("Boom", {})
    assert not client.circuit_breaker.is_open  # the server is provably up
    assert client.call("Echo", {"x": 1})["echo"]["x"] == 1
    client.close()
