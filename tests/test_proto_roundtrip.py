"""Wire-format roundtrips for Trials/Measurements/StudyConfigs (hypothesis)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Measurement,
    Metadata,
    MetricInformation,
    ObjectiveMetricGoal,
    StudyConfig,
    Trial,
    TrialState,
    converters,
)

metric_values = st.floats(allow_nan=False, allow_infinity=False,
                          min_value=-1e12, max_value=1e12)
param_values = st.one_of(
    st.floats(allow_nan=False, allow_infinity=False, min_value=-1e9, max_value=1e9),
    st.integers(min_value=-10**9, max_value=10**9),
    st.text(min_size=0, max_size=20),
)


@st.composite
def measurements(draw):
    metrics = draw(st.dictionaries(st.text(min_size=1, max_size=10),
                                   metric_values, max_size=4))
    return Measurement(metrics=metrics,
                       steps=draw(st.integers(min_value=0, max_value=10**6)),
                       elapsed_secs=draw(st.floats(min_value=0, max_value=1e6)))


@st.composite
def trials(draw):
    t = Trial(
        id=draw(st.integers(min_value=1, max_value=10**6)),
        parameters=draw(st.dictionaries(st.text(min_size=1, max_size=8),
                                        param_values, max_size=5)),
    )
    for m in draw(st.lists(measurements(), max_size=3)):
        t.add_measurement(m)
    if draw(st.booleans()):
        t.complete(draw(measurements()))
    elif draw(st.booleans()):
        t.complete(infeasibility_reason="broken")
    t.metadata.ns("algo")["state"] = draw(st.text(max_size=30))
    return t


@given(trials())
@settings(max_examples=150, deadline=None)
def test_trial_roundtrip(trial):
    proto = trial.to_proto()
    back = Trial.from_proto(proto)
    assert back.to_proto() == proto
    assert back.id == trial.id
    assert back.state == trial.state
    assert back.parameters.as_dict() == trial.parameters.as_dict()
    assert back.metadata == trial.metadata


@given(measurements())
@settings(max_examples=100, deadline=None)
def test_measurement_roundtrip(m):
    assert Measurement.from_proto(m.to_proto()).to_proto() == m.to_proto()


def test_study_config_roundtrip(basic_config):
    proto = basic_config.to_proto()
    back = StudyConfig.from_proto(proto)
    assert back.to_proto() == proto
    assert back.algorithm == basic_config.algorithm
    assert [m.name for m in back.metrics] == [m.name for m in basic_config.metrics]


def test_converter_objects_match_paper_table2(basic_config):
    t = Trial(id=3, parameters={"a": 1.5})
    assert converters.TrialConverter.from_proto(
        converters.TrialConverter.to_proto(t)).id == 3
    protos = converters.TrialConverter.to_protos([t, t])
    assert len(converters.TrialConverter.from_protos(protos)) == 2
    mi = MetricInformation("m", ObjectiveMetricGoal.MINIMIZE)
    assert converters.MetricInformationConverter.from_proto(mi.to_proto()).goal \
        == ObjectiveMetricGoal.MINIMIZE


@st.composite
def search_spaces(draw):
    """Random search spaces: every parameter kind, every scale type."""
    from repro.core import ScaleType, SearchSpace

    space = SearchSpace()
    root = space.select_root()
    n_params = draw(st.integers(min_value=1, max_value=5))
    for i in range(n_params):
        kind = draw(st.sampled_from(["float", "log_float", "int",
                                     "categorical", "discrete"]))
        name = f"p{i}_{kind}"
        if kind == "float":
            lo = draw(st.floats(min_value=-1e6, max_value=1e6,
                                allow_nan=False, allow_infinity=False))
            span = draw(st.floats(min_value=1e-6, max_value=1e6,
                                  allow_nan=False, allow_infinity=False))
            root.add_float_param(name, lo, lo + span,
                                 scale_type=ScaleType.LINEAR)
        elif kind == "log_float":
            lo = draw(st.floats(min_value=1e-9, max_value=1e3,
                                allow_nan=False, allow_infinity=False))
            factor = draw(st.floats(min_value=1.5, max_value=1e6,
                                    allow_nan=False, allow_infinity=False))
            root.add_float_param(name, lo, lo * factor,
                                 scale_type=ScaleType.LOG)
        elif kind == "int":
            lo = draw(st.integers(min_value=-1000, max_value=1000))
            span = draw(st.integers(min_value=0, max_value=1000))
            root.add_int_param(name, lo, lo + span)
        elif kind == "categorical":
            values = draw(st.lists(st.text(min_size=1, max_size=6),
                                   min_size=1, max_size=5, unique=True))
            root.add_categorical_param(name, values)
        else:
            values = sorted(draw(st.lists(
                st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                          allow_infinity=False),
                min_size=1, max_size=6, unique=True)))
            root.add_discrete_param(name, values)
    return space


@st.composite
def study_configs(draw):
    from repro.core import StudyConfig

    cfg = StudyConfig()
    cfg.search_space = draw(search_spaces())
    n_metrics = draw(st.integers(min_value=1, max_value=3))
    for i in range(n_metrics):
        cfg.metrics.add(
            f"m{i}", draw(st.sampled_from(["MAXIMIZE", "MINIMIZE"])),
            safety_threshold=draw(st.one_of(
                st.sampled_from([None]),   # shim-safe st.none()
                st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                          allow_infinity=False))))
    cfg.algorithm = draw(st.sampled_from(
        ["RANDOM_SEARCH", "GP_UCB", "GRID_SEARCH", "CMA_ES"]))
    return cfg


@given(study_configs())
@settings(max_examples=40, deadline=None)
def test_study_config_roundtrip_property(cfg):
    """Arbitrary StudyConfigs — multi-metric, safety thresholds and all —
    survive the wire format bit-for-bit."""
    proto = cfg.to_proto()
    back = StudyConfig.from_proto(proto)
    assert back.to_proto() == proto
    assert back.algorithm == cfg.algorithm
    assert [m.name for m in back.metrics] == [m.name for m in cfg.metrics]
    assert [m.safety_threshold for m in back.metrics] == \
        [m.safety_threshold for m in cfg.metrics]
    assert len(back.search_space.parameters) == len(cfg.search_space.parameters)


def test_metrics_add_safety_threshold_and_duplicates():
    """MetricsConfig.add accepts safety_threshold (it used to silently lack
    the parameter), and duplicate metric ids are rejected on BOTH build
    paths — .add() and from_proto (which used to bare-append around the
    check, roundtripping ambiguous studies)."""
    import pytest

    cfg = StudyConfig()
    mi = cfg.metrics.add("safe_m", "MAXIMIZE", safety_threshold=0.25)
    assert mi.safety_threshold == 0.25
    assert StudyConfig.from_proto(cfg.to_proto()).metrics[0].safety_threshold \
        == 0.25
    with pytest.raises(ValueError, match="duplicate metric"):
        cfg.metrics.add("safe_m", "MINIMIZE")
    proto = cfg.to_proto()
    proto["metrics"].append(dict(proto["metrics"][0]))
    with pytest.raises(ValueError, match="duplicate metric"):
        StudyConfig.from_proto(proto)


@given(search_spaces(), st.integers(min_value=0, max_value=10**6))
@settings(max_examples=40, deadline=None)
def test_search_space_sampling_within_bounds(space, seed):
    """Every sampled assignment is feasible: in-bounds for continuous/int
    params, a member of the feasible set for categorical/discrete — and the
    space's own validator agrees, before and after a proto roundtrip."""
    import random as _random

    from repro.core import SearchSpace

    params = space.sample(_random.Random(seed))
    space.validate_parameters(params)
    by_name = {c.name: c for c in space.parameters}
    for name, value in params.items():
        cfg = by_name[name]
        if cfg.bounds is not None:
            lo, hi = cfg.bounds
            assert lo <= value.as_float <= hi, (name, value)
        elif cfg.categories is not None:
            assert value.as_str in cfg.categories
        else:
            assert value.as_float in cfg.feasible_values
    # same space after a wire roundtrip accepts the same assignment
    back = SearchSpace.from_proto(space.to_proto())
    back.validate_parameters(params)


@st.composite
def conditional_spaces(draw):
    """Random conditional trees: categorical parents, mixed-kind children,
    occasional grandchildren."""
    from repro.core import SearchSpace

    space = SearchSpace()
    root = space.select_root()
    for i in range(draw(st.integers(min_value=1, max_value=3))):
        cats = [f"c{j}" for j in range(draw(st.integers(min_value=2, max_value=4)))]
        parent = root.add_categorical_param(f"p{i}", cats)
        for k in range(draw(st.integers(min_value=0, max_value=3))):
            matches = draw(st.lists(st.sampled_from(cats), min_size=1,
                                    max_size=len(cats), unique=True))
            scope = parent.select_values(matches)
            name = f"p{i}_ch{k}"
            kind = draw(st.sampled_from(["float", "int", "cat"]))
            if kind == "float":
                scope.add_float_param(name, 0.0, 1.0)
            elif kind == "int":
                scope.add_int_param(name, 0, 5)
            else:
                sub = scope.add_categorical_param(name, ["x", "y"])
                if draw(st.booleans()):  # grandchild: depth-2 conditionality
                    sub.select_values(["x"]).add_float_param(
                        f"{name}_g", 0.0, 2.0)
    return space


def _tree_shape(space):
    """The conditional tree as a comparable value: names, types, and the
    parent-value matches guarding each child, recursively."""
    def shape(cfg):
        return (cfg.name, cfg.type.value, tuple(
            (tuple(matches), shape(child)) for matches, child in cfg.children))
    return tuple(shape(c) for c in space.parameters)


@given(conditional_spaces(), st.integers(min_value=0, max_value=10**6))
@settings(max_examples=40, deadline=None)
def test_conditional_tree_proto_roundtrip(space, seed):
    """from_proto(to_proto(space)) preserves the conditional tree exactly,
    and samples drawn from the roundtripped space validate in the original."""
    import random as _random

    from repro.core import SearchSpace

    proto = space.to_proto()
    back = SearchSpace.from_proto(proto)
    assert back.to_proto() == proto
    assert _tree_shape(back) == _tree_shape(space)
    space.validate_parameters(back.sample(_random.Random(seed)))


def test_prior_study_names_roundtrip(basic_config):
    basic_config.prior_studies = [
        "owners/o/studies/a", "owners/o/studies/b", "owners/o/studies/a"]
    assert basic_config.prior_study_names == [
        "owners/o/studies/a", "owners/o/studies/b"]  # deduped, order kept
    back = StudyConfig.from_proto(basic_config.to_proto())
    assert back.prior_study_names == basic_config.prior_study_names
    # empty stays absent from the wire form
    assert "prior_study_names" not in StudyConfig().to_proto()


def test_metadata_namespaces():
    md = Metadata()
    md["top"] = "1"
    sub = md.ns("gp")
    sub["state"] = "xyz"
    sub2 = md.ns("gp")
    assert sub2["state"] == "xyz"
    assert "top" not in sub2
    proto = md.to_proto()
    back = Metadata.from_proto(proto)
    assert back == md
    assert back.ns("gp")["state"] == "xyz"
