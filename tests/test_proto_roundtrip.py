"""Wire-format roundtrips for Trials/Measurements/StudyConfigs (hypothesis)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Measurement,
    Metadata,
    MetricInformation,
    ObjectiveMetricGoal,
    StudyConfig,
    Trial,
    TrialState,
    converters,
)

metric_values = st.floats(allow_nan=False, allow_infinity=False,
                          min_value=-1e12, max_value=1e12)
param_values = st.one_of(
    st.floats(allow_nan=False, allow_infinity=False, min_value=-1e9, max_value=1e9),
    st.integers(min_value=-10**9, max_value=10**9),
    st.text(min_size=0, max_size=20),
)


@st.composite
def measurements(draw):
    metrics = draw(st.dictionaries(st.text(min_size=1, max_size=10),
                                   metric_values, max_size=4))
    return Measurement(metrics=metrics,
                       steps=draw(st.integers(min_value=0, max_value=10**6)),
                       elapsed_secs=draw(st.floats(min_value=0, max_value=1e6)))


@st.composite
def trials(draw):
    t = Trial(
        id=draw(st.integers(min_value=1, max_value=10**6)),
        parameters=draw(st.dictionaries(st.text(min_size=1, max_size=8),
                                        param_values, max_size=5)),
    )
    for m in draw(st.lists(measurements(), max_size=3)):
        t.add_measurement(m)
    if draw(st.booleans()):
        t.complete(draw(measurements()))
    elif draw(st.booleans()):
        t.complete(infeasibility_reason="broken")
    t.metadata.ns("algo")["state"] = draw(st.text(max_size=30))
    return t


@given(trials())
@settings(max_examples=150, deadline=None)
def test_trial_roundtrip(trial):
    proto = trial.to_proto()
    back = Trial.from_proto(proto)
    assert back.to_proto() == proto
    assert back.id == trial.id
    assert back.state == trial.state
    assert back.parameters.as_dict() == trial.parameters.as_dict()
    assert back.metadata == trial.metadata


@given(measurements())
@settings(max_examples=100, deadline=None)
def test_measurement_roundtrip(m):
    assert Measurement.from_proto(m.to_proto()).to_proto() == m.to_proto()


def test_study_config_roundtrip(basic_config):
    proto = basic_config.to_proto()
    back = StudyConfig.from_proto(proto)
    assert back.to_proto() == proto
    assert back.algorithm == basic_config.algorithm
    assert [m.name for m in back.metrics] == [m.name for m in basic_config.metrics]


def test_converter_objects_match_paper_table2(basic_config):
    t = Trial(id=3, parameters={"a": 1.5})
    assert converters.TrialConverter.from_proto(
        converters.TrialConverter.to_proto(t)).id == 3
    protos = converters.TrialConverter.to_protos([t, t])
    assert len(converters.TrialConverter.from_protos(protos)) == 2
    mi = MetricInformation("m", ObjectiveMetricGoal.MINIMIZE)
    assert converters.MetricInformationConverter.from_proto(mi.to_proto()).goal \
        == ObjectiveMetricGoal.MINIMIZE


def test_metadata_namespaces():
    md = Metadata()
    md["top"] = "1"
    sub = md.ns("gp")
    sub["state"] = "xyz"
    sub2 = md.ns("gp")
    assert sub2["state"] == "xyz"
    assert "top" not in sub2
    proto = md.to_proto()
    back = Metadata.from_proto(proto)
    assert back == md
    assert back.ns("gp")["state"] == "xyz"
