"""Subprocess halves of the crash-restart durability test (test_chaos.py).

Two phases, run as separate processes so the kill is a REAL ``SIGKILL`` —
no atexit, no finally blocks, no flush; exactly what a machine failure
looks like to the datastore.

``serve DB SHARDS SENTINEL``
    Start a DefaultVizierServer on the durable path, complete one trial
    (acked work that must survive), dispatch a 2-suggestion op into the
    SLEEPY policy (stalls CRASH_SLEEP seconds inside the worker batch),
    write the sentinel JSON, then sleep until killed.

``recover DB SHARDS {wait|get} OP_NAME STUDY_NAME``
    Fresh server on the same path (CRASH_SLEEP=0 in the parent's env):
    ``recover_pending_operations`` re-enqueues the interrupted op; poll it
    to completion via WaitOperation long-poll or the classic GetOperation
    loop, then print a JSON report for the parent's assertions.
"""

import json
import os
import sys
import time

from repro.core import Trial
from repro.pythia.baseline_designers import RandomSearchDesigner
from repro.pythia.designers import SerializableDesignerPolicy
from repro.pythia.policy import Policy
from repro.pythia.registry import register
from repro.service import DefaultVizierServer, VizierClient
from repro.service.rpc import RpcClient


@register("SLEEPY")
def _sleepy(supporter, config):
    inner = SerializableDesignerPolicy(
        supporter, lambda cfg: RandomSearchDesigner(cfg), RandomSearchDesigner)
    sleep_s = float(os.environ.get("CRASH_SLEEP", "30"))

    class SleepyPolicy(Policy):
        def suggest(self, request):
            time.sleep(sleep_s)  # the parent SIGKILLs us in here
            return inner.suggest(request)

    return SleepyPolicy()


def _config():
    from repro.core import ObjectiveMetricGoal, StudyConfig

    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("acc", ObjectiveMetricGoal.MAXIMIZE)
    cfg.algorithm = "SLEEPY"
    return cfg


def _server(db: str, shards: int) -> DefaultVizierServer:
    return DefaultVizierServer(
        database_path=db,
        database_shards=shards,
        n_pythia_workers=2,
        n_shards=4,
    )


def serve(db: str, shards: int, sentinel: str) -> None:
    server = _server(db, shards)
    client = VizierClient.load_or_create_study(
        "crash", _config(), client_id="killer", target=server.address)

    # acked work that must survive the kill (different client_id so the
    # suggest below cannot be satisfied by handing this trial back)
    done = client.add_trial(Trial(parameters={"x": 0.5}))
    client.complete_trial({"acc": 1.0}, trial_id=done.id)

    # dispatch without awaiting: the op record is durable before the RPC
    # returns; a worker leases it and stalls inside SLEEPY.suggest
    rpc = RpcClient(server.address)
    op = rpc.call("SuggestTrials", {
        "parent": client.study_name,
        "suggestion_count": 2,
        "client_id": "killer",
    })["operation"]

    payload = {
        "op_name": op["name"],
        "study_name": client.study_name,
        "completed_trial_id": done.id,
    }
    tmp = sentinel + ".tmp"
    with open(tmp, "w") as f:
        f.write(json.dumps(payload))
    os.replace(tmp, sentinel)
    time.sleep(3600)  # killed long before this returns


def recover(db: str, shards: int, poll_mode: str, op_name: str,
            study_name: str) -> None:
    server = _server(db, shards)  # recover=True re-enqueues the pending op
    rpc = RpcClient(server.address)
    deadline = time.monotonic() + 60
    while True:
        if poll_mode == "wait":
            op = rpc.call("WaitOperation",
                          {"name": op_name, "timeout_ms": 2000})["operation"]
        else:
            op = rpc.call("GetOperation", {"name": op_name})["operation"]
        if op.get("done"):
            break
        if time.monotonic() > deadline:
            break
        if poll_mode == "get":
            time.sleep(0.05)

    client = VizierClient(server.address, study_name, "recover")
    trials = client.list_trials()
    completed = [t for t in trials if t.state.is_terminal]
    report = {
        "done": bool(op.get("done")),
        "error": op.get("error"),
        "requeues": op.get("requeues"),
        "result_trials": len((op.get("result") or {}).get("trials", [])),
        "trial_count": len(trials),
        "completed_trial_state_terminal": len(completed) >= 1,
    }
    client.close()
    rpc.close()
    server.stop()
    print(json.dumps(report))


def main(argv) -> int:
    cmd = argv[1]
    if cmd == "serve":
        serve(argv[2], int(argv[3]), argv[4])
    elif cmd == "recover":
        recover(argv[2], int(argv[3]), argv[4], argv[5], argv[6])
    else:
        raise SystemExit(f"unknown phase {cmd!r}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
