"""Substrate: optimizer, data, checkpoint, compression, sharding, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    compress_with_feedback,
    init_error_feedback,
)
from repro.distributed.elastic import ElasticController, plan_elastic_mesh
from repro.distributed.sharding import ShardingCtx, make_rules, parse_axes
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, make_dataset
from repro.train.optimizer import AdamW, Adafactor, constant_lr, global_norm


# -- optimizers --------------------------------------------------------------------


@pytest.mark.parametrize("opt_cls", [AdamW, Adafactor])
def test_optimizer_minimizes_quadratic(opt_cls):
    opt = opt_cls(schedule=constant_lr(0.1))
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0),
              "m": jnp.ones((4, 4))}
    state = opt.init(params)

    def loss_fn(p):
        return (jnp.sum(p["w"] ** 2) + p["b"] ** 2 + jnp.sum(p["m"] ** 2))

    for _ in range(150):
        grads = jax.grad(loss_fn)(params)
        params, state, info = opt.apply(grads, state, params)
    assert float(loss_fn(params)) < 0.3, opt_cls.__name__


def test_adamw_clipping():
    opt = AdamW(schedule=constant_lr(0.01), clip_norm=1.0)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    _, _, info = opt.apply({"w": jnp.asarray([1e6])}, state, params)
    assert float(info["grad_norm"]) == pytest.approx(1e6)


def test_adamw_state_axes_match_params():
    opt = AdamW(schedule=constant_lr(0.1))
    axes = {"w": "embed mlp", "b": "-"}
    st_axes = opt.state_axes(axes)
    assert st_axes.m == axes and st_axes.v == axes


# -- data ---------------------------------------------------------------------------


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=8, seed=5)
    full = make_dataset(cfg)
    b0 = full.batch_at(3)
    b1 = full.batch_at(3)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])  # deterministic
    # labels are next tokens
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
    # shards partition the batch deterministically
    s0 = make_dataset(cfg, shard_id=0, num_shards=2).batch_at(3)
    s1 = make_dataset(cfg, shard_id=1, num_shards=2).batch_at(3)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_token_file_dataset(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10000, dtype=np.uint16).tofile(path)
    cfg = DataConfig(vocab_size=50000, seq_len=32, global_batch=4,
                     token_file=str(path))
    ds = make_dataset(cfg)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- checkpoint -----------------------------------------------------------------------


def test_checkpoint_roundtrip_and_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "nested": {"b": jnp.ones(4), "step": jnp.asarray(7)}}
    ckpt.save_checkpoint(d, 10, tree)
    tree2 = jax.tree.map(jnp.zeros_like, tree)
    step, restored = ckpt.restore_latest(d, tree2)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    # newer checkpoint wins; uncommitted ones are ignored
    ckpt.save_checkpoint(d, 20, tree)
    os.remove(os.path.join(d, "step_00000020", "COMMITTED"))
    assert ckpt.latest_step(d) == 10
    ckpt.save_checkpoint(d, 30, tree)
    ckpt.prune_old(d, keep=1)
    assert ckpt.latest_step(d) == 30


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(d, 1, {"a": jnp.ones((3, 3))})


# -- gradient compression ---------------------------------------------------------------


def test_compression_error_feedback_reduces_bias():
    rng = np.random.RandomState(0)
    grads = {"w": jnp.asarray(rng.randn(1000) * 1e-3, jnp.float32)}
    err = init_error_feedback(grads)
    # single-shot quantization error
    deq1, err1 = compress_with_feedback(grads, err)
    e1 = float(jnp.max(jnp.abs(deq1["w"] - grads["w"])))
    assert e1 < 1e-4  # int8 block quant of small grads
    # accumulated feedback: repeated identical grads average to the truth
    total = jnp.zeros_like(grads["w"])
    err = init_error_feedback(grads)
    for _ in range(32):
        deq, err = compress_with_feedback(grads, err)
        total = total + deq["w"]
    avg = total / 32
    assert float(jnp.max(jnp.abs(avg - grads["w"]))) < 2e-5


# -- sharding rules -------------------------------------------------------------------


def test_spec_divisibility_fallback():
    import jax
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, rules={"heads": ("model",), "batch": ("data",)})
    # axis size 1 -> never sharded, no fallback needed
    spec = ctx.spec_for("batch - heads -", (8, 4, 56, 64))
    assert spec == jax.sharding.PartitionSpec(None, None, None, None)


def test_parse_axes():
    assert parse_axes("vocab embed") == ("vocab", "embed")
    assert parse_axes("- mlp -") == (None, "mlp", None)
    assert parse_axes(("a", None)) == ("a", None)


def test_rules_decode_and_context_parallel():
    r = make_rules("decode")
    assert r["kv_seq"] == ("model",)
    r2 = make_rules("decode", context_parallel=True)
    assert r2["kv_seq"] == ("data", "model") and r2["batch"] == ()


# -- elastic -----------------------------------------------------------------------------


def test_elastic_plan_preserves_tp():
    assert plan_elastic_mesh(512, model_parallel=16) == (32, 16)
    assert plan_elastic_mesh(496, model_parallel=16) == (31, 16)
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16)


def test_elastic_controller_failure_and_rejoin():
    ctl = ElasticController(4, heartbeat_timeout=0.1, model_parallel=2)
    gen0 = ctl.generation
    ctl.fail(2)
    assert ctl.check() == [2]
    assert ctl.generation > gen0
    assert ctl.plan(devices_per_host=8) == (12, 2)  # 3 hosts * 8 / 2
    ctl.heartbeat(2)  # host rejoins
    assert ctl.alive_hosts() == [0, 1, 2, 3]
    assert ctl.plan(devices_per_host=8) == (16, 2)


def test_elastic_reshard_roundtrip():
    from repro.distributed.elastic import reshard_state
    from jax.sharding import Mesh

    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    axes = {"w": "embed mlp"}
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    out = reshard_state(state, axes, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
