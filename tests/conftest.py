import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import pytest


def pytest_sessionfinish(session, exitstatus):
    """Under ARCHLINT_WITNESS=1 the whole run doubles as a lock-order
    audit: fail the session if the witnessed acquisition graph has a cycle
    (see tools/archlint/README.md, runtime witness)."""
    from repro.service import _lockwitness as lw

    if not lw.witness_enabled():
        return
    try:
        lw.WITNESS.assert_acyclic()
    except lw.LockOrderViolation as e:
        session.exitstatus = 1
        print(f"\n[lockwitness] {e}", file=sys.stderr)
        raise

from repro.core import (
    Measurement,
    ObjectiveMetricGoal,
    ScaleType,
    StudyConfig,
    Trial,
)


@pytest.fixture
def basic_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    root.add_int_param("layers", 1, 8)
    root.add_categorical_param("act", ["relu", "gelu", "silu"])
    cfg.metrics.add("acc", ObjectiveMetricGoal.MAXIMIZE)
    cfg.algorithm = "RANDOM_SEARCH"
    return cfg


@pytest.fixture
def conditional_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    model = root.add_categorical_param("model", ["linear", "dnn", "forest"])
    dnn = model.select_values(["dnn"])
    dnn.add_int_param("num_layers", 1, 5)
    dnn.add_float_param("dropout", 0.0, 0.5)
    forest = model.select_values(["forest"])
    forest.add_int_param("num_trees", 10, 100)
    cfg.metrics.add("acc", ObjectiveMetricGoal.MAXIMIZE)
    cfg.algorithm = "RANDOM_SEARCH"
    return cfg


def completed_trial(uid: int, params: dict, metrics: dict) -> Trial:
    t = Trial(id=uid, parameters=params)
    t.complete(Measurement(metrics=metrics))
    return t
