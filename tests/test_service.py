"""Service-level behavior: the paper's §3 and §5 guarantees."""

import threading
import time

import pytest

from repro.core import (
    Measurement,
    ObjectiveMetricGoal,
    StudyConfig,
    StudyState,
    Trial,
    TrialState,
)
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    InMemoryDatastore,
    SQLiteDatastore,
    VizierClient,
    VizierService,
)
from repro.service.vizier_service import InProcessPythia


@pytest.fixture(params=["memory", "sqlite"])
def datastore(request, tmp_path):
    if request.param == "memory":
        return InMemoryDatastore()
    return SQLiteDatastore(str(tmp_path / "vizier.db"))


def make_local(datastore, **kw) -> VizierService:
    return VizierService(datastore, InProcessPythia(datastore), **kw)


def test_suggest_complete_cycle(basic_config, datastore):
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study(
        "s1", basic_config, client_id="c0", target=svc)
    for _ in range(3):
        (trial,) = client.get_suggestions(count=1)
        assert trial.state == TrialState.ACTIVE
        assert trial.client_id == "c0"
        client.complete_trial({"acc": 0.5}, trial_id=trial.id)
    assert len(client.list_trials(states=[TrialState.COMPLETED])) == 3
    svc.shutdown()


def test_client_rebind_same_trial(basic_config, datastore):
    """Paper §5: restarted worker with the same client_id resumes its trial."""
    svc = make_local(datastore)
    c1 = VizierClient.load_or_create_study("s1", basic_config, client_id="w",
                                           target=svc)
    (t1,) = c1.get_suggestions(count=1)
    c2 = VizierClient(svc, c1.study_name, "w")  # "restarted" worker
    (t2,) = c2.get_suggestions(count=1)
    assert t1.id == t2.id
    # a different client gets a different trial
    c3 = VizierClient(svc, c1.study_name, "other")
    (t3,) = c3.get_suggestions(count=1)
    assert t3.id != t1.id
    svc.shutdown()


def test_server_crash_operation_recovery(basic_config, tmp_path):
    """Paper §3.2: ops persisted in the datastore restart after a crash."""

    ds = SQLiteDatastore(str(tmp_path / "crash.db"))
    svc1 = make_local(ds)

    # Interruptible block: a bare time.sleep(999) leaves the pool worker
    # alive after the test, and the executor's atexit join then hangs the
    # whole pytest process for the rest of the sleep.
    release = threading.Event()

    class BlockedPythia(InProcessPythia):
        def suggest(self, study, count, client_id):
            release.wait(999)
            raise RuntimeError("blocked op released at test teardown")

    svc1._pythia = BlockedPythia(ds)
    client = VizierClient.load_or_create_study("s1", basic_config,
                                               client_id="c0", target=svc1)
    # request suggestions; op gets stuck "mid-computation"
    result = svc1.dispatch({"id": "1", "method": "SuggestTrials",
                            "params": {"parent": client.study_name,
                                       "suggestion_count": 1, "client_id": "c0"}})
    op_name = result["result"]["operation"]["name"]
    assert not result["result"]["operation"]["done"]
    svc1.shutdown()  # server crash — op is still pending in the datastore

    # new server process over the same durable datastore
    svc2 = make_local(ds)
    recovered = svc2.recover_pending_operations()
    assert recovered >= 1
    deadline = time.time() + 30
    while time.time() < deadline:
        op = ds.get_operation(op_name)
        if op["done"]:
            break
        time.sleep(0.05)
    assert op["done"] and not op.get("error"), op
    assert op["result"]["trials"], "recovered op produced suggestions"
    release.set()  # unblock svc1's stuck worker so the process can exit
    svc2.shutdown()


def test_stalled_trial_reassignment(basic_config, datastore):
    """Paper §5: trials from dead clients are reassigned after a timeout."""
    svc = make_local(datastore, reassign_stalled_after=0.2)
    c1 = VizierClient.load_or_create_study("s1", basic_config, client_id="dead",
                                           target=svc)
    (t1,) = c1.get_suggestions(count=1)
    time.sleep(0.4)  # dead client stops heartbeating
    c2 = VizierClient(svc, c1.study_name, "alive")
    (t2,) = c2.get_suggestions(count=1)
    assert t2.id == t1.id, "stalled trial should be handed to the live client"
    assert t2.client_id == "alive"
    svc.shutdown()


def test_heartbeat_prevents_reassignment(basic_config, datastore):
    # Margins matter in both directions: heartbeats span MORE than the stall
    # threshold (1.8s > 1.2s — without them the trial WOULD be reassigned,
    # so the test cannot pass vacuously), while each heartbeat gap (0.3s)
    # stays far enough under the threshold to tolerate scheduler stalls on
    # a loaded box.
    svc = make_local(datastore, reassign_stalled_after=1.2)
    c1 = VizierClient.load_or_create_study("s1", basic_config, client_id="slow",
                                           target=svc)
    (t1,) = c1.get_suggestions(count=1)
    for _ in range(6):  # intermediate measurements act as heartbeats
        time.sleep(0.3)
        c1.report_intermediate_objective_value({"acc": 0.1}, trial_id=t1.id, step=1)
    c2 = VizierClient(svc, c1.study_name, "thief")
    (t2,) = c2.get_suggestions(count=1)
    assert t2.id != t1.id
    svc.shutdown()


def test_infeasible_trial(basic_config, datastore):
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study("s1", basic_config,
                                               client_id="c", target=svc)
    (t,) = client.get_suggestions(count=1)
    done = client.complete_trial(trial_id=t.id, infeasibility_reason="OOM")
    assert done.state == TrialState.INFEASIBLE
    assert done.infeasibility_reason == "OOM"
    # infeasible trials are excluded from optimal trials
    assert client.list_optimal_trials() == []
    svc.shutdown()


def test_multiobjective_optimal_trials(datastore):
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1)
    cfg.metrics.add("cost", ObjectiveMetricGoal.MINIMIZE)
    cfg.metrics.add("quality", ObjectiveMetricGoal.MAXIMIZE)
    cfg.algorithm = "RANDOM_SEARCH"
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study("mo", cfg, client_id="c",
                                               target=svc)
    points = [(1.0, 1.0), (2.0, 2.0), (1.5, 0.5), (3.0, 2.5), (2.5, 1.0)]
    for cost, quality in points:
        (t,) = client.get_suggestions(count=1)
        client.complete_trial({"cost": cost, "quality": quality}, trial_id=t.id)
    optimal = client.list_optimal_trials()
    got = sorted((t.final_objective("cost"), t.final_objective("quality"))
                 for t in optimal)
    assert got == [(1.0, 1.0), (2.0, 2.0), (3.0, 2.5)]
    svc.shutdown()


def test_study_state_stops_suggestions(basic_config, datastore):
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study("s1", basic_config,
                                               client_id="c", target=svc)
    (t,) = client.get_suggestions(count=1)
    client.complete_trial({"acc": 1.0}, trial_id=t.id)
    client.set_study_state(StudyState.COMPLETED)
    assert client.get_suggestions(count=1) == []  # loop terminates
    svc.shutdown()


def test_add_trial_for_transfer(basic_config, datastore):
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study("s1", basic_config,
                                               client_id="c", target=svc)
    prior = Trial(parameters={"lr": 0.01, "layers": 2, "act": "relu"})
    prior.complete(Measurement(metrics={"acc": 0.9}))
    added = client.add_trial(prior)
    assert added.id == 1
    assert client.get_trial(added.id).final_objective("acc") == 0.9
    svc.shutdown()


def test_tcp_and_distributed_topologies(basic_config):
    server = DefaultVizierServer()
    client = VizierClient.load_or_create_study("t", basic_config,
                                               client_id="c",
                                               target=server.address)
    (t,) = client.get_suggestions(count=1)
    client.complete_trial({"acc": 0.3}, trial_id=t.id)
    server.stop()

    dist = DistributedVizierServer()
    client = VizierClient.load_or_create_study("t2", basic_config,
                                               client_id="c",
                                               target=dist.address)
    (t,) = client.get_suggestions(count=1)
    client.complete_trial({"acc": 0.4}, trial_id=t.id)
    assert len(client.list_trials()) == 1
    dist.stop()


def test_parallel_clients_unique_trials(basic_config, datastore):
    svc = make_local(datastore)
    client = VizierClient.load_or_create_study("par", basic_config,
                                               client_id="seed", target=svc)
    ids, errs = [], []
    lock = threading.Lock()

    def worker(wid):
        try:
            c = VizierClient(svc, client.study_name, f"w{wid}")
            for _ in range(3):
                (t,) = c.get_suggestions(count=1)
                with lock:
                    ids.append(t.id)
                c.complete_trial({"acc": 0.1 * wid}, trial_id=t.id)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    assert len(ids) == 12 and len(set(ids)) == 12, "every trial unique"
    svc.shutdown()


def test_delete_study_prunes_lock_map(basic_config):
    """Regression: DeleteStudy never evicted the per-study lock, so a
    create/delete churn workload leaked one threading.Lock per study for
    the life of the server. 1k churned studies must leave the map empty."""
    ds = InMemoryDatastore()
    svc = make_local(ds)
    spec = basic_config.to_proto()
    for i in range(1000):
        r = svc.CreateStudy(
            {"owner": "churn", "display_name": f"s{i}", "study_spec": spec})
        name = r["study"]["name"]
        # a COMPLETED study's SuggestTrials takes the inline fast path —
        # it touches (and therefore instantiates) the study's lock without
        # dispatching Pythia
        svc.SetStudyState({"name": name, "state": StudyState.COMPLETED.value})
        op = svc.SuggestTrials({"parent": name, "client_id": "w"})["operation"]
        assert op["done"] and op["result"] == {"trials": []}
        svc.DeleteStudy({"name": name})
    assert len(svc._study_locks) == 0, len(svc._study_locks)
    svc.shutdown()
