"""Deterministic chaos-injection suite (ISSUE 10).

Layers:

1. Unit tests of the harness itself — seeded determinism (same seed → same
   firing trace), the ``after``/``times``/``prob`` windows, per-kind effects
   (expire_lease, kill_worker, corrupt), the datastore proxy, and the
   env-var installation path.
2. Fast end-to-end smokes: a mild mixed storm through each topology (these
   ride in the coverage-floor run).
3. The slow sweep: ~10 named fault schedules x both topologies, each a real
   socketed server + multi-threaded client workload, asserting the
   robustness invariants after every storm:
     * no lost acked work — every operation reaches ``done``, every trial a
       client saw complete stays terminal;
     * no duplicate trials — trial ids in each study are unique;
     * per-item isolation — every failure surfaces as an int status code;
     * the queue fully drains (exactly-once finalize, nothing stranded);
     * non-vacuity — the schedule's target seam actually fired.
4. Crash-restart durability (subprocess SIGKILL mid-suggest-batch; see
   ``tests/_crash_server.py``): after restarting on the same database path,
   ``recover_pending_operations`` completes every op exactly once, in both
   polling modes and on both SQLite backends.

Reproduction recipe: every failure here prints its seed; re-run any single
schedule with the same seed (or set ``CHAOS_SEED``/``CHAOS_SCHEDULE`` on a
live server) to replay the identical fault trace.
"""

import json
import os
import signal
import subprocess
import sys
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core import Metadata, ObjectiveMetricGoal, StudyConfig
from repro.core.metadata import MetadataDelta, Namespace
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    OperationFailedError,
    VizierClient,
    chaos,
)
from repro.service.chaos import ChaosError, Fault, FaultInjector
from repro.service.operations import fail_operation_from_exception
from repro.service.rpc import StatusCode, VizierRpcError

pytestmark = pytest.mark.chaos

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _config(algorithm: str = "RANDOM_SEARCH") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0)
    cfg.metrics.add("acc", ObjectiveMetricGoal.MAXIMIZE)
    cfg.algorithm = algorithm
    return cfg


# ---------------------------------------------------------------------------
# 1. Harness unit tests
# ---------------------------------------------------------------------------


def test_fault_site_glob_matching():
    f = Fault(site="datastore.*", kind="stall")
    assert f.matches("datastore.put_operation")
    assert f.matches("datastore")
    assert not f.matches("transport.send")
    exact = Fault(site="queue.ack", kind="error")
    assert exact.matches("queue.ack")
    assert not exact.matches("queue.ack.extra")


def _firing_trace(seed: int, n_events: int):
    inj = FaultInjector(seed, [Fault(site="s", kind="delay", prob=0.5,
                                     times=n_events, delay_s=0.0)])
    for _ in range(n_events):
        inj.fire("s", {})
    return list(inj.events)


def test_same_seed_same_firing_trace():
    assert _firing_trace(123, 40) == _firing_trace(123, 40)


def test_different_seed_different_firing_trace():
    assert _firing_trace(123, 40) != _firing_trace(124, 40)


def test_after_and_times_window():
    inj = FaultInjector(0, [Fault(site="s", kind="delay", after=2, times=2,
                                  delay_s=0.0)])
    for _ in range(10):
        inj.fire("s", {})
    assert inj.fired_count("s") == 2
    # fired exactly on the 3rd and 4th matching events
    assert [e[2] for e in inj.events] == [2, 3]


def test_error_kind_carries_status_code():
    inj = FaultInjector(0, [Fault(site="s", kind="error", code=9)])
    with pytest.raises(ChaosError) as ei:
        inj.fire("s", {})
    assert ei.value.code == 9
    # ...and the op-failure mapper consumes it like any carried code
    op = {"name": "x/operations/1", "done": False, "result": None,
          "error": None}
    failed = fail_operation_from_exception(op, ei.value)
    assert failed["done"] is True
    assert failed["error"]["code"] == 9


def test_sever_and_drop_raise_connection_error():
    for kind in ("sever", "drop"):
        inj = FaultInjector(7, [Fault(site="s", kind=kind)])
        with pytest.raises(ConnectionError):
            inj.fire("s", {})


def test_expire_lease_effect():
    inj = FaultInjector(0, [Fault(site="queue.lease", kind="expire_lease")])
    lease = SimpleNamespace(deadline=time.monotonic() + 1e6)
    inj.fire("queue.lease", {"lease": lease})
    assert lease.deadline < time.monotonic()


def test_kill_worker_effect_and_raising_fault_still_wins():
    killed = threading.Event()
    inj = FaultInjector(0, [
        Fault(site="queue.ack", kind="kill_worker"),
        Fault(site="queue.ack", kind="error", code=14),
    ])
    with pytest.raises(ChaosError) as ei:
        inj.fire("queue.ack", {"kill": killed.set})
    assert killed.is_set()  # non-raising effect applied before the raise
    assert ei.value.code == 14


def test_corrupt_scrambles_only_gp_bandit_namespace():
    delta = MetadataDelta()
    delta.assign("repro.gp_bandit", "state", b"precious")
    delta.assign("user.notes", "state", b"untouched")
    delta.assign("repro.gp_bandit", "state", b"trial", trial_id=3)
    inj = FaultInjector(0, [Fault(site="datastore.apply_metadata_delta",
                                  kind="corrupt")])
    inj.fire("datastore.apply_metadata_delta", {"delta": delta})
    gp = delta.on_study.abs_ns(Namespace("repro.gp_bandit"))
    assert gp["state"] == chaos._CORRUPT_BLOB
    assert delta.on_study.abs_ns(Namespace("user.notes"))["state"] == b"untouched"
    tgp = delta.on_trials[3].abs_ns(Namespace("repro.gp_bandit"))
    assert tgp["state"] == chaos._CORRUPT_BLOB


def test_inject_is_noop_when_uninstalled():
    chaos.uninstall()
    assert not chaos.active()
    chaos.inject("transport.send", method="Anything")  # must not raise


def test_scenario_installs_and_uninstalls():
    assert not chaos.active()
    with chaos.scenario(5, [Fault(site="s", kind="error")]) as inj:
        assert chaos.active()
        assert chaos.current() is inj
        with pytest.raises(ChaosError):
            chaos.inject("s")
    assert not chaos.active()


def test_install_from_env(monkeypatch):
    monkeypatch.delenv("CHAOS_SEED", raising=False)
    assert chaos.install_from_env() is None

    monkeypatch.setenv("CHAOS_SEED", "99")
    inj = chaos.install_from_env()
    try:
        assert inj is not None and inj.seed == 99
        assert [f.site for f in inj.faults] == \
            [f.site for f in chaos.DEFAULT_SCHEDULE]
    finally:
        chaos.uninstall()

    monkeypatch.setenv(
        "CHAOS_SCHEDULE",
        json.dumps([{"site": "transport.send", "kind": "sever", "times": 2}]))
    inj = chaos.install_from_env()
    try:
        assert [(f.site, f.kind, f.times) for f in inj.faults] == \
            [("transport.send", "sever", 2)]
    finally:
        chaos.uninstall()


def test_scenario_wins_over_env(monkeypatch):
    monkeypatch.setenv("CHAOS_SEED", "99")
    with chaos.scenario(1, []) as inj:
        assert chaos.install_from_env() is inj  # env does not clobber
    chaos.uninstall()


def test_wrap_datastore_passthrough_and_proxy():
    from repro.service import InMemoryDatastore

    ds = InMemoryDatastore()
    assert chaos.wrap_datastore(ds) is ds  # chaos off: untouched
    with chaos.scenario(3, [Fault(site="datastore.update_study_metadata",
                                  kind="corrupt", times=1)]) as inj:
        proxy = chaos.wrap_datastore(ds)
        assert proxy is not ds
        assert proxy.wrapped is ds
        from repro.core import Study

        study = Study(name="owners/o/studies/s", study_config=_config())
        proxy.create_study(study)
        assert inj.fired_count("datastore.create_study") == 0  # no fault for it
        md = Metadata()
        md.abs_ns(Namespace("repro.gp_bandit"))["state"] = b"live"
        proxy.update_study_metadata(study.name, md)
        assert inj.fired_count("datastore.update_study_metadata") == 1
        # the proxy handed the payload to the corrupt kind before delegating
        stored = ds.get_study(study.name).study_config  # study still readable
        assert stored is not None
        assert md.abs_ns(Namespace("repro.gp_bandit"))["state"] == \
            chaos._CORRUPT_BLOB


# ---------------------------------------------------------------------------
# End-to-end harness helpers
# ---------------------------------------------------------------------------

_TOLERATED = (VizierRpcError, OperationFailedError, ConnectionError,
              TimeoutError)


def _retrying(fn, *, attempts=12, errors=None, pause=0.05):
    """Run ``fn`` through injected faults: any tolerated failure must carry
    an int status code (per-item isolation) and is retried."""
    last = None
    for _ in range(attempts):
        try:
            return fn()
        except _TOLERATED as e:
            code = getattr(e, "code", None)
            if code is not None:
                assert isinstance(code, int), f"non-int status code: {e!r}"
                if errors is not None:
                    errors.append(code)
            last = e
            time.sleep(pause)
    raise AssertionError(f"gave up after {attempts} attempts: {last!r}")


def _complete_tolerant(client, trial_id, value, errors):
    def attempt():
        try:
            client.complete_trial({"acc": value}, trial_id=trial_id)
        except VizierRpcError as e:
            # a dropped-response resend: the first attempt DID land
            if e.code != StatusCode.FAILED_PRECONDITION:
                raise

    _retrying(attempt, errors=errors)


def _make_server(topology, tmp_path):
    common = dict(n_pythia_workers=2, n_shards=4, lease_timeout=0.5)
    if topology == "default":
        # the crash-durable sharded backend under storm
        return DefaultVizierServer(
            database_path=str(tmp_path / "chaosdb"), database_shards=4,
            **common)
    return DistributedVizierServer(**common)


def _workload(server, *, n_studies=2, n_clients=2, rounds=2,
              algorithm="RANDOM_SEARCH", prefix="chaos"):
    """Concurrent suggest/complete rounds; returns (study_names, completed
    trial ids per study, observed status codes)."""
    errors = []
    completed = {}
    lock = threading.Lock()
    study_names = []
    for si in range(n_studies):
        c = _retrying(lambda si=si: VizierClient.load_or_create_study(
            f"{prefix}-{si}", _config(algorithm), client_id="seed",
            target=server.address), errors=errors)
        study_names.append(c.study_name)
        c.close()

    failures = []

    def run_client(ci):
        try:
            for si in range(n_studies):
                client = VizierClient(server.address, study_names[si],
                                      f"c{ci}")
                try:
                    for r in range(rounds):
                        trials = _retrying(
                            lambda: client.get_suggestions(
                                count=1, timeout=30.0),
                            errors=errors)
                        for t in trials:
                            _complete_tolerant(
                                client, t.id, float(ci + r), errors)
                            with lock:
                                completed.setdefault(
                                    study_names[si], set()).add(t.id)
                finally:
                    client.close()
        except BaseException as e:  # noqa: BLE001 — surfaced to the test
            failures.append(e)

    threads = [threading.Thread(target=run_client, args=(ci,))
               for ci in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not failures, f"client thread failed: {failures[0]!r}"
    return study_names, completed, errors


def _drain_and_check(server, study_names, completed, injector,
                     *, expect_prefix=None, timeout=30.0):
    """Wait for the queue to drain (restarting chaos-killed workers — the
    operator action) and assert the robustness invariants."""
    ds = getattr(server.datastore, "wrapped", server.datastore)
    svc = server.servicer
    deadline = time.monotonic() + timeout
    while True:
        if svc.worker_pool is not None:
            alive = set(svc.worker_pool.alive_workers())
            for wid in range(svc.worker_pool.n_workers):
                if wid not in alive:
                    svc.worker_pool.restart_worker(wid)
        pending = [op["name"] for s in study_names
                   for op in ds.list_operations(s, only_pending=True)]
        queued = svc._queue.pending_count() if svc._queue is not None else 0
        if not pending and queued == 0:
            break
        assert time.monotonic() < deadline, (
            f"seed {injector.seed}: queue never drained: "
            f"pending={pending} queued={queued}")
        time.sleep(0.05)

    for s in study_names:
        ids = [t.id for t in ds.list_trials(s)]
        assert len(ids) == len(set(ids)), \
            f"seed {injector.seed}: duplicate trial ids in {s}: {sorted(ids)}"
        for op in ds.list_operations(s):
            assert op["done"] is True, \
                f"seed {injector.seed}: lost op {op['name']}"
            err = op.get("error")
            if err is not None:
                assert isinstance(err.get("code"), int), \
                    f"seed {injector.seed}: anonymous failure on {op['name']}"
    # no lost acked work: every completion a client observed stays terminal
    for s, ids in completed.items():
        for tid in ids:
            assert ds.get_trial(s, tid).state.is_terminal, \
                f"seed {injector.seed}: acked completion of {s}/{tid} lost"
    if expect_prefix is not None:
        assert injector.fired_count(expect_prefix) > 0, (
            f"seed {injector.seed}: schedule never fired at "
            f"{expect_prefix!r} — the sweep is vacuous")


# ---------------------------------------------------------------------------
# 2. Fast end-to-end smokes (coverage-floor run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["default", "distributed"])
def test_mild_storm_smoke(topology, tmp_path):
    faults = [
        Fault(site="transport.send", kind="sever", prob=0.5, times=2),
        Fault(site="datastore.*", kind="stall", prob=0.2, times=5,
              delay_s=0.005),
    ]
    with chaos.scenario(4242, faults) as inj:
        server = _make_server(topology, tmp_path)
        try:
            names, completed, errors = _workload(server)
            _drain_and_check(server, names, completed, inj, expect_prefix="")
        finally:
            server.stop()
    assert all(isinstance(c, int) for c in errors)


# ---------------------------------------------------------------------------
# 3. The sweep: named schedules x both topologies
# ---------------------------------------------------------------------------

# (name, faults, non-vacuity site prefix, study algorithm)
SCHEDULES = [
    ("send-sever",
     [Fault(site="transport.send", kind="sever", prob=0.3, times=4)],
     "transport.send", "RANDOM_SEARCH"),
    ("recv-drop",  # server applied the request; the response is lost
     [Fault(site="transport.recv", kind="drop", prob=0.3, times=4)],
     "transport.recv", "RANDOM_SEARCH"),
    ("ds-get-error",  # read fails inside the RPC handler: carried-code map
     [Fault(site="datastore.get_study", kind="error", prob=0.3, times=3,
            code=14)],
     "datastore.get_study", "RANDOM_SEARCH"),
    ("ds-stall",
     [Fault(site="datastore.*", kind="stall", prob=0.15, times=12,
            delay_s=0.01)],
     "datastore.", "RANDOM_SEARCH"),
    ("ds-put-op-error",  # write fails inside finalize too: release-path test
     [Fault(site="datastore.put_operation", kind="error", prob=0.4,
            times=2)],
     "datastore.put_operation", "RANDOM_SEARCH"),
    ("lease-expire",  # reclaimed mid-run: exactly-once finalize guard
     [Fault(site="queue.lease", kind="expire_lease", prob=0.6, times=3)],
     "queue.lease", "RANDOM_SEARCH"),
    ("worker-kill-ack",  # dies after the batch ran, before acking
     [Fault(site="queue.ack", kind="kill_worker", times=1)],
     "queue.ack", "RANDOM_SEARCH"),
    ("worker-kill-batch",  # dies holding an unprocessed lease
     [Fault(site="worker.batch", kind="kill_worker", after=1, times=1)],
     "worker.batch", "RANDOM_SEARCH"),
    ("finalize-delay",
     [Fault(site="service.finalize", kind="delay", prob=0.5, times=4,
            delay_s=0.02)],
     "service.finalize", "RANDOM_SEARCH"),
    ("mixed-storm", list(chaos.DEFAULT_SCHEDULE), None, "RANDOM_SEARCH"),
]

_SCHEDULE_INDEX = {s[0]: i for i, s in enumerate(SCHEDULES)}


@pytest.mark.slow
@pytest.mark.parametrize("topology", ["default", "distributed"])
@pytest.mark.parametrize(
    "name,faults,expect,algorithm", SCHEDULES,
    ids=[s[0] for s in SCHEDULES])
def test_seeded_schedule_sweep(name, faults, expect, algorithm, topology,
                               tmp_path):
    seed = 1000 + 2 * _SCHEDULE_INDEX[name] + (topology == "distributed")
    with chaos.scenario(seed, [Fault(**vars(f)) for f in faults]) as inj:
        server = _make_server(topology, tmp_path)
        try:
            names, completed, errors = _workload(
                server, algorithm=algorithm, prefix=f"sweep-{name}")
            _drain_and_check(server, names, completed, inj,
                             expect_prefix=expect)
        finally:
            server.stop()
    assert all(isinstance(c, int) for c in errors), errors


def test_corrupt_state_blob_is_cold_start_not_failure(tmp_path):
    """The ``corrupt`` kind scrambles a repro.gp_bandit checkpoint on its
    way through the datastore seam (a torn write); the GP policy must treat
    the garbage as a cold start on the next suggest — the op never fails
    (the state loader's defensive-load contract)."""
    from repro.pythia.state import GP_BANDIT_NAMESPACE, STATE_KEY

    faults = [Fault(site="datastore.apply_metadata_delta", kind="corrupt",
                    times=1)]
    with chaos.scenario(5151, faults) as inj:
        server = _make_server("default", tmp_path)
        try:
            c = VizierClient.load_or_create_study(
                "corrupt", _config("GP_UCB"), client_id="c0",
                target=server.address)
            delta = MetadataDelta()
            delta.assign(GP_BANDIT_NAMESPACE, STATE_KEY,
                         b"valid-looking-checkpoint")
            c.update_metadata(delta)
            assert inj.fired_count("datastore.apply_metadata_delta") == 1
            # the torn write really landed in the store...
            stored = c.get_study_metadata().abs_ns(
                Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
            assert stored == chaos._CORRUPT_BLOB
            # ...and the policy shrugs it off as a cold start
            for r in range(2):
                trials = _retrying(lambda: c.get_suggestions(
                    count=1, timeout=60.0))
                assert trials
                for t in trials:
                    _complete_tolerant(c, t.id, float(r), [])
            study = c.study_name
            c.close()
            _drain_and_check(
                server, [study], {}, inj,
                expect_prefix="datastore.apply_metadata_delta")
        finally:
            server.stop()


def test_frame_budget_intact_under_chaos_harness():
    """Control run: with the injector installed but an empty schedule, the
    chaos seams and datastore proxy add ZERO frames to the dispatch (one
    Pythia hop + one GetTrialsMulti per suggest — the pinned budget)."""
    with chaos.scenario(7, []) as inj:
        server = DistributedVizierServer()
        try:
            c = VizierClient.load_or_create_study(
                "frames", _config(), client_id="w0", target=server.address)
            server.servicer.reset_method_counts()
            server.pythia_servicer.reset_method_counts()
            (t,) = c.get_suggestions(count=1)
            assert t.id >= 1
            api = server.servicer.method_counts()
            pythia = server.pythia_servicer.method_counts()
            assert pythia == {"PythiaSuggest": 1}
            assert api.get("GetTrialsMulti") == 1
            assert "ListTrials" not in api
            assert "GetStudy" not in api
            c.close()
        finally:
            server.stop()
        assert inj.fired_count() == 0


# ---------------------------------------------------------------------------
# 4. Crash-restart durability (SIGKILL mid-suggest-batch)
# ---------------------------------------------------------------------------

_CRASH_HELPER = os.path.join(REPO_ROOT, "tests", "_crash_server.py")


def _crash_env(sleep_s=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env.pop("CHAOS_SEED", None)
    if sleep_s is not None:
        env["CRASH_SLEEP"] = str(sleep_s)
    return env


@pytest.mark.slow
@pytest.mark.parametrize("poll_mode,shards", [
    ("wait", 0), ("get", 0), ("wait", 4),
], ids=["waitop-sqlite", "getop-sqlite", "waitop-sharded"])
def test_sigkill_mid_batch_then_recover_exactly_once(tmp_path, poll_mode,
                                                     shards):
    """Phase 1 (subprocess): server on a durable SQLite path, one trial
    completed (acked work), then a suggest op dispatched into a policy that
    stalls for 30s — SIGKILLed mid-batch. Phase 2 (fresh subprocess, same
    path): recover_pending_operations must finish the op exactly once."""
    db = str(tmp_path / ("db" if shards else "db.sqlite3"))
    sentinel = str(tmp_path / "sentinel.json")

    p1 = subprocess.Popen(
        [sys.executable, _CRASH_HELPER, "serve", db, str(shards), sentinel],
        env=_crash_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    try:
        deadline = time.monotonic() + 60
        while not os.path.exists(sentinel):
            assert p1.poll() is None, (
                f"phase-1 server died early: "
                f"{p1.communicate()[1].decode(errors='replace')[-2000:]}")
            assert time.monotonic() < deadline, "phase-1 sentinel timeout"
            time.sleep(0.05)
        state = json.loads(open(sentinel).read())
        time.sleep(0.3)  # let the worker lease the op and enter the policy
    finally:
        os.kill(p1.pid, signal.SIGKILL)
        p1.wait(timeout=10)

    out = subprocess.run(
        [sys.executable, _CRASH_HELPER, "recover", db, str(shards),
         poll_mode, state["op_name"], state["study_name"]],
        env=_crash_env(sleep_s=0), capture_output=True, timeout=120)
    assert out.returncode == 0, out.stderr.decode(errors="replace")[-2000:]
    report = json.loads(out.stdout.decode().strip().splitlines()[-1])

    assert report["done"] is True
    assert report["error"] is None, report
    # recovery re-ran the op from its persisted record — not via the
    # worker-death requeue path, so the stamp stays untouched
    assert report["requeues"] == 0
    assert report["result_trials"] == 2
    # exactly once: 1 pre-kill completed trial + the 2 suggested, no extras
    assert report["trial_count"] == 3
    assert report["completed_trial_state_terminal"] is True
