"""Deterministic stand-in for the `hypothesis` API subset used by this suite.

The container has no `hypothesis` wheel and dependencies cannot be added, so
property tests import this shim as a fallback:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, strategies as st

Semantics: @given runs the test body `max_examples` times (capped by
SHIM_MAX_EXAMPLES, default 50) with values drawn from a per-example
`random.Random` seeded by (test name, example index) — fully deterministic
across runs, no example database, no shrinking. Numeric strategies bias
toward boundary values so edge cases are exercised on every run.
"""

from __future__ import annotations

import functools
import os
import random
import string
import zlib
from types import SimpleNamespace

_MAX_EXAMPLES_CAP = int(os.environ.get("SHIM_MAX_EXAMPLES", "50"))


class settings:
    """Decorator recording (max_examples, deadline); deadline is ignored."""

    def __init__(self, max_examples: int = 50, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example(self, rng: random.Random):
        return self._draw_fn(rng)


def _integers(min_value=-(2**63), max_value=2**63 - 1) -> _Strategy:
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return min_value
        if r < 0.10:
            return max_value
        if r < 0.15 and min_value <= 0 <= max_value:
            return 0
        return rng.randint(min_value, max_value)

    return _Strategy(draw)


def _floats(min_value=None, max_value=None, allow_nan=True, allow_infinity=True,
            **_ignored) -> _Strategy:
    lo = -1e300 if min_value is None else float(min_value)
    hi = 1e300 if max_value is None else float(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        if r < 0.15 and lo <= 0.0 <= hi:
            return 0.0
        if r < 0.30:
            # small-magnitude values near the low end of the range
            span = hi - lo
            return lo + span * (10.0 ** rng.uniform(-9, 0))
        return rng.uniform(lo, hi)

    return _Strategy(draw)


_ALPHABET = string.ascii_letters + string.digits + " _-./:é中α"


def _text(min_size=0, max_size=20, **_ignored) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(rng.choice(_ALPHABET) for _ in range(n))

    return _Strategy(draw)


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: options[rng.randrange(len(options))])


def _one_of(*strategies) -> _Strategy:
    return _Strategy(lambda rng: strategies[rng.randrange(len(strategies))].example(rng))


def _tuples(*strategies) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def _lists(elements: _Strategy, min_size=0, max_size=10, unique=False,
           **_ignored) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out, seen = [], set()
        for _ in range(n * 20):
            if len(out) >= n:
                break
            v = elements.example(rng)
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out

    return _Strategy(draw)


def _dictionaries(keys: _Strategy, values: _Strategy, min_size=0, max_size=10,
                  **_ignored) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        out = {}
        for _ in range(n * 2):  # oversample: key collisions shrink the dict
            if len(out) >= n:
                break
            out[keys.example(rng)] = values.example(rng)
        return out

    return _Strategy(draw)


def _composite(fn):
    """@st.composite — fn(draw, *args) becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strategy: strategy.example(rng), *args, **kwargs)

        return _Strategy(draw_value)

    return factory


strategies = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    text=_text,
    booleans=_booleans,
    sampled_from=_sampled_from,
    one_of=_one_of,
    tuples=_tuples,
    lists=_lists,
    dictionaries=_dictionaries,
    composite=_composite,
)


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        # NOT functools.wraps: copying __wrapped__ would expose the original
        # signature and make pytest treat strategy parameters as fixtures.
        def wrapper():
            cfg = getattr(fn, "_shim_settings", None) or getattr(
                wrapper, "_shim_settings", None
            )
            n = min(cfg.max_examples if cfg else 50, _MAX_EXAMPLES_CAP)
            for i in range(n):
                seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}:{i}".encode())
                rng = random.Random(seed)
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*drawn, **drawn_kw)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate
